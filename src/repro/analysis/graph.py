"""Pass 1 — static verifier for ``hnp`` lazy expression graphs.

The frontend (PR 4–5) captures whole computations as expression graphs and
the scheduler lowers them onto the offload registry in topological waves,
fusing elementwise chains and stacking independent GEMMs.  Every one of
those transformations assumes invariants that nothing proved until now:
node shapes/dtypes must agree with the registry host lowerings they will
dispatch through, residency handles must still be alive (and known to the
engine) when a node consuming them is forced, no buffer may be staged onto
a device twice, and the wave schedule must be hazard-free (no stacked
launch reading a value produced inside the same launch, no fused chain
overwriting a value a live consumer still needs).

This module checks all of that *pre-dispatch*, on the captured graph — the
verifier never launches anything.  It is exposed three ways:

* standalone: :func:`verify_graph` / :func:`assert_valid` over graph roots;
* ``hnp.offload_region(..., validate=True)`` — the scheduler calls
  :func:`assert_valid` on every graph forced inside the region;
* ``dispatch_placed(..., validate=True)`` — :func:`verify_call` checks one
  eager registry call (operand shapes against the host lowering, handle
  lifetime) before anything is scheduled or recorded.

Violations carry stable rule names (``graph/shape-mismatch``,
``graph/use-after-unstage``, ``graph/raw-hazard``, ...) so tests and CI can
assert on exactly which invariant broke.

Import-light by contract: stdlib + numpy + the (equally light) frontend at
module scope; jax and the offload engine load lazily inside the checks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.base import AnalysisError, Violation
from repro.frontend.lazy import (
    ELEMENTWISE,
    ELEMENTWISE_BINARY,
    ELEMENTWISE_UNARY,
    REDUCTIONS,
    SHAPE_OPS,
    Node,
    is_heavy,
    rebuild_call,
)
from repro.frontend.schedule import _batch_key, _fusion_chains

__all__ = [
    "GraphVerificationError",
    "WavePlan",
    "assert_call_valid",
    "assert_valid",
    "check_plan",
    "collect_nodes",
    "plan_waves",
    "verify_call",
    "verify_graph",
]


class GraphVerificationError(AnalysisError):
    def __init__(self, violations: Sequence[Violation]) -> None:
        super().__init__(violations, "hnp graph failed pre-dispatch verification")


def _where(node: Node) -> str:
    return f"node#{node.id}({node.op})"


# ---------------------------------------------------------------------------
# Graph walk
# ---------------------------------------------------------------------------

def collect_nodes(roots: Sequence[Node]) -> List[Node]:
    """Postorder over every node reachable from ``roots`` (leaves included,
    evaluated or not — unlike the scheduler's walk, verification wants the
    whole captured graph, since corruption hides in the evaluated parts)."""
    order: List[Node] = []
    seen = set()
    stack: List[Tuple[Node, bool]] = [(r, False) for r in reversed(list(roots))]
    while stack:
        node, expanded = stack.pop()
        if node.id in seen:
            continue
        if expanded:
            seen.add(node.id)
            order.append(node)
            continue
        stack.append((node, True))
        for inp in node.inputs:
            if inp.id not in seen:
                stack.append((inp, False))
    return order


# ---------------------------------------------------------------------------
# Structural rules: shape/dtype consistency, arity, staleness
# ---------------------------------------------------------------------------

def _registry_infer(node: Node) -> Tuple[Tuple[int, ...], Any]:
    """Re-infer a registry node's result spec through the op's *host*
    lowering (the same abstract evaluation ``registry_node`` used at capture
    time) — the ground truth the dispatch will actually run against."""
    import jax

    from repro.core.dispatch import get_op

    op = get_op(node.attrs["name"])
    specs = [
        jax.ShapeDtypeStruct(i.shape, i.dtype) if i.dtype is not None
        else i.value
        for i in node.inputs
    ]

    def _abstract(*vals):
        pos, kw = rebuild_call(node, list(vals))
        return op.host(*pos, **kw)

    out = jax.eval_shape(_abstract, *specs)
    return tuple(out.shape), out.dtype


def _expected_spec(node: Node) -> Optional[Tuple[Tuple[int, ...], Any]]:
    """Independently recompute (shape, dtype) for one node, or None when the
    op carries no static contract to check (weak scalar leaves)."""
    from repro.frontend.lazy import _result_dtype

    ins = node.inputs
    if node.op == "leaf":
        if node.dtype is None:          # weak Python scalar
            return None
        return tuple(np.shape(node.value)) if node.evaluated else node.shape, (
            getattr(node.value, "dtype", node.dtype) if node.evaluated
            else node.dtype
        )
    if node.op in ELEMENTWISE_UNARY:
        (x,) = ins
        return x.shape, x.dtype
    if node.op in ELEMENTWISE_BINARY:
        a, b = ins
        return (
            tuple(np.broadcast_shapes(a.shape, b.shape)),
            _result_dtype(a.dtype, b.dtype),
        )
    if node.op in REDUCTIONS:
        (x,) = ins
        axis = node.attrs.get("axis")
        axes = (
            tuple(range(x.ndim)) if axis is None
            else tuple(
                a % x.ndim
                for a in ((axis,) if isinstance(axis, int) else tuple(axis))
            )
        )
        if node.attrs.get("keepdims"):
            shape = tuple(1 if i in axes else d for i, d in enumerate(x.shape))
        else:
            shape = tuple(d for i, d in enumerate(x.shape) if i not in axes)
        return shape, x.dtype
    if node.op == "reshape":
        return tuple(node.attrs["shape"]), ins[0].dtype
    if node.op == "transpose":
        (x,) = ins
        return tuple(x.shape[a] for a in node.attrs["axes"]), x.dtype
    if node.op == "astype":
        return ins[0].shape, node.attrs["dtype"]
    if is_heavy(node.op):
        return _registry_infer(node)
    return None


_ARITY = {1: ELEMENTWISE_UNARY | REDUCTIONS | SHAPE_OPS, 2: ELEMENTWISE_BINARY}


def _check_structure(order: List[Node]) -> List[Violation]:
    out: List[Violation] = []
    known = ELEMENTWISE | REDUCTIONS | SHAPE_OPS | {"leaf"}
    for n in order:
        if n.op not in known and not is_heavy(n.op):
            out.append(Violation(
                "graph/unknown-op",
                f"node has no lowering: op {n.op!r} is neither a light op "
                "nor a registry:<op> dispatch",
                _where(n),
            ))
            continue
        for arity, ops in _ARITY.items():
            if n.op in ops and len(n.inputs) != arity:
                out.append(Violation(
                    "graph/bad-arity",
                    f"{n.op!r} expects {arity} input(s), found "
                    f"{len(n.inputs)}",
                    _where(n),
                ))
                break
        else:
            if n.evaluated and n.op != "leaf" and any(
                not i.evaluated for i in n.inputs
            ):
                pend = [i.id for i in n.inputs if not i.evaluated]
                out.append(Violation(
                    "graph/stale-value",
                    "node carries a cached value while producer input(s) "
                    f"{pend} are still pending — a consumer would read a "
                    "stale buffer (RAW on the value cache)",
                    _where(n),
                ))
                continue
            try:
                spec = _expected_spec(n)
            except KeyError as e:
                out.append(Violation(
                    "graph/unknown-op",
                    f"registry lookup failed: {e}",
                    _where(n),
                ))
                continue
            except Exception as e:
                out.append(Violation(
                    "graph/shape-mismatch",
                    "host lowering rejected the operand specs: "
                    f"{type(e).__name__}: {e}",
                    _where(n),
                ))
                continue
            if spec is None:
                continue
            shape, dtype = spec
            if tuple(shape) != tuple(n.shape):
                out.append(Violation(
                    "graph/shape-mismatch",
                    f"node claims shape {n.shape} but {n.op!r} over inputs "
                    f"{[i.shape for i in n.inputs]} produces {tuple(shape)}",
                    _where(n),
                ))
            elif dtype is not None and n.dtype is not None and (
                np.dtype(dtype) != np.dtype(n.dtype)
            ):
                out.append(Violation(
                    "graph/dtype-mismatch",
                    f"node claims dtype {n.dtype} but {n.op!r} produces "
                    f"{dtype}",
                    _where(n),
                ))
    return out


# ---------------------------------------------------------------------------
# Residency lifetime rules
# ---------------------------------------------------------------------------

def _engine_or_none():
    try:
        from repro.core.hero import engine

        return engine()
    except Exception:  # pragma: no cover — engine import failure
        return None


def _handle_violations(handle, eng, where: str) -> List[Violation]:
    if handle is None or not hasattr(handle, "valid"):
        return []
    if not handle.valid:
        return [Violation(
            "graph/use-after-unstage",
            f"buffer {handle.name!r} is consumed after its handle was "
            "unstaged/evicted — the residency credit it promises is gone",
            where,
        )]
    if eng is not None and eng.handle(handle.name) is not handle:
        return [Violation(
            "graph/handle-escapes-region",
            f"handle {handle.name!r} (device {handle.device_id}) is not in "
            "the engine ledger — it escaped the offload_region/handle_scope "
            "that owned it",
            where,
        )]
    return []


def _check_residency(order: List[Node], region) -> List[Violation]:
    out: List[Violation] = []
    eng = _engine_or_none()
    by_buffer: Dict[int, List[Tuple[Node, Any]]] = {}
    for n in order:
        handles = []
        h = n.attrs.get("handle") if isinstance(n.attrs, dict) else None
        if h is not None:
            handles.append(h)
        if region is not None:
            rh = getattr(region, "residency", {}).get(n.id)
            if rh is not None and rh is not h:
                handles.append(rh)
        for h in handles:
            out.extend(_handle_violations(h, eng, _where(n)))
        if n.evaluated and n.dtype is not None:
            live = [h for h in handles if getattr(h, "valid", False)]
            if live:
                by_buffer.setdefault(id(n.value), []).append((n, live))
    for entries in by_buffer.values():
        names = {h.name for _, hs in entries for h in hs}
        if len(names) > 1:
            nodes = ",".join(_where(n) for n, _ in entries)
            out.append(Violation(
                "graph/double-stage",
                "the same underlying buffer is staged on device under "
                f"{len(names)} distinct handles ({sorted(names)}) — the "
                "copy is paid twice and the residency ledgers disagree",
                nodes,
            ))
    return out


# ---------------------------------------------------------------------------
# Wave-schedule hazards
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WavePlan:
    """The schedule the scheduler *would* run: topological waves over the
    unevaluated subgraph, per-head fused elementwise chains, and stacked
    ``gemm_batched`` groups.  :func:`check_plan` validates a plan — the
    real one from :func:`plan_waves`, or an injected/corrupted one in
    tests — independently of how it was built."""

    order: List[Node]
    waves: List[List[Node]]
    chains: Dict[int, List[Node]]      # head node id -> fused chain
    groups: List[List[Node]]           # members of one stacked launch
    leftover: List[Node]               # unschedulable nodes (cycles)


def plan_waves(roots: Sequence[Node]) -> WavePlan:
    """Dry-run the scheduler's wave construction (no dispatch, no values)."""
    order = [n for n in collect_nodes(roots) if not n.evaluated]
    in_graph = {n.id for n in order}
    by_id = {n.id: n for n in order}
    consumers: Dict[int, List[Node]] = {}
    deps: Dict[int, int] = {}
    for n in order:
        cnt = 0
        for i in n.inputs:
            if i.id in in_graph:
                consumers.setdefault(i.id, []).append(n)
                cnt += 1
        deps[n.id] = cnt
    chains, _fused_into = _fusion_chains(order, consumers)
    waves: List[List[Node]] = []
    groups: List[List[Node]] = []
    ready = sorted(nid for nid, c in deps.items() if c == 0)
    done = set()
    while ready:
        wave = [by_id[i] for i in ready]
        waves.append(wave)
        batch: Dict[Any, List[Node]] = {}
        for n in wave:
            if is_heavy(n.op):
                key = _batch_key(n)
                if key is not None:
                    batch.setdefault(key, []).append(n)
        groups.extend(m for m in batch.values() if len(m) >= 2)
        nxt: List[int] = []
        for n in wave:
            done.add(n.id)
            for c in consumers.get(n.id, []):
                deps[c.id] -= 1
                if deps[c.id] == 0:
                    nxt.append(c.id)
        ready = sorted(nxt)
    leftover = [n for n in order if n.id not in done]
    return WavePlan(order, waves, chains, groups, leftover)


def _reaches(src: Node, dst: Node, in_graph: set) -> bool:
    """True when ``dst`` is reachable from ``src`` through graph inputs."""
    stack, seen = [src], set()
    while stack:
        n = stack.pop()
        if n.id == dst.id:
            return True
        if n.id in seen:
            continue
        seen.add(n.id)
        stack.extend(i for i in n.inputs if i.id in in_graph)
    return False


def check_plan(plan: WavePlan) -> List[Violation]:
    """Validate one wave schedule against the hazard rules."""
    out: List[Violation] = []
    if plan.leftover:
        out.append(Violation(
            "graph/cycle",
            "schedule cannot complete; unschedulable nodes (dependency "
            f"cycle): {[_where(n) for n in plan.leftover]}",
        ))
    in_graph = {n.id for n in plan.order}
    wave_of: Dict[int, int] = {}
    for k, wave in enumerate(plan.waves):
        for n in wave:
            wave_of[n.id] = k
    chain_of: Dict[int, int] = {}      # link id -> head id
    chain_pos: Dict[int, int] = {}     # link id -> position in chain
    for head_id, chain in plan.chains.items():
        for pos, link in enumerate(chain):
            chain_of[link.id] = head_id
            chain_pos[link.id] = pos
            # a fused link executes with its head's launch
            if head_id in wave_of:
                wave_of[link.id] = wave_of[head_id]

    # RAW: every read must happen-after the write that produced it.
    for n in plan.order:
        if n.id not in wave_of:
            continue  # leftover, already reported as a cycle
        for i in n.inputs:
            if i.id not in in_graph or i.id not in wave_of:
                continue
            same_chain = (
                chain_of.get(n.id) is not None
                and (
                    chain_of.get(i.id) == chain_of.get(n.id)
                    and chain_pos[i.id] < chain_pos[n.id]
                    or i.id == chain_of.get(n.id)
                )
            )
            if same_chain:
                continue  # ordered within one fused launch
            if wave_of[i.id] >= wave_of[n.id]:
                out.append(Violation(
                    "graph/raw-hazard",
                    f"{_where(n)} (wave {wave_of[n.id]}) reads "
                    f"{_where(i)} scheduled in wave {wave_of[i.id]} — the "
                    "consumer would launch before its producer's value "
                    "exists",
                    _where(n),
                ))

    # RAW inside one stacked launch: a gemm_batched member must not depend
    # on another member — the single launch would read its own output.
    for members in plan.groups:
        for a in members:
            for b in members:
                if a is not b and _reaches(a, b, in_graph):
                    out.append(Violation(
                        "graph/raw-hazard",
                        f"stacked launch batches {_where(a)} with its own "
                        f"producer {_where(b)} — the batched GEMM would "
                        "read a value it is itself computing",
                        _where(a),
                    ))

    # WAR: a fused chain evaluates link k and moves on; any *other* consumer
    # of link k in the plan reads after the chain has conceptually replaced
    # it — every non-final link must have exactly its successor as consumer.
    consumers: Dict[int, List[Node]] = {}
    for n in plan.order:
        for i in n.inputs:
            if i.id in in_graph:
                consumers.setdefault(i.id, []).append(n)
    for head_id, chain in plan.chains.items():
        prev_id = head_id
        for pos, link in enumerate(chain):
            if prev_id not in {i.id for i in link.inputs}:
                out.append(Violation(
                    "graph/war-hazard",
                    f"fused chain under head node#{head_id} is not linear: "
                    f"{_where(link)} does not consume its predecessor "
                    f"node#{prev_id}",
                    _where(link),
                ))
            if pos < len(chain) - 1:
                cs = consumers.get(link.id, [])
                extra = [c for c in cs if c.id != chain[pos + 1].id]
                if extra:
                    out.append(Violation(
                        "graph/war-hazard",
                        f"fused link {_where(link)} has outside consumer(s) "
                        f"{[_where(c) for c in extra]} — fusing it into "
                        f"node#{head_id}'s launch overwrites a value a live "
                        "reader still needs",
                        _where(link),
                    ))
            prev_id = link.id
    return out


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def verify_graph(
    roots: Sequence[Node],
    region=None,
    *,
    check_shapes: bool = True,
    check_waves: bool = True,
) -> List[Violation]:
    """Run every graph rule over the subgraph reachable from ``roots``.

    ``region`` (a :class:`~repro.frontend.schedule.GraphRegion`) supplies
    scheduler-owned residency for the lifetime rules; without it only
    node-attached handles are checked.
    """
    roots = [getattr(r, "node", r) for r in roots]
    order = collect_nodes(roots)
    out: List[Violation] = []
    if check_shapes:
        out.extend(_check_structure(order))
    out.extend(_check_residency(order, region))
    if check_waves:
        out.extend(check_plan(plan_waves(roots)))
    return out


def assert_valid(roots: Sequence[Node], region=None) -> None:
    violations = verify_graph(roots, region)
    if violations:
        raise GraphVerificationError(violations)


def verify_call(
    name: str,
    args: Sequence[Any] = (),
    kwargs: Optional[Dict[str, Any]] = None,
    *,
    handle=None,
) -> List[Violation]:
    """Verify one *eager* registry call pre-dispatch (``dispatch_placed``'s
    ``validate=True``): op known, handle alive and engine-owned, operand
    shapes/dtypes accepted by the host lowering under abstract evaluation.
    """
    kwargs = dict(kwargs or {})
    where = f"dispatch:{name}"
    from repro.core.dispatch import get_op

    try:
        op = get_op(name)
    except KeyError as e:
        return [Violation("graph/unknown-op", str(e), where)]
    out = _handle_violations(handle, _engine_or_none(), where)

    import jax

    template: List[Tuple[str, Any]] = []
    kw_specs: Dict[str, Any] = {}
    specs: List[Any] = []
    for a in args:
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            template.append(("in", len(specs)))
            specs.append(jax.ShapeDtypeStruct(tuple(a.shape), a.dtype))
        else:
            template.append(("static", a))
    for k, v in kwargs.items():
        if hasattr(v, "shape") and hasattr(v, "dtype"):
            kw_specs[k] = len(specs)
            specs.append(jax.ShapeDtypeStruct(tuple(v.shape), v.dtype))

    def _abstract(*vals):
        pos = [vals[idx] if kind == "in" else idx for kind, idx in template]
        kw = {
            k: (vals[kw_specs[k]] if k in kw_specs else v)
            for k, v in kwargs.items()
        }
        return op.host(*pos, **kw)

    try:
        jax.eval_shape(_abstract, *specs)
    except Exception as e:
        out.append(Violation(
            "graph/shape-mismatch",
            "host lowering rejected the operand specs "
            f"{[(tuple(s.shape), str(s.dtype)) for s in specs]}: "
            f"{type(e).__name__}: {e}",
            where,
        ))
    return out


def assert_call_valid(
    name: str,
    args: Sequence[Any] = (),
    kwargs: Optional[Dict[str, Any]] = None,
    *,
    handle=None,
) -> None:
    violations = verify_call(name, args, kwargs, handle=handle)
    if violations:
        raise GraphVerificationError(violations)
