"""``repro.analysis`` — the static-analysis substrate of the reproduction.

Three passes over three layers of the offload seam:

* :mod:`repro.analysis.graph` — pre-dispatch verifier for ``hnp`` lazy
  expression graphs (shapes/dtypes vs registry host lowerings, residency
  handle lifetimes, wave-schedule RAW/WAR hazards);
* :mod:`repro.analysis.races` — happens-before checker over the
  ``LaunchTicket`` event streams the modeled devices emit;
* :mod:`repro.analysis.lint` — AST lint rule engine for the repo's
  structural invariants (driven by ``tools/repro_lint.py`` / ``make lint``).

All passes report :class:`~repro.analysis.base.Violation` records with
stable rule names and raise :class:`~repro.analysis.base.AnalysisError`
subclasses from their ``assert_*`` entry points.

Import-light by contract (gated by ``tools/check_import_time.py``): this
package pulls no jax and no engine at import; the dynamic passes load them
lazily when handed live graphs or clusters.
"""

from repro.analysis.base import AnalysisError, Violation, format_violations

__all__ = ["AnalysisError", "Violation", "format_violations"]
