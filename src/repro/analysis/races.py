"""Pass 2 — happens-before checker over ``LaunchTicket`` event streams.

PR 6 turned every modeled device into two event streams (DMA engine,
compute cluster) whose frontier clocks ``VirtualDevice.issue`` advances per
launch; every ticket is stamped with where its events landed
(``issue_s -> copy_ready_s -> copy_done_s`` on the DMA stream,
``compute_start_s -> complete_s`` on the compute stream).  The whole
overlap story — pipelined staging, cross-wave prefetch, ``d2d_copy``
migration shingled under compute — is only *correct* if a happens-before
order holds between those events.  HERO-class shared-memory platforms get
exactly this wrong in subtle ways (arxiv 1712.06497): a compute kernel
reading a buffer whose DMA hasn't drained reads garbage without crashing.

This pass re-derives the order from the tickets alone (it never consults
the scheduler that produced them) and reports named violations:

* ``race/event-order`` — a ticket's own events out of order;
* ``race/compute-before-copy-ready`` — compute starts before the first
  staged chunk has landed;
* ``race/complete-before-copy-done`` — a launch retires before its staging
  stream drained (the readback would copy a half-written buffer);
* ``race/dma-clock-monotone`` / ``race/compute-clock-monotone`` — a
  device's stream clocks ran backwards between consecutive tickets;
* ``race/read-before-copy-done`` — a launch's compute starts before the
  copy-done of a staging ticket (prefetch / d2d / restage) issued earlier
  on its device: the data it could consume is still in flight;
* ``race/resident-charged-dma`` — a fully-resident launch
  (``resident_fraction >= 1``) charged DMA time it must not pay;
* ``race/device-mismatch`` — a ticket filed on a device other than the one
  stamped on it;
* ``race/slot-refill-before-complete`` — continuous-batching slot refill:
  a freed decode slot's next launch was issued before the finishing
  request's ``complete`` event (:func:`check_slot_refills`, over the
  streaming engine's :class:`~repro.launch.streaming.SlotRefill` records);
* ``race/expert-migrate-before-drain`` — dynamic expert placement: an
  expert-weight migration's d2d ticket issued while a source-lane launch
  still reading the handle was in flight (:func:`check_expert_migrations`,
  over the placement policy's
  :class:`~repro.core.placement.MigrationEdge` records).

Violations carry the offending ticket chain so the report reads as a
timeline, not a boolean.

Import-light by contract: stdlib only at module scope; the engine loads
lazily inside :func:`ticket_streams`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.analysis.base import AnalysisError, Violation

__all__ = [
    "StreamRaceError",
    "assert_race_free",
    "check_cluster",
    "check_expert_migrations",
    "check_slot_refills",
    "check_ticket_streams",
    "ticket_streams",
]

# Stream clocks are exact float copies of one another in a correct model;
# the tolerance only forgives accumulated fp error, never a real reorder.
_TOL = 1e-9

_STAGING_KINDS = ("prefetch", "d2d", "restage")


class StreamRaceError(AnalysisError):
    def __init__(self, violations: Sequence[Violation]) -> None:
        super().__init__(violations, "LaunchTicket streams violate happens-before")


def _tag(device_id: int, idx: int, t) -> str:
    return f"dev{device_id}[{idx}]({t.kind}:{t.op}/{t.shape_key})"


def _chain(device_id: int, *pairs) -> str:
    return " -> ".join(_tag(device_id, i, t) for i, t in pairs)


def ticket_streams(cluster=None) -> Dict[int, List]:
    """Per-device ticket streams, in issue order, from ``cluster`` (the
    engine singleton when omitted)."""
    if cluster is None:
        from repro.core.hero import engine

        cluster = engine()
    return {d.device_id: list(d.inflight) for d in cluster.devices}


def _check_one(device_id: int, idx: int, t) -> List[Violation]:
    out: List[Violation] = []
    where = _tag(device_id, idx, t)
    if t.compute_start_s < t.copy_ready_s - _TOL:
        out.append(Violation(
            "race/compute-before-copy-ready",
            f"compute starts at {t.compute_start_s:.6g}s but the first "
            f"staged chunk lands at {t.copy_ready_s:.6g}s — the kernel "
            "would read an empty operand buffer",
            where,
        ))
    if t.complete_s < t.copy_done_s - _TOL:
        out.append(Violation(
            "race/complete-before-copy-done",
            f"launch retires at {t.complete_s:.6g}s with its staging "
            f"stream draining until {t.copy_done_s:.6g}s — readback would "
            "ship a half-written buffer",
            where,
        ))
    ordered = (
        t.issue_s - _TOL <= t.copy_ready_s <= t.copy_done_s + _TOL
        and t.compute_start_s - _TOL <= t.complete_s
    )
    if not ordered:
        out.append(Violation(
            "race/event-order",
            "ticket events out of order: issue="
            f"{t.issue_s:.6g} copy_ready={t.copy_ready_s:.6g} "
            f"copy_done={t.copy_done_s:.6g} "
            f"compute_start={t.compute_start_s:.6g} "
            f"complete={t.complete_s:.6g}",
            where,
        ))
    if t.kind == "launch" and t.resident_fraction >= 1.0 and (
        t.copy_done_s > t.issue_s + _TOL
    ):
        out.append(Violation(
            "race/resident-charged-dma",
            "fully-resident launch (resident_fraction="
            f"{t.resident_fraction:.2f}) charged "
            f"{t.copy_done_s - t.issue_s:.6g}s of DMA — residency credit "
            "must make the copy region free",
            where,
        ))
    if t.device_id != device_id:
        out.append(Violation(
            "race/device-mismatch",
            f"ticket stamped device_id={t.device_id} is filed on device "
            f"{device_id}'s queue — its events were charged to the wrong "
            "stream clocks",
            where,
        ))
    return out


def check_ticket_streams(streams: Dict[int, List]) -> List[Violation]:
    """Run every happens-before rule over per-device ticket streams
    (``{device_id: [LaunchTicket, ...]}`` in issue order)."""
    out: List[Violation] = []
    for device_id in sorted(streams):
        tickets = list(streams[device_id])
        for idx, t in enumerate(tickets):
            out.extend(_check_one(device_id, idx, t))

        # Clock monotonicity between consecutive tickets.  Requeued orphans
        # occupy only the compute stream (their staging was charged where
        # they first ran), so they are exempt from the DMA-stream rule.
        prev_dma = None        # (idx, ticket) of last DMA-stream user
        prev = None            # (idx, ticket) of last ticket
        for idx, t in enumerate(tickets):
            if prev_dma is not None and t.kind != "requeue":
                pi, p = prev_dma
                if t.issue_s < p.copy_done_s - _TOL:
                    out.append(Violation(
                        "race/dma-clock-monotone",
                        f"DMA clock ran backwards: issue at {t.issue_s:.6g}s "
                        f"while the previous staging drains until "
                        f"{p.copy_done_s:.6g}s",
                        _chain(device_id, (pi, p), (idx, t)),
                    ))
            if prev is not None:
                pi, p = prev
                if t.compute_start_s < p.complete_s - _TOL:
                    out.append(Violation(
                        "race/compute-clock-monotone",
                        "compute clock ran backwards: start at "
                        f"{t.compute_start_s:.6g}s while the previous "
                        f"launch retires at {p.complete_s:.6g}s",
                        _chain(device_id, (pi, p), (idx, t)),
                    ))
            if t.kind != "requeue":
                prev_dma = (idx, t)
            prev = (idx, t)

        # Happens-before from staging to compute: data staged by a
        # prefetch/d2d/restage ticket must be fully landed before any later
        # launch on the device starts computing — that launch is exactly the
        # consumer the staging was issued for (cross-wave prefetch lands
        # under wave k's compute, is read by wave k+1).
        for si, s in enumerate(tickets):
            if s.kind not in _STAGING_KINDS:
                continue
            for ti in range(si + 1, len(tickets)):
                t = tickets[ti]
                if t.kind != "launch":
                    continue
                if t.compute_start_s < s.copy_done_s - _TOL:
                    out.append(Violation(
                        "race/read-before-copy-done",
                        f"launch compute starts at {t.compute_start_s:.6g}s "
                        f"but the {s.kind} staging it may consume "
                        f"({s.shape_key!r}) only lands at "
                        f"{s.copy_done_s:.6g}s",
                        _chain(device_id, (si, s), (ti, t)),
                    ))
                break  # monotone streams make the first launch the witness
    return out


def check_slot_refills(refills: Sequence) -> List[Violation]:
    """Happens-before over continuous-batching slot refills.

    The streaming engine frees a decode slot when its request's final step
    retires (the ``complete`` event) and records the lane's next launch as
    a refill edge.  The invariant: that next launch's *issue* event is
    at-or-after the freeing completion — issuing into a slot whose previous
    occupant is still computing would interleave two requests' KV state on
    one lane.  Duck-typed over anything carrying ``device_id``,
    ``freed_rids``, ``freed_complete_s``, ``next_rids``, ``refill_issue_s``
    (the engine's ``SlotRefill`` records), so this pass stays import-light.
    """
    out: List[Violation] = []
    for i, r in enumerate(refills):
        if r.refill_issue_s < r.freed_complete_s - _TOL:
            out.append(Violation(
                "race/slot-refill-before-complete",
                f"slot refill issued at {r.refill_issue_s:.6g}s while the "
                f"freed request(s) {list(r.freed_rids)} only complete at "
                f"{r.freed_complete_s:.6g}s — the next launch "
                f"({list(r.next_rids)}) would share the lane with a live "
                "occupant",
                f"dev{r.device_id}[refill {i}]",
            ))
    return out


def check_expert_migrations(edges: Sequence) -> List[Violation]:
    """Happens-before over dynamic expert-weight migrations.

    When the placement policy moves a hot expert's weights between lanes,
    the d2d copy reads the source-lane buffer that in-flight grouped-FFN
    launches may still be consuming.  The invariant: the migration ticket's
    *issue* event is at-or-after the latest ``complete`` of source-lane
    launches keyed on the handle (the drain fence) — issuing earlier would
    copy weights out from under a running kernel.  Duck-typed over anything
    carrying ``expert``, ``handle_name``, ``src_device``, ``dst_device``,
    ``migrate_issue_s``, ``src_drain_s`` (the policy's ``MigrationEdge``
    records), so this pass stays import-light.
    """
    out: List[Violation] = []
    for i, e in enumerate(edges):
        if e.migrate_issue_s < e.src_drain_s - _TOL:
            out.append(Violation(
                "race/expert-migrate-before-drain",
                f"expert {e.expert} weight migration "
                f"({e.handle_name!r}, dev{e.src_device} -> "
                f"dev{e.dst_device}) issued its d2d at "
                f"{e.migrate_issue_s:.6g}s while a source-lane launch still "
                f"reading the handle completes at {e.src_drain_s:.6g}s — "
                "the copy would lift weights out from under a running "
                "kernel",
                f"dev{e.src_device}[migration {i}]",
            ))
    return out


def check_cluster(cluster=None) -> List[Violation]:
    """Check the live engine (or an explicit cluster) for stream races."""
    return check_ticket_streams(ticket_streams(cluster))


def assert_race_free(cluster_or_streams=None) -> None:
    if isinstance(cluster_or_streams, dict):
        violations = check_ticket_streams(cluster_or_streams)
    else:
        violations = check_cluster(cluster_or_streams)
    if violations:
        raise StreamRaceError(violations)
