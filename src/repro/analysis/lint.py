"""Pass 3 — the repo lint rule engine (AST-based, named per-path rules).

The offload seam only stays transparent if every layer goes through it: the
model zoo must not hand-roll contractions or bare engine accounting, only
``compat.py`` may probe jax's surface, the frontend and this package must
not import jax at module scope, the registry must stay closed (every pallas
table row reachable, every registered op exercised by the parity suite),
and every trace record must carry its placement.  Until now the only
enforcement was one ad-hoc AST scan buried in ``tests/test_models.py``;
this module generalizes it into named, per-path :class:`LintRule` objects
so each invariant exists in exactly one place and is reported as
``path:line: rule: message`` (the ``tools/repro_lint.py`` CLI and
``make lint`` drive it; the old test is now a thin assertion over
:func:`run_lint`).

Rules:

* ``models-no-dot-general`` — no raw ``*.dot_general(...)`` contraction
  call sites under ``models/`` (dispatch through a registered OffloadOp);
* ``models-no-bare-launch`` — no ``engine().launch(...)`` under
  ``models/`` (accounting the scheduler/cost model/trace cannot see);
* ``no-jax-probe-outside-compat`` — no ``getattr``/``hasattr`` probing of
  jax modules outside ``compat.py`` (version seams live in one file);
* ``frontend-import-light`` — no module-scope jax imports under
  ``frontend/`` and ``analysis/`` (the import-time budget's static twin);
* ``trace-record-device-id`` — every ``OffloadRecord``/``LaunchTicket``
  constructor names its ``device_id`` (placement is never defaulted into
  the trace);
* ``registry-closure`` — repo-level: every ``pallas_lowering("x")`` fetch
  in ``core/blas.py`` has a ``kernels/ops.py`` table row, and the parity
  suite's sample dict covers exactly the registered ops;
* ``serve-no-wallclock`` — no ``time.time``/``perf_counter``/``datetime
  .now`` reads in the streaming-serve cost paths (``launch/streaming.py``,
  ``launch/costing.py``): the driver is modeled-time only, so same-seed
  runs stay byte-identical.
* ``obs-modeled-time-only`` — the same wall-clock machinery over the
  observability layer (``src/repro/obs/``) and its instrumentation call
  sites (``core/hero.py``, ``core/dispatch.py``, ``frontend/schedule.py``):
  spans and counters carry modeled timestamps only.

Import-light by contract: stdlib only at module scope.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Callable, List, Optional, Sequence, Set

from repro.analysis.base import AnalysisError, Violation

__all__ = [
    "FileView",
    "LintError",
    "LintRule",
    "RULES",
    "check_registry_closure",
    "lint_file",
    "repo_root",
    "run_lint",
]


class LintError(AnalysisError):
    def __init__(self, violations: Sequence[Violation]) -> None:
        super().__init__(violations, "repo lint failed")


def repo_root(start: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Repo root: nearest ancestor of this file holding ``src/repro``."""
    p = (start or pathlib.Path(__file__)).resolve()
    for parent in [p] + list(p.parents):
        if (parent / "src" / "repro").is_dir():
            return parent
    return pathlib.Path.cwd()


@dataclasses.dataclass
class FileView:
    """One parsed source file as the rules see it."""

    path: pathlib.Path
    rel: str                      # posix path relative to the repo root
    source: str
    tree: Optional[ast.AST]       # None when the file failed to parse

    @classmethod
    def load(cls, path: pathlib.Path, root: pathlib.Path) -> "FileView":
        source = path.read_text()
        try:
            rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        try:
            tree = ast.parse(source)
        except SyntaxError:
            tree = None
        return cls(path=path, rel=rel, source=source, tree=tree)

    def where(self, node: ast.AST) -> str:
        return f"{self.rel}:{getattr(node, 'lineno', 0)}"


@dataclasses.dataclass(frozen=True)
class LintRule:
    """One named invariant: where it applies, and how to check one file."""

    name: str
    description: str
    paths: tuple                  # rel-path prefixes the rule applies under
    check: Callable[["FileView"], List[Violation]]
    exclude: tuple = ()           # rel-path prefixes/exact files exempted

    def applies(self, rel: str) -> bool:
        if not rel.endswith(".py"):
            return False
        if any(rel == e or rel.startswith(e) for e in self.exclude):
            return False
        return any(rel.startswith(p) for p in self.paths)


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _jax_aliases(tree: ast.AST) -> Set[str]:
    """Names that are (or root) a jax module in this file: ``jax`` itself,
    ``import jax.numpy as jnp``, ``from jax import numpy as jnp``, ..."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax" or a.name.startswith("jax."):
                    names.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "jax" or mod.startswith("jax."):
                for a in node.names:
                    names.add(a.asname or a.name)
    return names


def _is_type_checking_if(node: ast.If) -> bool:
    t = node.test
    return (isinstance(t, ast.Name) and t.id == "TYPE_CHECKING") or (
        isinstance(t, ast.Attribute) and t.attr == "TYPE_CHECKING"
    )


def _module_scope_stmts(tree: ast.AST):
    """Statements that execute at import time: the module body, recursing
    into class bodies and if/try arms, never into function bodies; a
    ``TYPE_CHECKING`` guard is exempt (it never runs at import)."""
    stack = list(getattr(tree, "body", []))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, ast.If):
            if not _is_type_checking_if(node):
                stack.extend(node.body)
            stack.extend(node.orelse)
        elif isinstance(node, ast.ClassDef):
            stack.extend(node.body)
        elif isinstance(node, ast.Try):
            stack.extend(node.body)
            for h in node.handlers:
                stack.extend(h.body)
            stack.extend(node.orelse)
            stack.extend(node.finalbody)


# ---------------------------------------------------------------------------
# Per-file rule checks
# ---------------------------------------------------------------------------

def _check_no_dot_general(view: FileView) -> List[Violation]:
    out = []
    for node in ast.walk(view.tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "dot_general"
        ):
            out.append(Violation(
                "models-no-dot-general",
                "raw dot_general contraction under models/ — dispatch "
                "through a registered OffloadOp (core/blas.py) so the "
                "scheduler/cost model/trace see the call",
                view.where(node),
            ))
    return out


def _check_no_bare_launch(view: FileView) -> List[Violation]:
    out = []
    for node in ast.walk(view.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
            continue
        fn = node.func
        if (
            fn.attr == "launch"
            and isinstance(fn.value, ast.Call)
            and isinstance(fn.value.func, ast.Name)
            and fn.value.func.id in ("engine", "_engine")
        ):
            out.append(Violation(
                "models-no-bare-launch",
                "bare engine().launch(...) under models/ — go through "
                "dispatch()/dispatch_placed() so placement and accounting "
                "stay on the registry path",
                view.where(node),
            ))
    return out


def _check_no_jax_probe(view: FileView) -> List[Violation]:
    aliases = _jax_aliases(view.tree)
    if not aliases:
        return []
    out = []
    for node in ast.walk(view.tree):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in ("getattr", "hasattr")
            and node.args
        ):
            continue
        root = _root_name(node.args[0])
        if root in aliases:
            out.append(Violation(
                "no-jax-probe-outside-compat",
                f"{node.func.id}() probes the jax surface ({root}) — "
                "version/feature seams live in repro/compat.py only",
                view.where(node),
            ))
    return out


def _check_import_light(view: FileView) -> List[Violation]:
    out = []
    for node in _module_scope_stmts(view.tree):
        bad = None
        if isinstance(node, ast.Import):
            hits = [a.name for a in node.names
                    if a.name == "jax" or a.name.startswith(("jax.", "jaxlib"))]
            bad = ", ".join(hits) if hits else None
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "jax" or mod.startswith(("jax.", "jaxlib")):
                bad = mod
        if bad:
            out.append(Violation(
                "frontend-import-light",
                f"module-scope import of {bad} — frontend/analysis modules "
                "are import-light by contract (stdlib + numpy at module "
                "scope; jax loads lazily at first use)",
                view.where(node),
            ))
    return out


_WALLCLOCK_CALLS = frozenset({
    "time", "time_ns", "perf_counter", "perf_counter_ns",
    "monotonic", "monotonic_ns", "process_time", "process_time_ns",
})


def _time_aliases(tree: ast.AST) -> Set[str]:
    """Names bound to the ``time`` module (or its clock functions)."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time" or a.name.startswith("time."):
                    names.add(a.asname or "time")
        elif isinstance(node, ast.ImportFrom):
            if (node.module or "") == "time":
                for a in node.names:
                    names.add(a.asname or a.name)
    return names


def _no_wallclock_check(rule: str, context: str):
    """Build a wallclock checker for one rule: the modeled-time contract
    (two same-seed runs must be byte-identical — one ``time.time()``
    silently breaks that) is shared by the streaming-serve cost paths
    (``serve-no-wallclock``) and the observability/instrumentation seams
    (``obs-modeled-time-only``); only the rule name and the violation's
    context phrase differ.  Flag the imports (any wall clock enters
    through them) and every clock-function call."""

    def check(view: FileView) -> List[Violation]:
        out = []
        for node in ast.walk(view.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "time" or a.name.startswith("time."):
                        out.append(Violation(
                            rule,
                            f"import of the time module in {context} — "
                            "the driver is modeled-time only (seeded "
                            "traces + LaunchTicket event clocks); a "
                            "wall-clock read breaks same-seed determinism",
                            view.where(node),
                        ))
            elif isinstance(node, ast.ImportFrom):
                if (node.module or "") == "time":
                    out.append(Violation(
                        rule,
                        "from time import "
                        f"{', '.join(a.name for a in node.names)}"
                        f" in {context} — modeled time only",
                        view.where(node),
                    ))
            elif isinstance(node, ast.Call):
                fn = node.func
                name = None
                if (
                    isinstance(fn, ast.Attribute)
                    and fn.attr in _WALLCLOCK_CALLS
                    and _root_name(fn) in _time_aliases(view.tree)
                ):
                    name = f"{_root_name(fn)}.{fn.attr}"
                elif (
                    isinstance(fn, ast.Attribute)
                    and fn.attr in ("now", "utcnow", "today")
                    and _root_name(fn) in ("datetime", "date")
                ):
                    name = f"{_root_name(fn)}.{fn.attr}"
                if name:
                    out.append(Violation(
                        rule,
                        f"{name}() wall-clock read in {context} — "
                        "timestamps come from modeled LaunchTicket "
                        "events, never the host clock",
                        view.where(node),
                    ))
        return out

    return check


_check_no_wallclock = _no_wallclock_check(
    "serve-no-wallclock", "a streaming-serve cost path")
_check_obs_modeled_time = _no_wallclock_check(
    "obs-modeled-time-only", "an observability/instrumentation path")


_TRACE_RECORDS = ("OffloadRecord", "LaunchTicket")


def _check_trace_device_id(view: FileView) -> List[Violation]:
    out = []
    for node in ast.walk(view.tree):
        if not isinstance(node, ast.Call):
            continue
        name = (
            node.func.id if isinstance(node.func, ast.Name)
            else node.func.attr if isinstance(node.func, ast.Attribute)
            else None
        )
        if name not in _TRACE_RECORDS:
            continue
        kw = {k.arg for k in node.keywords}
        if "device_id" not in kw and None not in kw:  # None == **kwargs
            out.append(Violation(
                "trace-record-device-id",
                f"{name}(...) without device_id= — every trace record "
                "carries the placement it ran on; defaulting it hides "
                "mis-placed launches from the per-device rollups",
                view.where(node),
            ))
    return out


# ---------------------------------------------------------------------------
# Repo-level rule: registry closure
# ---------------------------------------------------------------------------

def _string_keys(d: ast.Dict) -> List[str]:
    return [k.value for k in d.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)]


def _registered_names(blas_tree: ast.AST) -> List[str]:
    """Names of ``register(OffloadOp(name="...", ...))`` sites."""
    names = []
    for node in ast.walk(blas_tree):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "OffloadOp"
        ):
            continue
        for k in node.keywords:
            if k.arg == "name" and isinstance(k.value, ast.Constant):
                names.append(k.value.value)
    return names


def _pallas_fetches(blas_tree: ast.AST) -> List[tuple]:
    """``(name, lineno)`` for every literal ``pallas_lowering("x")`` call."""
    fetches = []
    for node in ast.walk(blas_tree):
        if (
            isinstance(node, ast.Call)
            and _root_name(node.func) is not None
            and (
                (isinstance(node.func, ast.Name)
                 and node.func.id == "pallas_lowering")
                or (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "pallas_lowering")
            )
            and node.args
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, str)
        ):
            fetches.append((node.args[0].value, node.lineno))
    return fetches


def check_registry_closure(root: Optional[pathlib.Path] = None) -> List[Violation]:
    """Static closure of the op registry across its three homes:
    ``core/blas.py`` (descriptors + pallas fetches), ``kernels/ops.py``
    (the ``PALLAS_LOWERINGS`` table), ``tests/test_dispatch.py`` (the
    parity-sample dict the numerics suite sweeps)."""
    root = root or repo_root()
    blas = root / "src" / "repro" / "core" / "blas.py"
    ops = root / "src" / "repro" / "kernels" / "ops.py"
    samples = root / "tests" / "test_dispatch.py"
    out: List[Violation] = []
    missing = [p for p in (blas, ops, samples) if not p.is_file()]
    if missing:
        return [Violation(
            "registry-closure",
            f"cannot check: missing {[str(m) for m in missing]}",
        )]
    blas_tree = ast.parse(blas.read_text())
    ops_tree = ast.parse(ops.read_text())
    samples_tree = ast.parse(samples.read_text())

    table: List[str] = []
    for node in ast.walk(ops_tree):
        if (
            isinstance(node, ast.Assign)
            and any(isinstance(t, ast.Name) and t.id == "PALLAS_LOWERINGS"
                    for t in node.targets)
            and isinstance(node.value, ast.Dict)
        ):
            table = _string_keys(node.value)
    sample_keys: List[str] = []
    for node in ast.walk(samples_tree):
        if isinstance(node, ast.FunctionDef) and node.name == "_samples":
            for ret in ast.walk(node):
                if isinstance(ret, ast.Return) and isinstance(ret.value, ast.Dict):
                    sample_keys = _string_keys(ret.value)

    registered = _registered_names(blas_tree)
    rel = blas.relative_to(root).as_posix()
    for name, lineno in _pallas_fetches(blas_tree):
        if name not in table:
            out.append(Violation(
                "registry-closure",
                f"pallas_lowering({name!r}) has no PALLAS_LOWERINGS row in "
                "kernels/ops.py — the fetch would KeyError at first device "
                "dispatch",
                f"{rel}:{lineno}",
            ))
    for name in registered:
        if name not in sample_keys:
            out.append(Violation(
                "registry-closure",
                f"registered op {name!r} has no parity sample in "
                "tests/test_dispatch.py::_samples — the numerics suite "
                "never exercises it",
                rel,
            ))
    for name in sample_keys:
        if name not in registered:
            out.append(Violation(
                "registry-closure",
                f"parity sample {name!r} has no registered OffloadOp in "
                "core/blas.py — stale sample",
                samples.relative_to(root).as_posix(),
            ))
    return out


# ---------------------------------------------------------------------------
# The rule table + engine
# ---------------------------------------------------------------------------

RULES = (
    LintRule(
        name="models-no-dot-general",
        description="no raw *.dot_general(...) call sites under models/",
        paths=("src/repro/models/",),
        check=_check_no_dot_general,
    ),
    LintRule(
        name="models-no-bare-launch",
        description="no bare engine().launch(...) under models/",
        paths=("src/repro/models/",),
        check=_check_no_bare_launch,
    ),
    LintRule(
        name="no-jax-probe-outside-compat",
        description="getattr/hasattr probing of jax only in compat.py",
        paths=("src/repro/",),
        exclude=("src/repro/compat.py",),
        check=_check_no_jax_probe,
    ),
    LintRule(
        name="frontend-import-light",
        description="no module-scope jax imports under frontend/ and analysis/",
        paths=("src/repro/frontend/", "src/repro/analysis/"),
        check=_check_import_light,
    ),
    LintRule(
        name="trace-record-device-id",
        description="OffloadRecord/LaunchTicket constructors carry device_id",
        paths=("src/repro/",),
        check=_check_trace_device_id,
    ),
    LintRule(
        name="serve-no-wallclock",
        description="no wall-clock reads in the streaming-serve cost paths",
        paths=(
            "src/repro/launch/streaming.py",
            "src/repro/launch/costing.py",
        ),
        check=_check_no_wallclock,
    ),
    LintRule(
        name="obs-modeled-time-only",
        description="spans/metrics take timestamps from modeled clocks, "
                    "never time.* or datetime",
        paths=(
            "src/repro/obs/",
            "src/repro/core/hero.py",
            "src/repro/core/dispatch.py",
            "src/repro/frontend/schedule.py",
        ),
        check=_check_obs_modeled_time,
    ),
)


def lint_file(
    path: pathlib.Path,
    root: Optional[pathlib.Path] = None,
    rules: Optional[Sequence[LintRule]] = None,
) -> List[Violation]:
    root = root or repo_root()
    view = FileView.load(pathlib.Path(path), root)
    if view.tree is None:
        return [Violation("parse-error", "file does not parse", view.rel)]
    out: List[Violation] = []
    for rule in (RULES if rules is None else rules):
        if rule.applies(view.rel):
            out.extend(rule.check(view))
    return out


def run_lint(
    root: Optional[pathlib.Path] = None,
    paths: Optional[Sequence[pathlib.Path]] = None,
    rules: Optional[Sequence[LintRule]] = None,
    *,
    repo_rules: bool = True,
) -> List[Violation]:
    """Lint every ``.py`` under ``paths`` (default: ``src/repro``) with the
    per-file rules, plus the repo-level registry-closure rule."""
    root = root or repo_root()
    if paths is None:
        paths = [root / "src" / "repro"]
    out: List[Violation] = []
    for p in paths:
        p = pathlib.Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_file(f, root, rules))
    if repo_rules:
        out.extend(check_registry_closure(root))
    return out
