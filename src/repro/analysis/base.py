"""Shared violation/report types for the ``repro.analysis`` passes.

Every pass — graph verifier, stream-event race detector, lint rule engine —
reports findings as :class:`Violation` records carrying a stable *named*
rule (``"graph/shape-mismatch"``, ``"race/compute-before-copy-ready"``,
``"models-no-dot-general"`` ...), a human message, and a location: a file
position for lint, a node or ticket chain for the dynamic-model passes.
Raising paths wrap the list in :class:`AnalysisError` so the rule names
survive into the exception text (tests assert on them).

Import-light by contract: stdlib only at module scope (gated by
``tools/check_import_time.py`` alongside the frontend modules).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

__all__ = ["AnalysisError", "Violation", "format_violations"]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding of one analysis pass.

    rule    — stable rule name (``<pass>/<invariant>`` or the lint rule id);
    message — what broke, with enough operands/events to act on;
    where   — location: ``path:line`` for lint, a ``node#id`` chain for the
              graph verifier, a ticket chain for the race detector.
    """

    rule: str
    message: str
    where: str = ""

    def render(self) -> str:
        loc = f"{self.where}: " if self.where else ""
        return f"{loc}{self.rule}: {self.message}"


def format_violations(violations: Sequence[Violation]) -> str:
    return "\n".join(v.render() for v in violations)


class AnalysisError(Exception):
    """Raised by the ``assert_*`` entry points when violations were found.

    Carries ``flight``: the obs flight recorder's bounded window (last K
    tickets/spans per device) frozen at raise time next to the violations,
    so a red analysis run ships its own repro trace.
    """

    def __init__(self, violations: Sequence[Violation], header: str) -> None:
        self.violations: List[Violation] = list(violations)
        n = len(self.violations)
        super().__init__(
            f"{header}: {n} violation{'s' if n != 1 else ''}\n"
            + format_violations(self.violations)
        )
        try:
            from repro.obs import flight

            self.flight = flight.capture(self.violations)
        except Exception:       # never mask the analysis failure itself
            self.flight = None
