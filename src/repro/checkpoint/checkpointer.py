"""Atomic, mesh-agnostic checkpointing with keep-K retention.

Design (the 1000-node posture):
  * every leaf is saved as a *full logical* array (device shards are
    gathered on save) so a checkpoint restores onto ANY mesh / host count —
    this is what makes elastic re-scaling (``repro.runtime.elastic``) a
    pure-resharding operation;
  * writes go to ``step_XXXXXX.tmp/`` then ``os.rename`` to ``step_XXXXXX/``
    — readers can never observe a torn checkpoint (atomic publish);
  * a ``manifest.json`` records the pytree structure, leaf dtypes/shapes and
    a content checksum per leaf; restore validates before instantiating;
  * ``keep`` retention bounds disk usage; the newest K checkpoints survive.

Leaves are stored as raw ``.npy`` (one file per leaf) — no pickle, no
arbitrary code execution on restore.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Checkpointer", "save_pytree", "restore_pytree"]

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def save_pytree(tree, directory: Path) -> Dict[str, Any]:
    directory.mkdir(parents=True, exist_ok=True)
    manifest: Dict[str, Any] = {"leaves": {}}
    for key, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(directory / fname, arr, allow_pickle=False)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        }
    (directory / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def restore_pytree(template, directory: Path, *, shardings=None):
    """Restore into the structure of ``template`` (values ignored).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put with them (elastic restore onto any mesh)."""
    manifest = json.loads((directory / "manifest.json").read_text())
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    flat_s = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    leaves = []
    for i, (path, leaf) in enumerate(flat_t):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path
        )
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(directory / meta["file"], allow_pickle=False)
        if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
            raise ValueError(f"manifest mismatch for {key!r}")
        if zlib.crc32(arr.tobytes()) & 0xFFFFFFFF != meta["crc32"]:
            raise ValueError(f"checksum mismatch for {key!r} — corrupt checkpoint")
        if flat_s is not None:
            leaves.append(jax.device_put(arr, flat_s[i]))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class Checkpointer:
    root: Path
    keep: int = 3

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._pending = None  # in-flight async save

    # ---- write -----------------------------------------------------------
    def save(self, step: int, tree) -> Path:
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        save_pytree(tree, tmp)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host memory NOW, write in a background thread.

        The training loop only blocks for the device->host transfer (and
        for any previous in-flight write — single writer, ordered
        checkpoints).  Durability is identical to ``save``: the publish is
        still write-temp + atomic rename, so a crash mid-write never
        exposes a torn checkpoint."""
        import threading

        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda leaf: np.array(jax.device_get(leaf), copy=True), tree
        )
        t = threading.Thread(
            target=self.save, args=(step, host_tree), daemon=True
        )
        t.start()
        self._pending = t

    def wait(self) -> None:
        """Block until any in-flight async save has published."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ---- read ------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir():
            m = _STEP_RE.match(p.name)
            if m and p.is_dir():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: Optional[int] = None, *, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return restore_pytree(
            template, self.root / f"step_{step:08d}", shardings=shardings
        ), step

    # ---- retention ---------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)
