"""Span tracing on modeled time — zero-cost when disabled.

The runtime already stamps every interesting event onto modeled clocks
(`LaunchTicket` event pairs, stream-sim heap times, device stream clocks).
This module turns those stamps into a queryable span set: each
:class:`Span` carries a name, category, lane (``host``, ``dev3/dma``,
``dev3/compute``, ``requests``, ...), a ``[t0_s, t1_s]`` window in modeled
seconds, free-form attrs, and a parent link for nesting.

Design contract (enforced by tests/test_obs.py):

* **Zero cost when disabled.**  Instrumentation sites guard on
  ``current_tracer() is None`` and never compute span arguments when no
  tracer is installed, so a tracer-off run is bitwise-identical to a run
  of the uninstrumented code.
* **Observation only.**  A tracer records; it never touches device
  clocks, RNG, or scheduling state, so a tracer-on run produces the same
  numerical results as a tracer-off run.
* **Modeled time only.**  Timestamps come from ticket fields, sim event
  times, or :func:`modeled_now` — never ``time.*`` / ``datetime`` (the
  ``obs-modeled-time-only`` lint rule patrols this file and the
  instrumented call sites).

Usage::

    with span_trace() as tr:
        y = blas.gemm(a, b)
    print(len(tr.spans), tr.lanes())

Module-scope imports are stdlib-only: ``repro.core.hero`` and the
frontend import this module at module scope, and the frontend's
import-light contract (tools/check_import_time.py) extends to it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "CounterSample",
    "Span",
    "SpanTracer",
    "current_tracer",
    "modeled_now",
    "span_trace",
    "traced",
]

# Span record kinds, mirroring the Chrome trace-event phases they export to
# (trace_export.py owns the mapping; these names stay format-agnostic).
KIND_SPAN = "span"          # complete slice  [t0, t1]
KIND_INSTANT = "instant"    # point event     t0 == t1
KIND_ASYNC_B = "async_begin"  # async (request-lifecycle) open
KIND_ASYNC_E = "async_end"    # async close
KIND_ASYNC_N = "async_instant"  # point event inside an async track
KIND_FLOW_S = "flow_start"  # flow-arrow tail (e.g. d2d migration source)
KIND_FLOW_F = "flow_end"    # flow-arrow head


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded event on a modeled-time lane."""

    span_id: int
    parent_id: Optional[int]
    name: str
    cat: str
    lane: str
    t0_s: float
    t1_s: float
    kind: str = KIND_SPAN
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # Pairing id for async (request) and flow (arrow) events.
    pair_id: Optional[int] = None
    # Device the event belongs to (-1 = host / not device-specific); the
    # flight recorder buckets its bounded window by this.
    device_id: int = -1

    @property
    def dur_s(self) -> float:
        return self.t1_s - self.t0_s


@dataclasses.dataclass(frozen=True)
class CounterSample:
    """One sample on a counter track (in-flight depth, resident bytes...)."""

    name: str
    t_s: float
    value: float
    device_id: int = -1


class _OpenSpan:
    """A begun-but-not-finished span (mutable until :meth:`SpanTracer.end`)."""

    __slots__ = ("span_id", "parent_id", "name", "cat", "lane", "t0_s",
                 "attrs", "device_id")

    def __init__(self, span_id: int, parent_id: Optional[int], name: str,
                 cat: str, lane: str, t0_s: float,
                 attrs: Optional[Dict[str, Any]], device_id: int) -> None:
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.cat = cat
        self.lane = lane
        self.t0_s = t0_s
        self.attrs = dict(attrs) if attrs else {}
        self.device_id = device_id


class SpanTracer:
    """Accumulates spans and counter samples for one traced region.

    Nesting is tracked with an explicit open-span stack: :meth:`begin`
    pushes, :meth:`end` pops, and every event emitted in between gets the
    innermost open span as its parent.  One-shot :meth:`emit` calls (e.g.
    per-ticket stream spans, whose window is known up front) parent the
    same way without touching the stack.
    """

    def __init__(self, name: str = "trace") -> None:
        self.name = name
        self.spans: List[Span] = []
        self.counters: List[CounterSample] = []
        self._stack: List[_OpenSpan] = []
        self._ids = 0

    # ---- id / parent plumbing -------------------------------------------
    def _next_id(self) -> int:
        self._ids += 1
        return self._ids

    def _parent_id(self) -> Optional[int]:
        return self._stack[-1].span_id if self._stack else None

    def _add(self, span: Span) -> Span:
        self.spans.append(span)
        # The flight recorder keeps a bounded tail of spans per device for
        # post-mortem dumps; lazy import keeps this module self-contained.
        from repro.obs import flight
        flight.note_span(span)
        return span

    # ---- one-shot events ------------------------------------------------
    def emit(self, name: str, cat: str, lane: str, t0: float, t1: float, *,
             attrs: Optional[Dict[str, Any]] = None,
             kind: str = KIND_SPAN,
             pair_id: Optional[int] = None,
             parent_id: Optional[int] = None,
             device_id: int = -1) -> Span:
        """Record a complete span whose window is already known."""
        return self._add(Span(
            span_id=self._next_id(),
            parent_id=parent_id if parent_id is not None else self._parent_id(),
            name=name, cat=cat, lane=lane, t0_s=t0, t1_s=t1, kind=kind,
            attrs=dict(attrs) if attrs else {}, pair_id=pair_id,
            device_id=device_id,
        ))

    def instant(self, name: str, cat: str, lane: str, t: float, *,
                attrs: Optional[Dict[str, Any]] = None,
                device_id: int = -1) -> Span:
        return self.emit(name, cat, lane, t, t, attrs=attrs,
                         kind=KIND_INSTANT, device_id=device_id)

    def counter(self, name: str, t: float, value: float, *,
                device_id: int = -1) -> None:
        self.counters.append(CounterSample(name, t, value, device_id))

    # ---- nested spans ---------------------------------------------------
    def begin(self, name: str, cat: str, lane: str, t0: float, *,
              attrs: Optional[Dict[str, Any]] = None,
              device_id: int = -1) -> _OpenSpan:
        open_span = _OpenSpan(self._next_id(), self._parent_id(), name, cat,
                              lane, t0, attrs, device_id)
        self._stack.append(open_span)
        return open_span

    def end(self, open_span: _OpenSpan, t1: float, *,
            attrs: Optional[Dict[str, Any]] = None) -> Span:
        # Pop through abandoned inner opens (an exception unwound past
        # them): close them at the same instant so lanes stay well-nested.
        while self._stack and self._stack[-1] is not open_span:
            self.end(self._stack[-1], t1)
        if self._stack and self._stack[-1] is open_span:
            self._stack.pop()
        merged = open_span.attrs
        if attrs:
            merged = dict(merged)
            merged.update(attrs)
        return self._add(Span(
            span_id=open_span.span_id, parent_id=open_span.parent_id,
            name=open_span.name, cat=open_span.cat, lane=open_span.lane,
            t0_s=open_span.t0_s, t1_s=max(open_span.t0_s, t1),
            attrs=merged, device_id=open_span.device_id,
        ))

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "host", lane: str = "host", *,
             t0: Optional[float] = None,
             clock: Optional[Callable[[], float]] = None,
             attrs: Optional[Dict[str, Any]] = None,
             device_id: int = -1) -> Iterator[_OpenSpan]:
        """Context-manager span; end time read from ``clock`` (default
        :func:`modeled_now`) when the body exits."""
        tick = clock if clock is not None else modeled_now
        open_span = self.begin(name, cat, lane,
                               t0 if t0 is not None else tick(),
                               attrs=attrs, device_id=device_id)
        try:
            yield open_span
        finally:
            self.end(open_span, tick())

    # ---- async (request-lifecycle) tracks -------------------------------
    def async_begin(self, name: str, cat: str, lane: str, t: float,
                    pair_id: int, *,
                    attrs: Optional[Dict[str, Any]] = None) -> Span:
        return self.emit(name, cat, lane, t, t, attrs=attrs,
                         kind=KIND_ASYNC_B, pair_id=pair_id)

    def async_end(self, name: str, cat: str, lane: str, t: float,
                  pair_id: int, *,
                  attrs: Optional[Dict[str, Any]] = None) -> Span:
        return self.emit(name, cat, lane, t, t, attrs=attrs,
                         kind=KIND_ASYNC_E, pair_id=pair_id)

    def async_instant(self, name: str, cat: str, lane: str, t: float,
                      pair_id: int, *,
                      attrs: Optional[Dict[str, Any]] = None) -> Span:
        return self.emit(name, cat, lane, t, t, attrs=attrs,
                         kind=KIND_ASYNC_N, pair_id=pair_id)

    # ---- flow arrows ----------------------------------------------------
    def flow(self, name: str, cat: str, src_lane: str, src_t: float,
             dst_lane: str, dst_t: float, *,
             attrs: Optional[Dict[str, Any]] = None) -> int:
        """Record a flow arrow (d2d migration, slot refill) as a paired
        start/end event; returns the fresh pair id."""
        fid = self._next_id()
        self.emit(name, cat, src_lane, src_t, src_t, attrs=attrs,
                  kind=KIND_FLOW_S, pair_id=fid)
        self.emit(name, cat, dst_lane, dst_t, dst_t, attrs=attrs,
                  kind=KIND_FLOW_F, pair_id=fid)
        return fid

    # ---- queries --------------------------------------------------------
    def lanes(self) -> Tuple[str, ...]:
        seen: Dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.lane)
        return tuple(seen)


# ---------------------------------------------------------------------------
# Ambient tracer stack (mirrors accounting's offload_trace scoping).
# ---------------------------------------------------------------------------

_TRACER_STACK: List[SpanTracer] = []


def current_tracer() -> Optional[SpanTracer]:
    """The innermost active tracer, or None — instrumentation sites guard
    on this so disabled tracing costs one list lookup."""
    return _TRACER_STACK[-1] if _TRACER_STACK else None


@contextlib.contextmanager
def span_trace(name: str = "trace",
               tracer: Optional[SpanTracer] = None) -> Iterator[SpanTracer]:
    tr = tracer if tracer is not None else SpanTracer(name)
    _TRACER_STACK.append(tr)
    try:
        yield tr
    finally:
        _TRACER_STACK.pop()


def modeled_now() -> float:
    """Current modeled time: the furthest stream clock across the ambient
    engine's devices (0.0 on a fresh engine).  Host-lane spans (dispatch,
    graph scheduling) use this; stream-lane spans use ticket fields."""
    from repro.core.hero import engine
    eng = engine()
    best = 0.0
    for d in eng.devices:
        t = max(d.dma_free_s, d.compute_free_s)
        if t > best:
            best = t
    return best


def traced(name: Optional[str] = None, cat: str = "host",
           lane: str = "host") -> Callable:
    """Decorator twin of :meth:`SpanTracer.span`.  When no tracer is
    active the wrapper is a single ``if`` — it never reads a clock."""
    def deco(fn: Callable) -> Callable:
        label = name if name is not None else fn.__name__

        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> Any:
            tr = current_tracer()
            if tr is None:
                return fn(*args, **kwargs)
            with tr.span(label, cat=cat, lane=lane):
                return fn(*args, **kwargs)
        return wrapper
    return deco
