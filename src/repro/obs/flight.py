"""Bounded flight recorder — the last K tickets/spans per device.

Always on and O(K) per device: :func:`note_ticket` is called from every
``VirtualDevice.issue``/``requeue`` and :func:`note_span` from every
tracer record, each a single deque append.  When an analysis rule fires
(``StreamRaceError``, graph-validation errors), :func:`capture` freezes
the window next to the violation so a red ``make lint --smoke-races``
run ships its own repro trace — no re-run needed.

Stdlib-only at module scope; tickets/spans are duck-typed dataclasses so
this module imports nothing from ``repro.core``.
"""

from __future__ import annotations

import collections
import dataclasses
import json
from typing import Any, Deque, Dict, List, Optional, Sequence

__all__ = [
    "DEFAULT_CAPACITY",
    "FlightRecorder",
    "capture",
    "clear",
    "configure",
    "dump",
    "note_span",
    "note_ticket",
    "recorder",
]

DEFAULT_CAPACITY = 64


def _as_dict(obj: Any) -> Dict[str, Any]:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.asdict(obj)
    return {"repr": repr(obj)}


class FlightRecorder:
    """Per-device ring buffers of the most recent tickets and spans."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        if capacity < 1:
            raise ValueError(f"flight capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._tickets: Dict[int, Deque[Any]] = {}
        self._spans: Dict[int, Deque[Any]] = {}

    def _ring(self, store: Dict[int, Deque[Any]], device_id: int
              ) -> Deque[Any]:
        ring = store.get(device_id)
        if ring is None:
            ring = collections.deque(maxlen=self.capacity)
            store[device_id] = ring
        return ring

    def note_ticket(self, ticket: Any) -> None:
        self._ring(self._tickets, getattr(ticket, "device_id", -1)).append(
            ticket)

    def note_span(self, span: Any) -> None:
        self._ring(self._spans, getattr(span, "device_id", -1)).append(span)

    def capture(self, violations: Optional[Sequence[Any]] = None
                ) -> Dict[str, Any]:
        """Freeze the current window into a JSON-able dict."""
        return {
            "capacity": self.capacity,
            "violations": [
                getattr(v, "render", lambda: repr(v))()
                for v in (violations or [])
            ],
            "tickets": {
                str(dev): [_as_dict(t) for t in ring]
                for dev, ring in sorted(self._tickets.items())
            },
            "spans": {
                str(dev): [_as_dict(s) for s in ring]
                for dev, ring in sorted(self._spans.items())
            },
        }

    def clear(self) -> None:
        self._tickets.clear()
        self._spans.clear()


# ---------------------------------------------------------------------------
# Module singleton: one recorder per process, like accounting's engine.
# ---------------------------------------------------------------------------

_RECORDER = FlightRecorder()


def recorder() -> FlightRecorder:
    return _RECORDER


def note_ticket(ticket: Any) -> None:
    _RECORDER.note_ticket(ticket)


def note_span(span: Any) -> None:
    _RECORDER.note_span(span)


def capture(violations: Optional[Sequence[Any]] = None) -> Dict[str, Any]:
    return _RECORDER.capture(violations)


def configure(capacity: int) -> None:
    """Resize the window (drops the current contents — the new rings
    start empty, so 'last K' is exact from here on)."""
    global _RECORDER
    _RECORDER = FlightRecorder(capacity)


def clear() -> None:
    _RECORDER.clear()


def dump(path: str, violations: Optional[Sequence[Any]] = None) -> str:
    """Write the frozen window (plus the violations) to ``path``."""
    with open(path, "w") as f:
        json.dump(capture(violations), f, indent=1, default=repr)
        f.write("\n")
    return path
