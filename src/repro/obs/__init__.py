"""repro.obs — observability over the modeled runtime.

Four pieces, all on modeled time (never wall clock):

* :mod:`repro.obs.spans` — zero-cost-when-disabled span tracer
  (``span_trace()`` / ``current_tracer()`` / ``@traced``).
* :mod:`repro.obs.trace_export` — Chrome trace-event JSON export
  (Perfetto-loadable) of spans + raw ticket streams.
* :mod:`repro.obs.metrics` — process-local counters/gauges/histograms
  with labeled flat rollups (``obs.counter("dispatch.offloaded").inc()``).
* :mod:`repro.obs.flight` — bounded last-K-per-device flight recorder,
  dumped automatically when an analysis rule fires.

Stdlib-only at module scope: the core runtime and the frontend import
this package from their hot seams, so it must stay as cheap to import
as it is to leave disabled.
"""

from repro.obs.metrics import collect, counter, gauge, histogram, snapshot
from repro.obs.spans import (
    SpanTracer,
    current_tracer,
    modeled_now,
    span_trace,
    traced,
)

__all__ = [
    "SpanTracer",
    "collect",
    "counter",
    "current_tracer",
    "gauge",
    "histogram",
    "modeled_now",
    "snapshot",
    "span_trace",
    "traced",
]
