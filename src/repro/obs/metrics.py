"""Process-local metrics registry — counters, gauges, histograms.

The span tracer answers *where did modeled time go*; this registry
answers *how often did each path fire*: dispatch offload ratio per op,
staging-leg size distribution, admission reject reasons.  Metrics are
always on (a dict update per event — they never touch modeled clocks or
results), and scoped snapshots come from :func:`collect`::

    with metrics.collect() as reg:
        serve_stream("yi-6b", trace)
    print(reg.rollup())   # {"serve.admitted": 42.0, ...}

Registries stack and writes fan out to every active scope, so a bench
section's ``collect()`` and an inner per-run ``collect()`` both see the
same events.  Rollups are flat ``{"name{label=value}": scalar}`` dicts —
JSON-able as-is for ``StreamReport.point_dict`` and ``BENCH_offload``.

Stdlib-only at module scope (import-light contract).
"""

from __future__ import annotations

import contextlib
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "collect",
    "counter",
    "gauge",
    "histogram",
    "reset",
    "snapshot",
]

LabelKey = Tuple[Tuple[str, str], ...]


def _percentile(ordered: List[float], q: float) -> float:
    """Linear-interpolation percentile over a pre-sorted list."""
    if not ordered:
        return 0.0
    if len(ordered) == 1:
        return ordered[0]
    pos = (q / 100.0) * (len(ordered) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(ordered) - 1)
    frac = pos - lo
    return ordered[lo] * (1.0 - frac) + ordered[hi] * frac


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins level (slot target, queue depth...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Value distribution with count/sum/min/max and p50/p95/p99."""

    __slots__ = ("count", "total", "vmin", "vmax", "_values")

    def __init__(self) -> None:
        self.count = 0.0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._values: List[float] = []

    def observe(self, v: float, n: float = 1.0) -> None:
        """Record ``n`` observations of value ``v`` (``n > 1`` for closed-form
        batches, e.g. `chunks` identical staging legs)."""
        v = float(v)
        self.count += n
        self.total += v * n
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self._values.append(v)

    def summary(self) -> Dict[str, float]:
        ordered = sorted(self._values)
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin if self._values else 0.0,
            "max": self.vmax if self._values else 0.0,
            "p50": _percentile(ordered, 50.0),
            "p95": _percentile(ordered, 95.0),
            "p99": _percentile(ordered, 99.0),
        }


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_name(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """One scope's metrics, keyed by (kind, name, sorted labels)."""

    def __init__(self) -> None:
        self._items: Dict[Tuple[str, str, LabelKey], object] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, str],
             factory: type) -> object:
        key = (kind, name, _label_key(labels))
        item = self._items.get(key)
        if item is None:
            item = factory()
            self._items[key] = item
        return item

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get("counter", name, labels, Counter)  # type: ignore[return-value]

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get("gauge", name, labels, Gauge)  # type: ignore[return-value]

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self._get("histogram", name, labels, Histogram)  # type: ignore[return-value]

    def rollup(self) -> Dict[str, object]:
        """Flatten to ``{"name{k=v}": value}``; histograms flatten to
        ``name{...}.count/.sum/.p50/...`` scalar entries."""
        out: Dict[str, object] = {}
        for (kind, name, key), item in sorted(self._items.items()):
            rendered = _render_name(name, key)
            if kind == "histogram":
                for stat, v in item.summary().items():  # type: ignore[union-attr]
                    out[f"{rendered}.{stat}"] = v
            else:
                out[rendered] = item.value  # type: ignore[union-attr]
        return out

    def __len__(self) -> int:
        return len(self._items)


# ---------------------------------------------------------------------------
# Registry stack: a root registry always exists; collect() pushes scopes.
# Instrument handles fan writes out to every active scope so nested
# collectors each get a complete view.
# ---------------------------------------------------------------------------

_ROOT = MetricsRegistry()
_STACK: List[MetricsRegistry] = [_ROOT]


class _Fanout:
    """Write-through proxy over the same metric in every active scope."""

    __slots__ = ("_targets",)

    def __init__(self, targets: Tuple[object, ...]) -> None:
        self._targets = targets

    def inc(self, n: float = 1.0) -> None:
        for t in self._targets:
            t.inc(n)  # type: ignore[attr-defined]

    def set(self, v: float) -> None:
        for t in self._targets:
            t.set(v)  # type: ignore[attr-defined]

    def observe(self, v: float, n: float = 1.0) -> None:
        for t in self._targets:
            t.observe(v, n)  # type: ignore[attr-defined]


def counter(name: str, **labels: str) -> _Fanout:
    return _Fanout(tuple(r.counter(name, **labels) for r in _STACK))


def gauge(name: str, **labels: str) -> _Fanout:
    return _Fanout(tuple(r.gauge(name, **labels) for r in _STACK))


def histogram(name: str, **labels: str) -> _Fanout:
    return _Fanout(tuple(r.histogram(name, **labels) for r in _STACK))


@contextlib.contextmanager
def collect(registry: Optional[MetricsRegistry] = None
            ) -> Iterator[MetricsRegistry]:
    """Scope a fresh registry over the body; yields it for rollup."""
    reg = registry if registry is not None else MetricsRegistry()
    _STACK.append(reg)
    try:
        yield reg
    finally:
        _STACK.pop()


def snapshot() -> Dict[str, object]:
    """Rollup of the process-lifetime root registry."""
    return _ROOT.rollup()


def reset() -> None:
    """Clear the root registry (tests)."""
    _ROOT._items.clear()
