"""Chrome trace-event export — load the modeled run in Perfetto.

Maps the format-agnostic :class:`repro.obs.spans.Span` records onto the
Chrome trace-event JSON schema (the ``traceEvents`` array Perfetto and
``chrome://tracing`` both load):

* ``span``          -> ``ph="X"`` complete slices (``ts``/``dur`` in µs)
* ``instant``       -> ``ph="i"`` thread-scoped instants
* ``async_begin/end/instant`` -> ``ph="b"/"e"/"n"`` (request lifecycles,
  matched by ``id``)
* ``flow_start/end``-> ``ph="s"/"f"`` flow arrows (d2d migrations, slot
  refills), matched by ``id``
* counter samples   -> ``ph="C"`` counter tracks

Lanes become threads: ``pid`` is the process group (one per exported
tracer — e.g. per workload), ``tid`` is a stable small integer per lane,
and ``ph="M"`` metadata names both so the UI shows ``dev0/dma``,
``dev0/compute``, ... in device order with the host lane on top.

Modeled seconds convert to microseconds (``ts = t_s * 1e6``) — Perfetto
renders µs natively, and smoke-run spans live in the 1e-6..1e-1 s range.

Raw :class:`LaunchTicket` streams export losslessly through
:func:`ticket_spans` (each ticket -> its DMA window + compute window +
full field dict in attrs), so a trace can be built even for a run that
had no tracer installed.

Stdlib-only at module scope.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.obs.spans import (
    KIND_ASYNC_B,
    KIND_ASYNC_E,
    KIND_ASYNC_N,
    KIND_FLOW_F,
    KIND_FLOW_S,
    KIND_INSTANT,
    KIND_SPAN,
    CounterSample,
    Span,
    SpanTracer,
)

__all__ = [
    "chrome_trace",
    "self_time",
    "summarize",
    "ticket_spans",
    "validate_chrome_trace",
    "write_trace",
]

_US = 1e6  # modeled seconds -> trace microseconds

_PH = {
    KIND_SPAN: "X",
    KIND_INSTANT: "i",
    KIND_ASYNC_B: "b",
    KIND_ASYNC_E: "e",
    KIND_ASYNC_N: "n",
    KIND_FLOW_S: "s",
    KIND_FLOW_F: "f",
}


def _lane_sort_key(lane: str) -> Tuple[int, int, int, str]:
    """host first, then dev lanes grouped per device (dma above compute),
    then the named tracks (requests, aimd), then anything else."""
    if lane == "host":
        return (0, 0, 0, lane)
    if lane.startswith("dev"):
        head, _, stream = lane.partition("/")
        try:
            dev = int(head[3:])
        except ValueError:
            return (3, 0, 0, lane)
        order = {"dma": 0, "compute": 1}.get(stream, 2)
        return (1, dev, order, lane)
    return (2, 0, 0, lane)


def _lane_tids(lanes: Iterable[str]) -> Dict[str, int]:
    ordered = sorted(set(lanes), key=_lane_sort_key)
    return {lane: i + 1 for i, lane in enumerate(ordered)}


def _span_event(span: Span, pid: int, tid: int) -> Dict[str, Any]:
    ev: Dict[str, Any] = {
        "name": span.name,
        "cat": span.cat or "obs",
        "ph": _PH[span.kind],
        "ts": span.t0_s * _US,
        "pid": pid,
        "tid": tid,
        "args": dict(span.attrs),
    }
    if span.kind == KIND_SPAN:
        ev["dur"] = max(span.dur_s, 0.0) * _US
    elif span.kind == KIND_INSTANT:
        ev["s"] = "t"
    else:
        ev["id"] = str(span.pair_id)
        if span.kind == KIND_FLOW_F:
            ev["bp"] = "e"  # bind to the enclosing slice's end
    return ev


def _group_events(name: str, spans: Sequence[Span],
                  counters: Sequence[CounterSample],
                  pid: int) -> List[Dict[str, Any]]:
    lanes = [s.lane for s in spans]
    tids = _lane_tids(lanes)
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": name},
    }]
    for lane, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": lane},
        })
        events.append({
            "name": "thread_sort_index", "ph": "M", "pid": pid, "tid": tid,
            "args": {"sort_index": tid},
        })
    for span in spans:
        events.append(_span_event(span, pid, tids[span.lane]))
    for c in counters:
        events.append({
            "name": c.name, "cat": "counter", "ph": "C",
            "ts": c.t_s * _US, "pid": pid, "tid": 0,
            "args": {"value": c.value},
        })
    return events


def chrome_trace(tracers: "SpanTracer | Sequence[SpanTracer]", *,
                 meta: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
    """Export one tracer (or several — one Perfetto process each) to a
    Chrome trace-event dict; ``meta`` entries merge in at top level."""
    if isinstance(tracers, SpanTracer):
        tracers = [tracers]
    events: List[Dict[str, Any]] = []
    for pid, tr in enumerate(tracers, start=1):
        events.extend(_group_events(tr.name, tr.spans, tr.counters, pid))
    trace: Dict[str, Any] = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
    }
    if meta:
        trace.update(meta)
    return trace


def ticket_spans(streams: Mapping[int, Sequence[Any]]) -> List[Span]:
    """Lossless Span view of raw per-device LaunchTicket streams.

    Each ticket becomes its DMA window (``issue_s -> copy_done_s``, when
    it staged anything) and its compute window (``compute_start_s ->
    complete_s``); the full ticket field set rides in attrs, so nothing
    the ticket recorded is dropped.
    """
    out: List[Span] = []
    sid = 0
    for dev in sorted(streams):
        for t in streams[dev]:
            attrs = {
                "op": t.op, "shape_key": t.shape_key, "kind": t.kind,
                "offload_s": t.offload_s, "issue_s": t.issue_s,
                "copy_ready_s": t.copy_ready_s, "copy_done_s": t.copy_done_s,
                "compute_start_s": t.compute_start_s,
                "complete_s": t.complete_s,
                "resident_fraction": t.resident_fraction,
                "device_id": t.device_id,
            }
            if t.copy_done_s > t.issue_s:
                sid += 1
                out.append(Span(
                    span_id=sid, parent_id=None,
                    name=f"{t.kind}:{t.op}", cat="ticket",
                    lane=f"dev{dev}/dma",
                    t0_s=t.issue_s, t1_s=t.copy_done_s,
                    attrs=attrs, device_id=dev,
                ))
            sid += 1
            out.append(Span(
                span_id=sid, parent_id=None,
                name=f"{t.kind}:{t.op}", cat="ticket",
                lane=f"dev{dev}/compute",
                t0_s=t.compute_start_s, t1_s=t.complete_s,
                attrs=attrs, device_id=dev,
            ))
    return out


# ---------------------------------------------------------------------------
# Validation — tests and the check_obs gate assert on this, not on Perfetto.
# ---------------------------------------------------------------------------

def validate_chrome_trace(trace: Mapping[str, Any]) -> List[str]:
    """Structural validity of an exported trace; returns error strings
    (empty = valid)."""
    errors: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    flows: Dict[str, List[str]] = {}
    asyncs: Dict[str, List[str]] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if not ph:
            errors.append(f"event {i}: missing ph")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"event {i} ({ev.get('name')}): non-numeric ts")
            continue
        if ts < 0:
            errors.append(f"event {i} ({ev.get('name')}): negative ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"event {i} ({ev.get('name')}): X event needs dur >= 0")
        elif ph in ("s", "f"):
            fid = ev.get("id")
            if fid is None:
                errors.append(f"event {i} ({ev.get('name')}): flow without id")
            else:
                flows.setdefault(str(fid), []).append(ph)
        elif ph in ("b", "e", "n"):
            aid = ev.get("id")
            if aid is None:
                errors.append(
                    f"event {i} ({ev.get('name')}): async without id")
            elif ph != "n":
                asyncs.setdefault(str(aid), []).append(ph)
    for fid, phases in sorted(flows.items()):
        if phases.count("s") != phases.count("f"):
            errors.append(
                f"flow id {fid}: {phases.count('s')} starts vs "
                f"{phases.count('f')} finishes")
    for aid, phases in sorted(asyncs.items()):
        if phases.count("b") != phases.count("e"):
            errors.append(
                f"async id {aid}: {phases.count('b')} begins vs "
                f"{phases.count('e')} ends")
    return errors


# ---------------------------------------------------------------------------
# Self-time summary — `repro_trace --summary` and quick triage in tests.
# ---------------------------------------------------------------------------

def self_time(spans: Sequence[Span]) -> Dict[str, Dict[str, float]]:
    """Per-lane self-time by span name: duration minus direct children
    (parent links), so a wrapping dispatch span doesn't double-count the
    ticket spans it contains."""
    child_time: Dict[int, float] = {}
    for s in spans:
        if s.kind == KIND_SPAN and s.parent_id is not None:
            child_time[s.parent_id] = child_time.get(s.parent_id, 0.0) \
                + s.dur_s
    out: Dict[str, Dict[str, float]] = {}
    for s in spans:
        if s.kind != KIND_SPAN:
            continue
        own = max(s.dur_s - child_time.get(s.span_id, 0.0), 0.0)
        lane = out.setdefault(s.lane, {})
        lane[s.name] = lane.get(s.name, 0.0) + own
    return out


def summarize(spans: Sequence[Span], top: int = 10) -> str:
    """Top-``top`` spans by self-time per lane, in lane display order."""
    per_lane = self_time(spans)
    lines: List[str] = []
    for lane in sorted(per_lane, key=_lane_sort_key):
        lines.append(f"{lane}:")
        ranked = sorted(per_lane[lane].items(),
                        key=lambda kv: (-kv[1], kv[0]))[:top]
        for name, sec in ranked:
            lines.append(f"  {sec * 1e3:10.4f} ms  {name}")
    return "\n".join(lines)


def write_trace(path: str, trace: Mapping[str, Any]) -> str:
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return path
