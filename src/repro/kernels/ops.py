"""Public jit'd wrappers for the Pallas device kernels.

This is the device half of the BLAS seam: ``repro.core.blas`` routes here
when the offload policy selects the Pallas backend.  On this CPU container
the kernels execute with ``interpret=True``; on a real TPU the same calls
lower through Mosaic.  The `interpret` flag is plumbed, never hard-coded.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.gemm import DEFAULT_BLOCK, pallas_gemm, pallas_gemm_batched
from repro.kernels.ssd_scan import ssd_chunk_diag as _ssd_chunk

__all__ = [
    "PALLAS_LOWERINGS",
    "gemm",
    "gemm_batched",
    "moe_gemm",
    "flash_attention",
    "flash_decode",
    "ssd_chunk_diag",
    "pallas_lowering",
]


def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    return pallas_gemm(a, b, block=block, out_dtype=out_dtype, interpret=interpret)


def gemm_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    return pallas_gemm_batched(
        a, b, block=block, out_dtype=out_dtype, interpret=interpret
    )


def moe_gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Capacity-grouped expert GEMM: (E, C, d) @ (E, d, f) -> (E, C, f).

    Experts form the outermost *parallel* grid dimension, so each expert's
    tile stream is an independent GEMM pipeline (megablox-style layout with
    a static per-expert capacity, which keeps every tile MXU-dense)."""
    return pallas_gemm_batched(
        x, w, block=block, out_dtype=out_dtype, interpret=interpret
    )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    return _flash(
        q,
        k,
        v,
        causal=causal,
        window=window,
        sm_scale=sm_scale,
        block_q=block_q,
        block_kv=block_kv,
        interpret=interpret,
    )


def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    sm_scale: Optional[float] = None,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Single-token decode attention with per-batch valid-slot bounds.

    The TPU serving path calls this directly (the pjit'd serve_step uses
    the shardable masked-math fallback — the dry-run proves that form;
    this kernel is its device-optimal equivalent, one HBM pass over KV)."""
    return _flash_decode(
        q, k, v, lo, hi, sm_scale=sm_scale, block_kv=block_kv,
        interpret=interpret,
    )


def ssd_chunk_diag(
    x: jax.Array,
    dt_a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    return _ssd_chunk(x, dt_a, b, c, interpret=interpret)


# ---------------------------------------------------------------------------
# Lowering table: op name -> Pallas kernel entry point.
#
# The device half of the declarative registry (``repro.core.dispatch``): an
# :class:`OffloadOp` descriptor's ``pallas`` adapter fetches its kernel here
# by name, so the op table and the kernel table stay in one-to-one view and
# a new device kernel is wired up by adding one row.
# ---------------------------------------------------------------------------

PALLAS_LOWERINGS = {
    "gemm": gemm,
    "matmul": gemm,                  # leading dims collapse to GEMM m
    "gemm_batched": gemm_batched,
    "moe_gemm": moe_gemm,
    "attention": flash_attention,
    "decode_attention": flash_decode,
    "ssd_chunk_diag": ssd_chunk_diag,
    # Composite model-zoo descriptors: each fetches its core kernel here by
    # its own name, keeping the op registry and this table in one-to-one
    # view (the glue — bias adds, silu, the inter-chunk scan — lives in the
    # descriptor's pallas adapter in repro.core.blas).
    "qkv_project": gemm,             # concatenated-weight projection GEMM
    "ssd_scan": ssd_chunk_diag,      # within-chunk quadratic term
    "moe_expert_ffn": moe_gemm,      # gate/up/down grouped expert GEMMs
}


def pallas_lowering(name: str):
    try:
        return PALLAS_LOWERINGS[name]
    except KeyError:
        raise KeyError(
            f"no Pallas lowering for op {name!r}; have {sorted(PALLAS_LOWERINGS)}"
        ) from None
