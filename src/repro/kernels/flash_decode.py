"""Flash-decode: one query token against a long KV cache — Pallas TPU.

Decode attention is the serving hot loop: for every new token, each query
head streams the whole cache (memory-bound, arithmetic intensity ~1).  The
kernel keeps the (1, d) online-softmax state in VMEM scratch across the kv
grid dimension, so HBM traffic is exactly one pass over K and V — no
(1, S) score row ever round-trips.

Valid-slot semantics match the framework's decode caches
(`models/attention.py`): slots in [lo, hi) are attended; a rolling SWA
buffer passes lo=0, hi=cache_len, a partially-filled absolute cache passes
lo=max(0, count-window), hi=count.  Bounds are per-batch scalars
(prefetched, not masks), so ragged batches of requests share one kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["flash_decode"]

_NEG_INF = -1e30


def _decode_kernel(lo_ref, hi_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, n_kv: int, bkv: int,
                   sm_scale: float, group: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = lo_ref[b]
    hi = hi_ref[b]
    kv_lo = j * bkv
    live = jnp.logical_and(kv_lo < hi, kv_lo + bkv > lo)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        mask = jnp.logical_and(pos >= lo, pos < hi)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "block_kv", "interpret")
)
def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    sm_scale: float | None = None,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); lo, hi: (B,) int32 → (B, Hq, D)."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    bkv = min(block_kv, s)
    while s % bkv:
        bkv //= 2
    grid = (b, hq, s // bkv)

    kern = functools.partial(
        _decode_kernel, n_kv=grid[2], bkv=bkv, sm_scale=sm_scale, group=group
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lo (prefetch scalars)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # hi
            pl.BlockSpec((1, 1, 1, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec(
                (1, 1, bkv, d), lambda b_, h, j, g=group: (b_, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bkv, d), lambda b_, h, j, g=group: (b_, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lo, hi, q.reshape(b, hq, 1, d), k, v)[:, :, 0, :]
