"""Flash attention (causal / sliding-window, GQA) — Pallas TPU kernel.

Covers every attention variant in the assigned architectures:
  * full causal           (qwen2, yi, arctic, qwen3-moe, jamba attn layers…)
  * sliding-window        (h2o-danube SWA, gemma3 local layers)  — ``window``
  * bidirectional encoder (hubert)                               — ``causal=False``
  * GQA                   — kv heads indexed as ``q_head // group`` in the
                            BlockSpec index_map, so KV tiles are fetched once
                            per kv head, not per q head.

Memory discipline (the paper's SPM blocking at VMEM scale): the kernel never
materializes the (Sq, Skv) score matrix — only (bq, bkv) tiles live in VMEM,
with the online-softmax running state (m, l, acc) in fp32 VMEM scratch
persisted across the innermost (kv) grid dimension.  Fully-masked tiles are
skipped with ``pl.when`` (no MXU work; the DMA still streams, noted in §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["flash_attention", "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_KV"]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
_NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    n_kv: int,
    bq: int,
    bkv: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    sm_scale: float,
    skv_real: int,
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level bounds: skip tiles that are entirely masked.
    q_lo = i * bq + q_offset          # smallest query position in this tile
    q_hi = q_lo + bq - 1
    kv_lo = j * bkv
    kv_hi = kv_lo + bkv - 1
    live = kv_lo < skv_real  # tile of pure kv padding
    if causal:
        live = jnp.logical_and(live, kv_lo <= q_hi)
    if window is not None:
        live = jnp.logical_and(live, kv_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = kv_pos < skv_real  # kv padding never attended
        if causal:
            mask = jnp.logical_and(mask, kv_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - kv_pos < window)

        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (bq, bkv)
        corr = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)           # (bkv, d)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows -> 0
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "sm_scale",
        "block_q",
        "block_kv",
        "interpret",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.

    For Sq < Skv (decode / suffix prefill) queries are aligned to the *end*
    of the kv sequence (q position = Skv - Sq + row).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA mismatch: {hq} q heads vs {hkv} kv heads")
    if skv < sq:
        raise ValueError(f"kv shorter than q: {skv} < {sq}")
    group = hq // hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    q_offset = skv - sq

    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    pq, pkv = (-sq) % bq, (-skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        # Padded kv positions must never be attended: with causal=True they
        # sit beyond every real query position only if q is right-aligned;
        # enforce via an effective window over real positions instead.
    sqp, skvp = sq + pq, skv + pkv
    grid = (b, hq, sqp // bq, skvp // bkv)

    kern = functools.partial(
        _attn_kernel,
        n_kv=grid[3],
        bq=bq,
        bkv=bkv,
        causal=causal,
        window=window,
        q_offset=q_offset,
        sm_scale=sm_scale,
        skv_real=skv,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bkv, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bkv, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :, :sq, :]
    return out
