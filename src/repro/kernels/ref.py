"""Pure-jnp oracles for every Pallas kernel (the 'host kernels').

Each function is the semantic ground truth its kernel is tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes with assert_allclose), and
doubles as the XLA host path the dispatcher falls back to.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "gemm_ref",
    "gemm_batched_ref",
    "attention_ref",
    "ssd_chunk_diag_ref",
    "moe_gemm_ref",
]


def gemm_ref(a: jax.Array, b: jax.Array, *, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def gemm_batched_ref(a: jax.Array, b: jax.Array, *, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    return jax.lax.dot_general(
        a, b, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Masked softmax attention with GQA, fp32 softmax. Same semantics as
    ``flash_attention``: q aligned to the end of kv when Sq < Skv."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * sm_scale
    q_pos = (skv - sq) + jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all -1e30 is uniform; zero them like the kernel
    any_live = jnp.any(mask, axis=-1)[None, None, :, None]
    p = jnp.where(any_live, p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_chunk_diag_ref(
    x: jax.Array, dt_a: jax.Array, b: jax.Array, c: jax.Array
) -> jax.Array:
    """Y_diag = (L ∘ (C B^T)) X per (bh, chunk); L[i,j] = exp(Σa_i - Σa_j)·[j<=i]."""
    xf = x.astype(jnp.float32)
    af = dt_a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    s = jnp.einsum("zcqn,zckn->zcqk", cf, bf)
    q = x.shape[2]
    ii = jnp.arange(q)[:, None]
    jj = jnp.arange(q)[None, :]
    l_mask = jnp.where(
        jj <= ii, jnp.exp(af[..., :, None] - af[..., None, :]), 0.0
    )
    y = jnp.einsum("zcqk,zckp->zcqp", s * l_mask, xf)
    return y.astype(x.dtype)


def moe_gemm_ref(x: jax.Array, w: jax.Array, *, out_dtype=None) -> jax.Array:
    """(E, C, d) @ (E, d, f) — capacity-grouped expert GEMM."""
    return gemm_batched_ref(x, w, out_dtype=out_dtype)
