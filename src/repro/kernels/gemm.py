"""Tiled MXU GEMM — the paper's device kernel, re-blocked for TPU.

The paper's PMCA kernel DMA-refills 128 KiB of SPM and computes on 8 Snitch
cores.  The TPU analogue keeps the same discipline at VMEM scale: the grid
pipeline streams (bm, bk) / (bk, bn) tiles HBM->VMEM (hardware
double-buffering replaces the hand-written DMA), an fp32 VMEM scratch
accumulates across the k grid dimension (MXU accumulate semantics), and the
output tile is written once on the last k step.

Default tiles are MXU-aligned (multiples of 128); the working set
  bm*bk + bk*bn + bm*bn (fp32 scratch)
is sized well under VMEM so the pipeline can double-buffer.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["gemm_kernel", "pallas_gemm", "DEFAULT_BLOCK"]

DEFAULT_BLOCK: Tuple[int, int, int] = (128, 128, 128)  # (bm, bn, bk)


def gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, k_axis: int = 2):
    """One (bm, bn) output tile; accumulates over the k grid dimension."""
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU contraction with fp32 accumulation. Blocks may carry a leading
    # singleton batch dim (batched variant) — collapse it for the MXU.
    a = a_ref[...]
    b = b_ref[...]
    if a.ndim == 3:
        a, b = a[0], b[0]
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    acc_ref[...] += acc.reshape(acc_ref.shape)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def pallas_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C[m, n] = A[m, k] @ B[k, n] with explicit VMEM tiling.

    Operand dims are zero-padded up to tile multiples (the analogue of the
    paper's SPM blocking edge handling); the pad is sliced off the output.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"pallas_gemm: bad shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    bm, bn, bk = block

    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    mp, kp = a.shape
    _, np_ = b.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(gemm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
    if pm or pn:
        out = out[:m, :n]
    return out


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def pallas_gemm_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """(B, m, k) @ (B, k, n) — batch as the outermost (parallel) grid dim."""
    if a.ndim != 3 or b.ndim != 3 or a.shape[0] != b.shape[0]:
        raise ValueError(f"pallas_gemm_batched: bad shapes {a.shape} @ {b.shape}")
    bsz, m, k = a.shape
    _, _, n = b.shape
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    bm, bn, bk = block

    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, 0), (0, pk), (0, pn)))
    _, mp, kp = a.shape
    _, _, np_ = b.shape
    grid = (bsz, mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(gemm_kernel, n_k=grid[3], k_axis=3),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda bb, i, j, kk: (bb, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((1, bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
    if pm or pn:
        out = out[:, :m, :n]
    return out
