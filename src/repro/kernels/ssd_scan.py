"""Mamba-2 SSD (state-space duality) chunk kernel — Pallas TPU.

The SSD form turns the selective-SSM recurrence into *matmuls* over chunks —
the single best fit for a GEMM-offload paper: the "attention-like"
within-chunk term is

    Y_diag[c] = (L(c) ∘ (C_c @ B_c^T)) @ X_c            (per chunk c)

with L the causal decay mask built from cumulative log-decays.  The
inter-chunk state recurrence (tiny: (N, P) states) stays in a
``jax.lax.scan`` outside the kernel; this kernel computes the quadratic
within-chunk term for all chunks, one (batch*head, chunk) grid cell each,
entirely in VMEM.

Shapes (per head, already head-batched to BH = batch*heads):
  x     : (BH, C, Q, P)   chunked inputs  (Q = chunk len, P = head dim)
  dt_a  : (BH, C, Q)      cumulative log-decay within chunk (inclusive)
  b     : (BH, C, Q, N)   input  projection (state dim N)
  c     : (BH, C, Q, N)   output projection
  out   : (BH, C, Q, P)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["ssd_chunk_diag"]


def _ssd_chunk_kernel(x_ref, dta_ref, b_ref, c_ref, o_ref, *, q_len: int):
    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dta = dta_ref[0, 0].astype(jnp.float32)   # (Q,)  wait: block (1,1,Q)
    b = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    # scores: (Q, Q) = C @ B^T  (MXU)
    s = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    # decay mask L[i, j] = exp(dta_i - dta_j) for j <= i else 0
    di = dta[:, None]
    dj = dta[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    l_mask = jnp.where(jj <= ii, jnp.exp(di - dj), 0.0)
    y = jnp.dot(s * l_mask, x, preferred_element_type=jnp.float32)  # (Q, P)
    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_diag(
    x: jax.Array,
    dt_a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Within-chunk (diagonal-block) SSD term. See module docstring."""
    bh, nc, q, p = x.shape
    _, _, _, n = b.shape
    if dt_a.shape != (bh, nc, q):
        raise ValueError(f"dt_a shape {dt_a.shape} != {(bh, nc, q)}")
    grid = (bh, nc)
    kern = functools.partial(_ssd_chunk_kernel, q_len=q)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nc, q, p), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, dt_a, b, c)
