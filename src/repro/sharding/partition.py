"""Logical-axis partitioning rules: param/batch/cache pytrees → PartitionSpec.

Parallelism map (mesh axes: optional ``pod`` × ``data`` × ``model``):

  DP  — batch over (``pod``, ``data``); gradient psum inserted by GSPMD.
  TP  — Megatron col→row: qkv/up projections column-sharded over ``model``,
        o/down projections row-sharded; vocab/lm-head sharded over ``model``.
  EP  — MoE expert dim over ``model`` (every assigned MoE arch has ≥16
        experts); dispatch gather/scatter lowers to all-to-alls.
  SP  — long-context decode caches sequence-sharded (over ``model``, plus
        ``data`` when the batch can't use it), giving flash-decode style
        partial-softmax combines via GSPMD.

Rules are name-keyed (leaf names are unique across the zoo) with a
divisibility guard: a dim is only sharded if the mesh axis divides it
(e.g. mamba2's 50280 vocab stays replicated rather than force-padded).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_pspecs",
    "batch_pspecs",
    "opt_pspecs",
    "cache_pspecs",
    "named",
    "dp_axes",
]


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _guard(mesh: Mesh, dim: int, axes):
    """Shard ``dim`` over ``axes`` only if divisible; else replicate."""
    return axes if dim % _axis_size(mesh, axes) == 0 else None


# ---------------------------------------------------------------------------
# parameter rules (matched on the final dict key of the path)
# ---------------------------------------------------------------------------

_FSDP_MIN_ELEMS = 1 << 20


def _apply_fsdp(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-3 style: also shard the first free, divisible dim over 'data'.

    Giant models (arctic-480b: 60 GiB/dev params at TP-16 alone) cannot hold
    model-axis-only sharded params+moments in 16 GiB HBM; FSDP sharding over
    'data' brings params/dev to size/256, with GSPMD inserting the per-layer
    all-gathers inside the scan body (bounded working set)."""
    n = 1
    for d in shape:
        n *= d
    if n < _FSDP_MIN_ELEMS:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    dsz = mesh.shape["data"]
    for i, (dim, cur) in enumerate(zip(shape, entries)):
        if cur is None and dim % dsz == 0 and dim >= dsz:
            entries[i] = "data"
            return P(*entries)
    return spec


def _param_rule(name: str, shape: Tuple[int, ...], mesh: Mesh, fsdp: bool = False) -> P:
    nd = len(shape)
    m = "model"

    def spec(*tail):
        """Pad with leading Nones to the leaf's rank (stacked-layer axes)."""
        pad = (None,) * (nd - len(tail))
        out = P(*pad, *tail)
        return _apply_fsdp(out, shape, mesh) if fsdp else out

    if name == "embed":          # (V, D)
        out = P(_guard(mesh, shape[0], m), None)
        return _apply_fsdp(out, shape, mesh) if fsdp else out
    if name == "head":           # (D, V)
        out = P(None, _guard(mesh, shape[1], m))
        return _apply_fsdp(out, shape, mesh) if fsdp else out
    if name in ("we_gate", "we_up", "we_down"):
        # MoE expert weights (…, E, D, F): EP over the expert dim
        out = P(*((None,) * (nd - 3)), _guard(mesh, shape[-3], m), None, None)
        return _apply_fsdp(out, shape, mesh) if fsdp else out
    if name in ("wk", "wv", "bk", "bv"):
        # kv projections: replicated — every assigned GQA arch has fewer kv
        # heads than the model axis, and the TP attention block wants whole
        # kv heads per device (the weights are tiny).
        return spec(*((None,) * min(nd, 2)))
    if name in ("wq", "wz", "wx", "wdt", "w_gate", "w_up"):
        return spec(None, _guard(mesh, shape[-1], m))
    if name in ("bq", "b_up"):
        return spec(_guard(mesh, shape[-1], m))
    if name in ("wo", "w_down"):
        return spec(_guard(mesh, shape[-2], m), None)
    if name in ("b_down",):
        return spec(None)
    if name == "router":         # (…, D, E) — replicated (tiny, all-reduce-free)
        return spec(None, None)
    if name in ("dt_bias", "a_log", "d_skip"):
        return spec(_guard(mesh, shape[-1], m))
    # conv weights, norms, biases, everything else: replicated
    return P(*((None,) * nd))


def _path_leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def param_pspecs(param_shapes, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec tree matching a params (or eval_shape thereof) tree."""

    def rule(path, leaf):
        name = _path_leaf_name(path)
        return _param_rule(name, tuple(leaf.shape), mesh, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(rule, param_shapes)


# ---------------------------------------------------------------------------
# optimizer state
# ---------------------------------------------------------------------------

def opt_pspecs(opt_shapes, mesh: Mesh, *, fsdp: bool = False):
    """OptState: step replicated; mu/nu follow the param rules (QTensor
    int8 payloads keep the param spec; their 1-D scales are replicated)."""

    def rule(path, leaf):
        name = _path_leaf_name(path)
        nd = len(leaf.shape)
        if name == "step" or nd == 0:
            return P()
        # QTensor fields: path ends (…, 'wq', GetAttr('q'|'scale')).  Both
        # follow the parent param's rule — the int8 payload has the param's
        # shape and the scales are axis-aligned (last dim divided by the
        # quantization block), so leading sharded dims coincide.
        tail = path[-1]
        if isinstance(tail, (jax.tree_util.GetAttrKey,)) and str(
            getattr(tail, "name", "")
        ) in ("q", "scale"):
            name = _path_leaf_name(path[:-1])
        elif isinstance(tail, jax.tree_util.SequenceKey):
            name = _path_leaf_name(path[:-1])
        return _param_rule(name, tuple(leaf.shape), mesh, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(rule, opt_shapes)


# ---------------------------------------------------------------------------
# batch / cache
# ---------------------------------------------------------------------------

def batch_pspecs(batch_shapes, mesh: Mesh):
    dp = dp_axes(mesh)

    def rule(path, leaf):
        name = _path_leaf_name(path)
        shape = leaf.shape
        if name == "positions" and len(shape) == 3:  # (3, B, S)
            return P(None, _guard(mesh, shape[1], dp), None)
        if len(shape) >= 1:
            b_ax = _guard(mesh, shape[0], dp)
            return P(b_ax, *((None,) * (len(shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_pspecs(cache_shapes, mesh: Mesh):
    """Decode caches. KV: (L, B, Hkv, S, hd) — batch over DP when divisible,
    sequence over ``model`` (SP; partial-softmax decode), and over
    (``data``+``model``) when the batch is too small to use DP (long_500k).
    SSM state (L, B, H, N, P): heads over ``model``."""
    dp = dp_axes(mesh)

    def rule(path, leaf):
        name = _path_leaf_name(path)
        shape = leaf.shape
        if name in ("k", "v") and len(shape) == 5:
            b_ax = _guard(mesh, shape[1], dp)
            seq_axes = "model" if b_ax is not None else tuple(dp) + ("model",)
            return P(None, b_ax, None, _guard(mesh, shape[3], seq_axes), None)
        if name == "ssm" and len(shape) >= 5:
            # (L, [sub,] B, H, N, P): batch over DP, heads over model
            nd = len(shape)
            out = [None] * nd
            h_idx, b_idx = nd - 3, nd - 4
            out[b_idx] = _guard(mesh, shape[b_idx], dp)
            out[h_idx] = _guard(mesh, shape[h_idx], "model")
            return P(*out)
        if name == "conv" and len(shape) >= 3:
            return P(*((None,) * len(shape)))
        return P(*((None,) * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def named(mesh: Mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
