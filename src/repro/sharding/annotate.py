"""Mesh-aware sharding constraints usable from mesh-agnostic model code.

``constrain(x, "dp", None, "model")`` applies a
``with_sharding_constraint`` iff tracing happens under an active Mesh
context; otherwise (single-device tests, local runs) it is the identity.
The "dp" token expands to whichever data-parallel axes the ambient mesh
has (("pod","data") on the multi-pod mesh, ("data",) on one pod), so model
code never hard-codes mesh topology.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["constrain"]


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def _expand(token, mesh) -> Optional[Tuple[str, ...]]:
    if token is None:
        return None
    if token == "dp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes or None
    if isinstance(token, str):
        return token if token in mesh.axis_names else None
    return token


def constrain(x: jax.Array, *spec_tokens):
    """Best-effort sharding constraint; identity without an active mesh."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = P(*(_expand(t, mesh) for t in spec_tokens))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
