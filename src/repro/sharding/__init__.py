"""repro.sharding — logical-axis partitioning rules."""

from repro.sharding.partition import (
    batch_pspecs,
    cache_pspecs,
    dp_axes,
    named,
    opt_pspecs,
    param_pspecs,
)

__all__ = [
    "batch_pspecs",
    "cache_pspecs",
    "dp_axes",
    "named",
    "opt_pspecs",
    "param_pspecs",
]
