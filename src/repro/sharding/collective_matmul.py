"""Collective matmul: overlap the TP all-gather with partial matmuls.

The dense-TP roofline (EXPERIMENTS §Perf, cell 2) is bound by the per-block
activation exchange.  A bytes-based roofline cannot show *overlap*, but on
hardware the classic fix is the ring collective matmul (Wang et al.,
"Overlap communication with dependent computation", ASPLOS'23): instead of

    x_full = all_gather(x_shard);  y = x_full @ w_shard

each of the N steps multiplies the chunk currently held while
``ppermute``-ing the next one around the ring — the interconnect streams
while the MXU works, hiding all but one chunk's latency.

``ring_ag_matmul`` computes y_local = x_full @ w_local with x arriving
sequence/contraction-sharded, exactly the all-gather + matmul pair at the
entry of a column-parallel block.  Used by the TP blocks when
``REPRO_RING_MATMUL=1`` (kept opt-in: on the CPU emulation backend it only
adds loop overhead; the dry-run proves it lowers and partitions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

__all__ = ["ring_ag_matmul"]


def ring_ag_matmul(x_shard: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = concat_ring(x_shard) @ w, overlapping the gather with compute.

    x_shard: (B, S/N, D) — this device's contraction/sequence shard;
    w:       (D, F_loc) — this device's weight slice (any column shard);
    returns  (B, S, F_loc) with rows ordered by source device.

    Must be called inside shard_map with ``axis`` manual.
    """
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]  # ring

    def dot(u):
        return jax.lax.dot_general(
            u, w, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(u.dtype)

    def step(carry, t):
        chunk = carry
        y_t = dot(chunk)                       # compute on what we hold...
        nxt = jax.lax.ppermute(chunk, axis, perm)  # ...while the ring moves
        # chunk at tick t originated at device (idx - t) mod n
        src = jnp.mod(idx - t, n)
        return nxt, (y_t, src)

    _, (ys, srcs) = jax.lax.scan(step, x_shard, jnp.arange(n))
    # reorder ticks into source order: out[src[t]] = ys[t]
    order = jnp.argsort(srcs)
    ys = jnp.take(ys, order, axis=0)           # (N, B, S/N, F_loc)
    nb, b, sl, f = ys.shape
    return ys.transpose(1, 0, 2, 3).reshape(b, nb * sl, f)
