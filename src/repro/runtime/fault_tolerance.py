"""Fault-tolerance runtime: failure detection, restart, straggler mitigation.

On a real multi-pod deployment each host runs this supervisor around the
training loop.  The pieces (all exercised by tests with injected faults):

  * **Heartbeats / failure detection** — ``HeartbeatMonitor`` tracks
    per-host last-seen times; a host silent for > ``timeout_s`` is declared
    failed.  (In-process simulation: the test advances a fake clock.)
  * **Restart-from-checkpoint** — ``run_with_recovery`` wraps the step loop;
    any step raising ``WorkerFailure`` rolls back to the latest checkpoint
    and replays.  Because the data pipeline is (seed, step)-pure and the
    train step is deterministic, recovery is *bitwise* identical to a run
    without the failure (asserted in tests).
  * **Straggler mitigation** — ``StragglerMonitor`` keeps a ring buffer of
    per-step durations per host; hosts slower than ``threshold`` × median
    over a window are flagged, and the policy hook decides (log / evict →
    elastic re-shard at the next checkpoint boundary).
  * **Device loss (cluster)** — ``ClusterSupervisor`` watches the
    :class:`~repro.core.hero.HeroCluster` through per-device heartbeats; a
    silent device is declared lost, its residency ledger evicted and its
    in-flight launches rescheduled onto survivors through the cluster's
    active scheduler.  Pinned :class:`~repro.core.hero.DeviceHandle` s homed
    on the lost device (KV caches, resident weights) become unstaged — their
    bytes exist only in host DRAM again — and the supervisor re-stages them
    onto scheduler-picked survivors, charging the full host->device copy
    region on the new lane (the d2d path needs a live source).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.hero import HeroCluster, LaunchTicket

__all__ = [
    "WorkerFailure",
    "HeartbeatMonitor",
    "StragglerMonitor",
    "ClusterSupervisor",
    "DeviceLossEvent",
    "run_with_recovery",
]


class WorkerFailure(RuntimeError):
    """A (possibly injected) worker/pod failure observed during a step."""


@dataclasses.dataclass
class HeartbeatMonitor:
    num_hosts: int
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self._last: Dict[int, float] = {h: now for h in range(self.num_hosts)}

    def beat(self, host: int) -> None:
        self._last[host] = self.clock()

    def failed_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, t in self._last.items() if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.failed_hosts()


@dataclasses.dataclass
class StragglerMonitor:
    num_hosts: int
    window: int = 16
    threshold: float = 1.8

    def __post_init__(self):
        self._times: Dict[int, deque] = {
            h: deque(maxlen=self.window) for h in range(self.num_hosts)
        }

    def record(self, host: int, step_s: float) -> None:
        self._times[host].append(step_s)

    def medians(self) -> Dict[int, float]:
        out = {}
        for h, dq in self._times.items():
            if dq:
                s = sorted(dq)
                out[h] = s[len(s) // 2]
        return out

    def stragglers(self) -> List[int]:
        med = self.medians()
        if len(med) < 2:
            return []
        global_median = sorted(med.values())[len(med) // 2]
        if global_median <= 0:
            return []
        return [h for h, m in med.items() if m > self.threshold * global_median]


@dataclasses.dataclass(frozen=True)
class DeviceLossEvent:
    """One observed device loss and where its work went."""

    device_id: int
    rescheduled: Tuple[Tuple[LaunchTicket, int], ...]  # (ticket, new device)
    evicted_buffers: Tuple[str, ...]
    # True when no survivor existed: in-flight work was dropped, not moved.
    total_loss: bool = False
    # Pinned handles that were homed on the lost device (now unstaged) ...
    unstaged_handles: Tuple[str, ...] = ()
    # ... and where each was re-staged: (handle name, new device id).
    restaged: Tuple[Tuple[str, int], ...] = ()


@dataclasses.dataclass
class ClusterSupervisor:
    """Device-level failure handling for a :class:`HeroCluster`.

    The host heartbeats each virtual PMCA (on real HW: the mailbox/doorbell
    the HeroSDK runtime already polls).  A device silent past ``timeout_s``
    is failed: residency evicted, queue rescheduled, event logged.  A later
    ``recover(device_id)`` brings the device back cold — its ledger stays
    empty until callers re-pin buffers, so the cost model charges the copy
    region again, exactly what re-staging after a reset costs.
    """

    cluster: HeroCluster
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self._last: Dict[int, float] = {
            d.device_id: now for d in self.cluster.devices
        }
        self.events: List[DeviceLossEvent] = []

    def beat(self, device_id: int) -> None:
        self._last[device_id] = self.clock()

    def silent_devices(self) -> List[int]:
        now = self.clock()
        return [
            d.device_id
            for d in self.cluster.alive_devices()
            if now - self._last.get(d.device_id, now) > self.timeout_s
        ]

    def fail_device(self, device_id: int) -> DeviceLossEvent:
        """Declare one device lost: evict + reschedule, return the event.

        Losing the *last* device is still recorded (``total_loss=True``,
        in-flight work dropped) rather than raised — the supervisor's job
        is to report every loss, not to die partway through a sweep.

        Handles pinned to the lost device come back unstaged from
        ``cluster.fail_device``; the supervisor immediately re-stages each
        onto a scheduler-picked survivor (full host copy charged on the new
        lane), so the caches survive the loss with their cost paid visibly.
        """
        dev = self.cluster.device(device_id)
        evicted = tuple(sorted(dev.resident))
        lost_handles = tuple(
            sorted(h.name for h in self.cluster.handles_on(device_id))
        )
        try:
            moved = self.cluster.fail_device(device_id)
            total_loss = False
        except RuntimeError:  # no reschedule target: whole cluster is down
            dev.fail()
            moved = []
            total_loss = True
            for name in lost_handles:  # unstaged, nowhere to re-stage
                h = self.cluster.handle(name)
                if h is not None:
                    self.cluster.unstage_handle(h)
        restaged = []
        if not total_loss:
            for name in lost_handles:
                h = self.cluster.handle(name)
                if h is not None and not h.valid:
                    self.cluster.restage_handle(h)
                    restaged.append((name, h.device_id))
        ev = DeviceLossEvent(
            device_id=device_id,
            rescheduled=tuple(moved),
            evicted_buffers=evicted,
            total_loss=total_loss,
            unstaged_handles=lost_handles,
            restaged=tuple(restaged),
        )
        self.events.append(ev)
        return ev

    def poll(self) -> List[DeviceLossEvent]:
        """Fail every heartbeat-silent device; returns the new events."""
        return [self.fail_device(d) for d in self.silent_devices()]

    def recover(self, device_id: int) -> None:
        self.cluster.restore_device(device_id)
        self._last[device_id] = self.clock()

    def resync(self) -> None:
        """Re-key the heartbeat table to the cluster's current topology
        (elastic resize at a checkpoint boundary adds/removes devices)."""
        now = self.clock()
        current = {d.device_id for d in self.cluster.devices}
        self._last = {
            i: self._last.get(i, now) for i in sorted(current)
        }


def run_with_recovery(
    *,
    num_steps: int,
    start_step: int,
    step_fn: Callable[[int], Tuple[object, float]],
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    checkpoint_every: int = 10,
    max_restarts: int = 5,
):
    """Drive the step loop with checkpoint/restart semantics.

    ``step_fn(step) -> (metrics, step_seconds)`` may raise WorkerFailure.
    ``restore_fn() -> step`` rolls state back and returns the resume step.
    Returns (final_step, metrics_log, num_restarts)."""
    log: List[object] = []
    restarts = 0
    step = start_step
    while step < num_steps:
        try:
            metrics, _dur = step_fn(step)
            log.append((step, metrics))
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step)
        except WorkerFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn()
    return step, log, restarts
