"""Fault-tolerance runtime: failure detection, restart, straggler mitigation.

On a real multi-pod deployment each host runs this supervisor around the
training loop.  The pieces (all exercised by tests with injected faults):

  * **Heartbeats / failure detection** — ``HeartbeatMonitor`` tracks
    per-host last-seen times; a host silent for > ``timeout_s`` is declared
    failed.  (In-process simulation: the test advances a fake clock.)
  * **Restart-from-checkpoint** — ``run_with_recovery`` wraps the step loop;
    any step raising ``WorkerFailure`` rolls back to the latest checkpoint
    and replays.  Because the data pipeline is (seed, step)-pure and the
    train step is deterministic, recovery is *bitwise* identical to a run
    without the failure (asserted in tests).
  * **Straggler mitigation** — ``StragglerMonitor`` keeps a ring buffer of
    per-step durations per host; hosts slower than ``threshold`` × median
    over a window are flagged, and the policy hook decides (log / evict →
    elastic re-shard at the next checkpoint boundary).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "WorkerFailure",
    "HeartbeatMonitor",
    "StragglerMonitor",
    "run_with_recovery",
]


class WorkerFailure(RuntimeError):
    """A (possibly injected) worker/pod failure observed during a step."""


@dataclasses.dataclass
class HeartbeatMonitor:
    num_hosts: int
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self._last: Dict[int, float] = {h: now for h in range(self.num_hosts)}

    def beat(self, host: int) -> None:
        self._last[host] = self.clock()

    def failed_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, t in self._last.items() if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.failed_hosts()


@dataclasses.dataclass
class StragglerMonitor:
    num_hosts: int
    window: int = 16
    threshold: float = 1.8

    def __post_init__(self):
        self._times: Dict[int, deque] = {
            h: deque(maxlen=self.window) for h in range(self.num_hosts)
        }

    def record(self, host: int, step_s: float) -> None:
        self._times[host].append(step_s)

    def medians(self) -> Dict[int, float]:
        out = {}
        for h, dq in self._times.items():
            if dq:
                s = sorted(dq)
                out[h] = s[len(s) // 2]
        return out

    def stragglers(self) -> List[int]:
        med = self.medians()
        if len(med) < 2:
            return []
        global_median = sorted(med.values())[len(med) // 2]
        if global_median <= 0:
            return []
        return [h for h, m in med.items() if m > self.threshold * global_median]


def run_with_recovery(
    *,
    num_steps: int,
    start_step: int,
    step_fn: Callable[[int], Tuple[object, float]],
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    checkpoint_every: int = 10,
    max_restarts: int = 5,
):
    """Drive the step loop with checkpoint/restart semantics.

    ``step_fn(step) -> (metrics, step_seconds)`` may raise WorkerFailure.
    ``restore_fn() -> step`` rolls state back and returns the resume step.
    Returns (final_step, metrics_log, num_restarts)."""
    log: List[object] = []
    restarts = 0
    step = start_step
    while step < num_steps:
        try:
            metrics, _dur = step_fn(step)
            log.append((step, metrics))
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step)
        except WorkerFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn()
    return step, log, restarts
