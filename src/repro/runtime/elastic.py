"""Elastic re-scaling: restore any checkpoint onto any mesh, and grow or
shrink the offload cluster at checkpoint boundaries.

Checkpoints store full logical arrays (see repro.checkpoint), so scaling a
job from N to M pods is: build the new mesh, recompute PartitionSpecs for
the same param tree, and ``restore(..., shardings=named(new_mesh, specs))``.
No resharding pass over the checkpoint data is needed — device_put places
each host's slice directly.

``replan`` also rescales the data-parallel batch splitting: the global
batch is invariant; hosts' local batches change.

``resize_cluster`` is the PMCA-cluster half of the same story: at a
checkpoint boundary the :class:`~repro.core.hero.HeroCluster` grows by
appending cold devices or shrinks by draining the removed lanes —
in-flight launches reschedule through the active scheduler and pinned
:class:`~repro.core.hero.DeviceHandle` s homed on removed devices are
re-staged onto keepers over the same host-copy path the
:class:`~repro.runtime.fault_tolerance.ClusterSupervisor` uses on device
loss (every move recorded on the new lane's trace).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro.sharding import batch_pspecs, named, opt_pspecs, param_pspecs

__all__ = ["ElasticPlan", "ResizeEvent", "replan", "resize_cluster"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh: object
    param_shardings: object
    opt_shardings: Optional[object]
    global_batch: int
    local_batch: int
    num_hosts: int


def replan(
    mesh,
    param_shapes,
    opt_shapes=None,
    *,
    global_batch: int,
    num_hosts: int,
) -> ElasticPlan:
    if global_batch % num_hosts:
        raise ValueError(
            f"global batch {global_batch} not divisible by {num_hosts} hosts"
        )
    p_shard = named(mesh, param_pspecs(param_shapes, mesh))
    o_shard = (
        named(mesh, opt_pspecs(opt_shapes, mesh)) if opt_shapes is not None else None
    )
    return ElasticPlan(
        mesh=mesh,
        param_shardings=p_shard,
        opt_shardings=o_shard,
        global_batch=global_batch,
        local_batch=global_batch // num_hosts,
        num_hosts=num_hosts,
    )


@dataclasses.dataclass(frozen=True)
class ResizeEvent:
    """One cluster grow/shrink at a checkpoint boundary."""

    before: int
    after: int
    # Handles re-staged off removed devices: (handle name, new device id).
    restaged: Tuple[Tuple[str, int], ...] = ()


def resize_cluster(cluster, num_devices: int, *, supervisor=None) -> ResizeEvent:
    """Grow/shrink a :class:`HeroCluster` at a checkpoint boundary.

    Thin policy wrapper over :meth:`HeroCluster.resize`: grow appends cold
    devices (existing queues, residency and pinned handles untouched);
    shrink reschedules the removed lanes' in-flight work and re-stages
    their pinned handles onto keepers via the existing supervisor path.
    Pass the watching :class:`ClusterSupervisor` so its heartbeat table
    follows the new topology.
    """
    before = cluster.num_devices
    moves = cluster.resize(num_devices)
    if supervisor is not None:
        supervisor.resync()
    return ResizeEvent(
        before=before, after=cluster.num_devices, restaged=tuple(moves)
    )
