"""Elastic re-scaling: restore any checkpoint onto any mesh.

Checkpoints store full logical arrays (see repro.checkpoint), so scaling a
job from N to M pods is: build the new mesh, recompute PartitionSpecs for
the same param tree, and ``restore(..., shardings=named(new_mesh, specs))``.
No resharding pass over the checkpoint data is needed — device_put places
each host's slice directly.

``replan`` also rescales the data-parallel batch splitting: the global
batch is invariant; hosts' local batches change.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro.sharding import batch_pspecs, named, opt_pspecs, param_pspecs

__all__ = ["ElasticPlan", "replan"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh: object
    param_shardings: object
    opt_shardings: Optional[object]
    global_batch: int
    local_batch: int
    num_hosts: int


def replan(
    mesh,
    param_shapes,
    opt_shapes=None,
    *,
    global_batch: int,
    num_hosts: int,
) -> ElasticPlan:
    if global_batch % num_hosts:
        raise ValueError(
            f"global batch {global_batch} not divisible by {num_hosts} hosts"
        )
    p_shard = named(mesh, param_pspecs(param_shapes, mesh))
    o_shard = (
        named(mesh, opt_pspecs(opt_shapes, mesh)) if opt_shapes is not None else None
    )
    return ElasticPlan(
        mesh=mesh,
        param_shardings=p_shard,
        opt_shardings=o_shard,
        global_batch=global_batch,
        local_batch=global_batch // num_hosts,
        num_hosts=num_hosts,
    )
