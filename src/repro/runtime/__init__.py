"""repro.runtime — fault tolerance, stragglers, elastic scaling."""

from repro.runtime.elastic import ElasticPlan, replan
from repro.runtime.fault_tolerance import (
    ClusterSupervisor,
    DeviceLossEvent,
    HeartbeatMonitor,
    StragglerMonitor,
    WorkerFailure,
    run_with_recovery,
)

__all__ = [
    "ClusterSupervisor",
    "DeviceLossEvent",
    "ElasticPlan",
    "replan",
    "HeartbeatMonitor",
    "StragglerMonitor",
    "WorkerFailure",
    "run_with_recovery",
]
