"""repro.runtime — fault tolerance, stragglers, elastic scaling."""

from repro.runtime.elastic import ElasticPlan, ResizeEvent, replan, resize_cluster
from repro.runtime.fault_tolerance import (
    ClusterSupervisor,
    DeviceLossEvent,
    HeartbeatMonitor,
    StragglerMonitor,
    WorkerFailure,
    run_with_recovery,
)

__all__ = [
    "ClusterSupervisor",
    "DeviceLossEvent",
    "ElasticPlan",
    "ResizeEvent",
    "replan",
    "resize_cluster",
    "HeartbeatMonitor",
    "StragglerMonitor",
    "WorkerFailure",
    "run_with_recovery",
]
