"""Hardware platform models for the heterogeneous BLAS offload substrate.

The paper targets an FPGA-emulated RISC-V heSoC (CVA6 host + 8-core Snitch
PMCA).  We model that platform analytically — calibrated to the paper's three
published anchors — and the TPU v5e target the framework actually runs on.

Calibration of ``HESOC_VCU128`` (see DESIGN.md §2):

  Anchors from the paper, all at n=128, float64 GEMM:
    (a) offload speedup  T_host / T_offload            = 2.71x
    (b) copy fraction    T_copy / T_offload            = 0.47
    (c) zero-copy projection: replacing the copy with IO-PTE creation
        (measured 7.5x faster than copying) brings total speedup to ~4.7x.
        With (a) and (b) exactly satisfied the model projects
        2.71 / (1 - 0.47 + 0.47/7.5) = 4.57x — the paper's 4.7x is the
        same quantity under rounding; tests assert within tolerance.

  Remaining free constants are set to plausible values for a 50 MHz
  FPGA-emulated SoC:
    host_flops   = 25 MFLOP/s  (CVA6 fpnew, ~0.5 flop/cycle @ 50 MHz)
      -> T_host(128)    = 2*128^3 / 25e6            = 167.8 ms
      -> T_offload(128) = T_host / 2.71             =  61.9 ms
      -> T_copy(128)    = 0.47 * T_offload          =  29.1 ms
         bytes(128)     = 3 * 128^2 * 8             = 393 216 B
         copy_bw        = bytes / T_copy            ~ 13.5 MB/s
         (memcpy into the uncached, manually-managed device-DRAM
          partition through Linux on a 50 MHz in-order core)
    fork_join_s  = 10% of offload time at n=128     ~ 6.19 ms
         (OpenMP target enter/exit + Hero kernel-module ioctls)
      -> T_compute(128) = remaining 43%             =  26.6 ms
         dev_flops      = 2*128^3 / T_compute       ~ 157.5 MFLOP/s
         (20% of the 800 MFLOP/s Snitch-cluster peak at 50 MHz —
          DMA-refill bound at these small tiles, per the paper's
          "compute = DMA copies local data and processes in SPM")
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "Platform",
    "HESOC_VCU128",
    "TPU_V5E",
    "CPU_HOST",
    "get_platform",
]


@dataclasses.dataclass(frozen=True)
class Platform:
    """Analytic description of a host + accelerator pair.

    Times are modeled with the paper's three-region decomposition:

      T_offload = T_copy(bytes) + T_fork_join + T_compute(flops, bytes)
      T_host    = flops / host_flops

    ``dev_flops``/``dev_mem_bw`` bound compute by whichever is slower
    (roofline); ``copy_bw`` charges host<->device staging for non-resident
    buffers; ``fork_join_s`` is the constant launch/teardown overhead.
    """

    name: str
    # Host (scalar) execution rate, FLOP/s.
    host_flops: float
    # Device peak compute, FLOP/s (per chip for TPU).
    dev_flops: float
    # Device local/main memory bandwidth, B/s (HBM for TPU, SPM-DMA for heSoC).
    dev_mem_bw: float
    # Host <-> device staging bandwidth, B/s (device-DRAM memcpy / PCIe).
    copy_bw: float
    # Constant per-offload overhead, seconds (OpenMP fork/join, kernel launch).
    fork_join_s: float
    # Local scratch memory per compute unit, bytes (SPM / VMEM).
    local_mem_bytes: int
    # Inter-chip interconnect bandwidth per link, B/s (TPU ICI); 0 if N/A.
    ici_bw: float = 0.0
    # Zero-copy staging speedup (paper: IO-PTE creation 7.5x faster than copy).
    zero_copy_speedup: float = 7.5
    # Number of chips (for pod-level roofline math).
    chips: int = 1
    # Device-to-device copy bandwidth, B/s (ticket/cache migration between
    # PMCAs).  0 means "fall back": ICI if present, else staging through the
    # host at copy_bw (the heSoC has no direct PMCA-to-PMCA path).
    d2d_bw: float = 0.0
    # Natural DMA staging-chunk size for double-buffered (pipelined) h2d
    # transfers, bytes.  Half the local scratch is the classic bound (one
    # buffer computes while the other refills); 0 disables chunked staging
    # (single-chunk transfers — e.g. the CPU "device" shares the host
    # address space, there is nothing to overlap).
    dma_chunk_bytes: int = 0

    # ---- region models -------------------------------------------------
    def t_host(self, flops: float) -> float:
        return flops / self.host_flops

    def t_copy(self, bytes_moved: float, *, zero_copy: bool = False) -> float:
        t = bytes_moved / self.copy_bw
        if zero_copy:
            t = t / self.zero_copy_speedup
        return t

    def t_fork_join(self) -> float:
        return self.fork_join_s

    def t_d2d(self, bytes_moved: float) -> float:
        """Device-to-device transfer time for a migrating resident buffer."""
        bw = self.d2d_bw or self.ici_bw
        if bw <= 0:
            # no direct link: bounce through host staging, paying both hops
            return 2.0 * bytes_moved / self.copy_bw
        return bytes_moved / bw

    def t_compute(self, flops: float, bytes_touched: float) -> float:
        """Device compute region under a two-term roofline."""
        return max(flops / self.dev_flops, bytes_touched / self.dev_mem_bw)

    def t_offload(
        self,
        flops: float,
        staged_bytes: float,
        touched_bytes: float,
        *,
        zero_copy: bool = False,
    ) -> float:
        return (
            self.t_copy(staged_bytes, zero_copy=zero_copy)
            + self.t_fork_join()
            + self.t_compute(flops, touched_bytes)
        )


# --------------------------------------------------------------------------
# The paper's platform: CVA6 host + 8x Snitch PMCA on a Xilinx VCU128.
# Constants derived from the paper's anchors — see module docstring.
# --------------------------------------------------------------------------
_N = 128
_FLOPS_128 = 2.0 * _N**3               # 4_194_304
_BYTES_128 = 3.0 * _N**2 * 8           # A, B in + C out, float64
_T_HOST_128 = _FLOPS_128 / 25.0e6      # 167.77 ms
_T_OFF_128 = _T_HOST_128 / 2.71        # 61.91 ms
_T_COPY_128 = 0.47 * _T_OFF_128        # 29.10 ms
_T_FORK = 0.10 * _T_OFF_128            # 6.19 ms
_T_COMP_128 = _T_OFF_128 - _T_COPY_128 - _T_FORK

HESOC_VCU128 = Platform(
    name="hesoc-vcu128",
    host_flops=25.0e6,
    dev_flops=_FLOPS_128 / _T_COMP_128,          # ~157.5 MFLOP/s effective
    dev_mem_bw=64.0e6,                           # DMA SPM refill; not binding @128
    copy_bw=_BYTES_128 / _T_COPY_128,            # ~13.5 MB/s
    fork_join_s=_T_FORK,
    local_mem_bytes=128 * 1024,                  # 128 KiB SPM
    zero_copy_speedup=7.5,
    dma_chunk_bytes=64 * 1024,                   # SPM/2 double-buffer halves
)

# --------------------------------------------------------------------------
# TPU v5e — the framework's real target (per-chip numbers).
# --------------------------------------------------------------------------
TPU_V5E = Platform(
    name="tpu-v5e",
    host_flops=2.0e11,            # XLA:CPU host fallback ballpark (not used for scoring)
    dev_flops=197.0e12,           # bf16 MXU peak
    dev_mem_bw=819.0e9,           # HBM
    copy_bw=32.0e9,               # PCIe gen4 x16 host->HBM staging
    fork_join_s=3.0e-6,           # fused-graph launch overhead
    local_mem_bytes=128 * 1024 * 1024,   # VMEM
    ici_bw=50.0e9,                # per link
    zero_copy_speedup=1.0e9,      # resident buffers: staging cost ~ 0
    d2d_bw=50.0e9,                # cache migration rides the ICI
    dma_chunk_bytes=4 * 1024 * 1024,   # Pallas-pipeline tile granularity
)

# CPU host-only platform (this container) — used for interpret-mode runs.
CPU_HOST = Platform(
    name="cpu-host",
    host_flops=5.0e9,
    dev_flops=5.0e9,
    dev_mem_bw=20.0e9,
    copy_bw=1.0e12,               # same address space
    fork_join_s=0.0,
    local_mem_bytes=32 * 1024 * 1024,
)

_REGISTRY = {p.name: p for p in (HESOC_VCU128, TPU_V5E, CPU_HOST)}


def get_platform(name: str) -> Platform:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown platform {name!r}; known: {sorted(_REGISTRY)}"
        ) from None
