"""Dynamic expert placement — migrate/replicate hot experts under skew.

The grouped MoE FFN used to run behind one static shard_map plan: expert
``e`` lives on lane ``e * L // E`` forever, so a Zipfian router serializes
the whole step on whichever lane owns the hot experts (the head of a
s=1.2 popularity curve puts ~70% of all routed tokens on one of four
lanes).  This module makes placement a *policy*, not a layout constant:

* each expert's weight triple (``we_gate``/``we_up``/``we_down``) is a
  first-class :class:`~repro.core.hero.DeviceHandle` homed on a lane
  (:meth:`ExpertPlacementPolicy.attach` pins the contiguous-block layout
  the static shard_map plan implies);
* the route/pack stage surfaces a per-expert token histogram, and
  :meth:`ExpertPlacementPolicy.step` folds it into a rolling (EMA) token
  share per expert with enter/exit hysteresis on the "hot" state;
* a hot expert **migrates** d2d to the lane that most reduces the modeled
  per-step makespan — but only when the move amortizes: the projected
  saving over ``amortize_steps`` steps must exceed the
  :func:`~repro.core.cost_model.d2d_breakdown` cost of moving its bytes
  (charged for real on the destination lane's DMA stream clock);
* a *persistently* hot expert **replicates** onto a second lane
  (:meth:`~repro.core.hero.HeroCluster.replicate_handle`), and
  :meth:`ExpertPlacementPolicy.plan` splits its tokens across the copies;
* capacity factors + token dropping are explicit knobs — every dropped
  token copy is counted (``moe.tokens_dropped{expert=}``), never silently
  lost: ``tokens_routed == tokens_processed + tokens_dropped`` by
  construction.

:meth:`plan` compiles one step's histogram into an
:class:`ExpertDispatchPlan` — the per-expert, handle-affine sub-launch
fan-out that ``dispatch_placed(..., placement=plan)`` executes under one
dispatch graph (the math lowering is untouched; only the accounting fans
out, so the placed path is bitwise-equal to the static one).

Everything here is modeled-time and deterministic: decisions are pure
arithmetic over the histogram stream, and the only randomness is the
caller's seeded :class:`random.Random` feeding :func:`zipf_histogram`.
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost_model import OpCost, d2d_breakdown, gemm_cost
from repro.core.hero import (
    DeviceHandle,
    HeroCluster,
    LaunchTicket,
    engine,
    offload_policy,
)
from repro.obs import metrics as _metrics
from repro.obs import spans as _spans

__all__ = [
    "ExpertDispatchPlan",
    "ExpertPlacementPolicy",
    "MigrationEdge",
    "PlacedSubLaunch",
    "PlacementConfig",
    "PlacementDecision",
    "SkewedRunResult",
    "placement_sweep",
    "run_skewed_workload",
    "zipf_histogram",
    "zipf_shares",
]


# ---------------------------------------------------------------------------
# Plan / decision records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacedSubLaunch:
    """One expert's share of a grouped-FFN step, placed on one lane.

    ``shape_key`` is the expert-weight handle name on that lane, so the
    ticket keys the residency ledger exactly like every handle-affine
    launch; ``resident_fraction`` is the weight bytes' share of the staged
    operand set (the activations still ride the DMA stream)."""

    expert: int
    device_id: int
    tokens: int
    cost: OpCost
    shape_key: str
    resident_fraction: float


@dataclasses.dataclass(frozen=True)
class ExpertDispatchPlan:
    """Per-expert placed fan-out for one grouped-FFN dispatch.

    Conservation is structural: ``tokens_routed == tokens_processed +
    tokens_dropped`` (the bench gate asserts zero unaccounted drops)."""

    sub_launches: Tuple[PlacedSubLaunch, ...]
    tokens_routed: int
    tokens_processed: int
    tokens_dropped: int
    dropped_by_expert: Tuple[int, ...]
    capacity: int  # per expert copy per step (0 = unbounded)


@dataclasses.dataclass(frozen=True)
class MigrationEdge:
    """Happens-before witness for one expert-weight d2d migration.

    ``src_drain_s`` is the latest modeled completion of a source-lane
    launch still reading the handle when the move was decided; the
    migration ticket may not issue before it.  Duck-typed for
    ``repro.analysis.races.check_expert_migrations`` (the
    ``race/expert-migrate-before-drain`` rule) so the import-light
    analysis pass never has to import this module."""

    expert: int
    handle_name: str
    src_device: int
    dst_device: int
    migrate_issue_s: float
    src_drain_s: float


@dataclasses.dataclass
class PlacementDecision:
    """One executed placement action (``kind`` is "migrate"/"replicate")."""

    step: int
    kind: str
    expert: int
    src_device: int
    dst_device: int
    d2d_s: float
    share: float
    ticket: Optional[LaunchTicket] = dataclasses.field(
        default=None, compare=False, repr=False)

    @property
    def key(self) -> tuple:
        """Comparable identity (same-seed runs must produce equal keys)."""
        return (self.step, self.kind, self.expert,
                self.src_device, self.dst_device)


# ---------------------------------------------------------------------------
# Policy configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlacementConfig:
    """Knobs of the dynamic-placement policy.

    Hotness thresholds are multiples of the fair share ``1/E`` with
    enter/exit hysteresis: an expert turns hot at ``hot_enter_x / E`` and
    only cools below ``hot_exit_x / E``, so a share oscillating between
    the two never flaps (and never re-triggers the rising-edge migration
    check — the no-ping-pong property the tests pin)."""

    num_experts: int = 16
    # Modeled expert dims default to a realistic MoE block (Mixtral-class):
    # per-token staging/compute must dominate the per-launch fork/join
    # overhead or lane makespan stops tracking token load entirely.
    d_model: int = 2048
    d_ff: int = 5632
    itemsize: int = 2               # weight dtype bytes (bf16)
    enabled: bool = True            # False = static homes, no decisions
    ema_alpha: float = 0.3          # rolling token-share smoothing
    hot_enter_x: float = 1.5        # hot when share >= hot_enter_x / E
    hot_exit_x: float = 1.1         # cool when share <  hot_exit_x  / E
    cooldown_steps: int = 16        # min steps between moves of one expert
    recheck_steps: int = 4          # re-score a still-hot expert's migration
    replicate_after: int = 8        # hot-streak period between replica checks
    max_replicas: int = 1           # extra copies per expert
    capacity_factor: float = 4.0    # per-copy slot headroom over fair share
    drop_tokens: bool = True        # clamp to capacity (drops are counted)
    amortize_steps: int = 16        # horizon a migration must pay back over
    name_prefix: str = "moe"        # handle namespace: {prefix}/expert{e}

    @property
    def expert_nbytes(self) -> float:
        """Bytes of one expert's weight triple (gate + up + down)."""
        return 3.0 * self.d_model * self.d_ff * self.itemsize


def _split_tokens(
    n: int, ncopies: int, cap: Optional[int]
) -> Tuple[List[int], int]:
    """Split ``n`` token copies across ``ncopies`` expert copies, each
    holding at most ``cap`` (None = unbounded).  Returns (parts, dropped)."""
    kept = n if cap is None else min(n, cap * ncopies)
    base, rem = divmod(kept, ncopies)
    parts = [base + (1 if i < rem else 0) for i in range(ncopies)]
    return parts, n - kept


# ---------------------------------------------------------------------------
# The policy
# ---------------------------------------------------------------------------

class ExpertPlacementPolicy:
    """Consume per-step expert histograms; migrate/replicate hot experts.

    Lifecycle: construct with a :class:`PlacementConfig`, ``attach()`` to
    pin one weight handle per expert (contiguous blocks over the lanes —
    the static layout), then per dispatch step call :meth:`step` with the
    route/pack histogram (decisions execute immediately on the cluster,
    d2d charged on the destination lane's stream clocks) and :meth:`plan`
    to build the placed sub-launch fan-out for that step's tokens.

    All decision state is host-side Python — the policy never touches the
    jnp math, which is how the placed path stays bitwise-equal to the
    static grouped MoE.
    """

    def __init__(
        self,
        cfg: Optional[PlacementConfig] = None,
        cluster: Optional[HeroCluster] = None,
    ) -> None:
        self.cfg = cfg or PlacementConfig()
        self.cluster = cluster if cluster is not None else engine()
        e = self.cfg.num_experts
        self.lanes: List[int] = []
        self.home: List[int] = []                  # expert -> home lane
        self.handles: Dict[int, DeviceHandle] = {}
        self.replica_lanes: Dict[int, List[int]] = {i: [] for i in range(e)}
        self.share: List[float] = [1.0 / e] * e    # EMA token share
        self.tokens_ema: float = 0.0               # EMA tokens per step
        self.hot: List[bool] = [False] * e
        self.hot_streak: List[int] = [0] * e
        self.cooldown: List[int] = [0] * e
        self.step_count = 0
        self.decisions: List[PlacementDecision] = []
        self.migration_edges: List[MigrationEdge] = []
        self.tokens_routed = 0
        self.tokens_processed = 0
        self.tokens_dropped = 0
        self.dropped_by_expert: List[int] = [0] * e

    # ---- attachment -------------------------------------------------------
    @property
    def attached(self) -> bool:
        return bool(self.handles)

    @property
    def enabled(self) -> bool:
        return self.cfg.enabled

    def attach(self, lanes: Optional[Sequence[int]] = None) -> None:
        """Pin each expert's weight triple as a handle homed on a lane.

        Homes are contiguous blocks over ``lanes`` (expert ``e`` on lane
        ``lanes[e·L//E]``) — exactly the static expert-parallel shard_map
        layout, so the policy-off placement matches today's plan."""
        if self.attached:
            raise RuntimeError("placement policy already attached")
        if lanes is None:
            lanes = [d.device_id for d in self.cluster.alive_devices()]
        self.lanes = [int(x) for x in lanes]
        n_lanes = len(self.lanes)
        if n_lanes == 0:
            raise RuntimeError("no lanes to attach expert handles to")
        e = self.cfg.num_experts
        for i in range(e):
            lane = self.lanes[min(i * n_lanes // e, n_lanes - 1)]
            h = self.cluster.pin_handle(
                f"{self.cfg.name_prefix}/expert{i}",
                self.cfg.expert_nbytes, lane)
            self.handles[i] = h
            self.home.append(lane)

    def _require_attached(self) -> None:
        if not self.attached:
            raise RuntimeError(
                "placement policy not attached; call attach() first")

    # ---- rolling histogram ------------------------------------------------
    def observe(self, histogram: Sequence[int]) -> None:
        """Fold one step's per-expert token histogram into the EMA shares."""
        hist = [max(int(h), 0) for h in histogram]
        if len(hist) != self.cfg.num_experts:
            raise ValueError(
                f"histogram has {len(hist)} entries for "
                f"{self.cfg.num_experts} experts")
        total = sum(hist)
        if total <= 0:
            return
        a = self.cfg.ema_alpha
        self.tokens_ema = (
            total if self.tokens_ema == 0.0
            else (1.0 - a) * self.tokens_ema + a * total
        )
        for i, n in enumerate(hist):
            self.share[i] = (1.0 - a) * self.share[i] + a * (n / total)

    # ---- per-step dispatch plan ------------------------------------------
    def _default_capacity(self, total_tokens: int) -> Optional[int]:
        if not self.cfg.drop_tokens:
            return None
        ideal = total_tokens / self.cfg.num_experts
        cap = int(math.ceil(ideal * self.cfg.capacity_factor / 8.0) * 8)
        return max(cap, 8)

    def _expert_cost(self, tokens: int) -> OpCost:
        c = self.cfg
        return gemm_cost(tokens, 3 * c.d_ff, c.d_model, c.itemsize,
                         op="moe_expert_ffn")

    def _replica_name(self, expert: int, lane: int) -> str:
        return f"{self.handles[expert].name}@dev{lane}"

    def plan(
        self,
        histogram: Sequence[int],
        *,
        capacity: Optional[int] = None,
        record: bool = True,
    ) -> ExpertDispatchPlan:
        """Compile one step's histogram into the placed sub-launch fan-out.

        Each expert's (capacity-clamped) tokens go to its home lane, split
        evenly across its replica set when one exists; empty experts are
        skipped (pipegoose's dispatch-order idiom).  ``record=False`` makes
        the call a pure probe (no counters, no running totals) — the
        decision heuristics use it to score hypothetical placements."""
        self._require_attached()
        hist = [max(int(h), 0) for h in histogram]
        total = sum(hist)
        cap = capacity if capacity is not None else self._default_capacity(total)
        w_bytes = self.cfg.expert_nbytes
        subs: List[PlacedSubLaunch] = []
        dropped_by = [0] * self.cfg.num_experts
        processed = 0
        for i, n in enumerate(hist):
            if n <= 0:
                continue
            targets = [self.home[i]] + self.replica_lanes[i]
            parts, dropped = _split_tokens(n, len(targets), cap)
            dropped_by[i] = dropped
            for lane, tok in zip(targets, parts):
                if tok <= 0:
                    continue
                cost = self._expert_cost(tok)
                rf = (
                    min(1.0, w_bytes / cost.staged_bytes)
                    if cost.staged_bytes > 0 else 0.0
                )
                name = (
                    self.handles[i].name if lane == self.home[i]
                    else self._replica_name(i, lane)
                )
                subs.append(PlacedSubLaunch(
                    expert=i, device_id=lane, tokens=tok, cost=cost,
                    shape_key=name, resident_fraction=rf))
                processed += tok
        tokens_dropped = total - processed
        if record:
            self.tokens_routed += total
            self.tokens_processed += processed
            self.tokens_dropped += tokens_dropped
            for i, dn in enumerate(dropped_by):
                if dn:
                    self.dropped_by_expert[i] += dn
                    _metrics.counter(
                        "moe.tokens_dropped", expert=str(i)).inc(dn)
        return ExpertDispatchPlan(
            sub_launches=tuple(subs),
            tokens_routed=total,
            tokens_processed=processed,
            tokens_dropped=tokens_dropped,
            dropped_by_expert=tuple(dropped_by),
            capacity=cap or 0,
        )

    # ---- placement scoring ------------------------------------------------
    def _ema_counts(self) -> List[int]:
        """The rolling histogram as integer token counts (decision input)."""
        t = self.tokens_ema or float(self.cfg.num_experts)
        return [int(round(s * t)) for s in self.share]

    def _lane_seconds(
        self,
        counts: Sequence[int],
        home: Sequence[int],
        replica_lanes: Dict[int, List[int]],
    ) -> Dict[int, float]:
        """Modeled busy seconds per lane for one step of ``counts`` under a
        hypothetical placement — same per-expert costs and policy scoring
        the real fan-out uses, so decisions and charges agree.

        Scoring deliberately ignores the capacity clamp: decisions balance
        the *offered* load.  Clamping here would hide exactly the signal
        replication exists to act on — a saturated expert looks identical
        before and after adding a copy if both trials are cut to the same
        per-copy cap, even though the replica doubles the tokens actually
        served (fewer drops at dispatch time)."""
        pol = self.cluster.policy
        cap = None
        w_bytes = self.cfg.expert_nbytes
        out = {lane: 0.0 for lane in self.lanes}
        for i, n in enumerate(counts):
            if n <= 0:
                continue
            targets = [home[i]] + replica_lanes.get(i, [])
            parts, _ = _split_tokens(n, len(targets), cap)
            for lane, tok in zip(targets, parts):
                if tok <= 0:
                    continue
                cost = self._expert_cost(tok)
                rf = (
                    min(1.0, w_bytes / cost.staged_bytes)
                    if cost.staged_bytes > 0 else 0.0
                )
                bd = pol.score(cost, self.cluster.platform,
                               resident_fraction=rf)
                out[lane] = out.get(lane, 0.0) + bd.offload_s
        return out

    def _src_drain_s(self, expert: int) -> float:
        """Latest in-flight completion on the source lane still reading the
        expert's handle — the migration's happens-before fence."""
        h = self.handles[expert]
        dev = self.cluster.devices[self.home[expert]]
        drain = 0.0
        for t in dev.inflight:
            if t.shape_key == h.name:
                drain = max(drain, t.complete_s)
        return drain

    # ---- decisions --------------------------------------------------------
    def _consider_migrate(
        self, expert: int, now_s: float
    ) -> Optional[PlacementDecision]:
        src = self.home[expert]
        counts = self._ema_counts()
        base = max(self._lane_seconds(counts, self.home, self.replica_lanes)
                   .values())
        best_dst, best_gain = None, 0.0
        for lane in self.lanes:
            if lane == src:
                continue
            trial = list(self.home)
            trial[expert] = lane
            after = max(self._lane_seconds(counts, trial, self.replica_lanes)
                        .values())
            gain = base - after
            if gain > best_gain + 1e-12:
                best_dst, best_gain = lane, gain
        if best_dst is None:
            return None
        bd = d2d_breakdown(self.cfg.expert_nbytes, self.cluster.platform)
        if best_gain * self.cfg.amortize_steps < bd.offload_s:
            return None  # the move does not amortize — stay put
        h = self.handles[expert]
        drain = self._src_drain_s(expert)
        dst_dev = self.cluster.devices[best_dst]
        # The d2d may not issue while a source-lane launch still reads the
        # handle: fence the destination DMA stream on the drain event.
        dst_dev.advance_clocks(max(now_s, drain))
        self.cluster.migrate_handle(h, best_dst)
        ticket = dst_dev.inflight[-1]
        self.migration_edges.append(MigrationEdge(
            expert=expert, handle_name=h.name, src_device=src,
            dst_device=best_dst, migrate_issue_s=ticket.issue_s,
            src_drain_s=drain))
        self.home[expert] = best_dst
        self.cooldown[expert] = self.cfg.cooldown_steps
        _metrics.counter("placement.migrations", expert=str(expert)).inc()
        tr = _spans.current_tracer()
        if tr is not None:
            tr.instant("expert-migrate", cat="placement",
                       lane=f"dev{best_dst}/dma", t=ticket.issue_s,
                       attrs={"expert": expert, "src": src, "dst": best_dst,
                              "share": round(self.share[expert], 4)},
                       device_id=best_dst)
        return PlacementDecision(
            step=self.step_count, kind="migrate", expert=expert,
            src_device=src, dst_device=best_dst, d2d_s=bd.d2d_s,
            share=self.share[expert], ticket=ticket)

    def _consider_replicate(
        self, expert: int, now_s: float
    ) -> Optional[PlacementDecision]:
        src = self.home[expert]
        taken = {src, *self.replica_lanes[expert]}
        counts = self._ema_counts()
        base = max(self._lane_seconds(counts, self.home, self.replica_lanes)
                   .values())
        best_dst, best_gain = None, 0.0
        for lane in self.lanes:
            if lane in taken:
                continue
            trial = {k: list(v) for k, v in self.replica_lanes.items()}
            trial[expert].append(lane)
            after = max(self._lane_seconds(counts, self.home, trial).values())
            gain = base - after
            if gain > best_gain + 1e-12:
                best_dst, best_gain = lane, gain
        if best_dst is None:
            return None
        bd = d2d_breakdown(self.cfg.expert_nbytes, self.cluster.platform)
        if best_gain * self.cfg.amortize_steps < bd.offload_s:
            return None
        dst_dev = self.cluster.devices[best_dst]
        dst_dev.advance_clocks(now_s)
        self.cluster.replicate_handle(self.handles[expert], best_dst)
        ticket = dst_dev.inflight[-1]
        self.replica_lanes[expert].append(best_dst)
        self.cooldown[expert] = self.cfg.cooldown_steps
        _metrics.counter("placement.replications", expert=str(expert)).inc()
        tr = _spans.current_tracer()
        if tr is not None:
            tr.instant("expert-replicate", cat="placement",
                       lane=f"dev{best_dst}/dma", t=ticket.issue_s,
                       attrs={"expert": expert, "src": src, "dst": best_dst,
                              "share": round(self.share[expert], 4)},
                       device_id=best_dst)
        return PlacementDecision(
            step=self.step_count, kind="replicate", expert=expert,
            src_device=src, dst_device=best_dst, d2d_s=bd.d2d_s,
            share=self.share[expert], ticket=ticket)

    def step(
        self, histogram: Sequence[int], *, now_s: float = 0.0
    ) -> List[PlacementDecision]:
        """Observe one step's histogram, then execute any migrate/replicate
        decisions on the cluster (d2d charged on the destination lane's
        stream clocks at modeled time ``now_s`` or later).

        Migration is scored on a hot *rising edge* and re-scored every
        ``recheck_steps`` while the expert stays hot (the rising edge often
        lands before the EMA has converged, so a once-only check can
        foreclose a profitable move forever); replication triggers only
        every ``replicate_after`` steps of a persistent hot streak.  Both
        must amortize their d2d cost, and that margin — not the trigger
        cadence — is what prevents ping-pong: once an expert sits on its
        best lane, moving it back never clears the amortization bar."""
        self._require_attached()
        self.step_count += 1
        self.observe(histogram)
        if not self.cfg.enabled:
            return []
        cfg = self.cfg
        fair = 1.0 / cfg.num_experts
        rising: List[int] = []
        for i in range(cfg.num_experts):
            if self.cooldown[i] > 0:
                self.cooldown[i] -= 1
            if self.hot[i]:
                if self.share[i] < cfg.hot_exit_x * fair:
                    self.hot[i] = False
                    self.hot_streak[i] = 0
                else:
                    self.hot_streak[i] += 1
            elif self.share[i] >= cfg.hot_enter_x * fair:
                self.hot[i] = True
                self.hot_streak[i] = 1
                rising.append(i)
        decisions: List[PlacementDecision] = []
        candidates = list(rising)
        for i in range(cfg.num_experts):
            if (
                i not in candidates
                and self.hot[i]
                and self.hot_streak[i] % cfg.recheck_steps == 0
            ):
                candidates.append(i)
        for i in candidates:
            if self.cooldown[i] > 0 or self.replica_lanes[i]:
                continue
            d = self._consider_migrate(i, now_s)
            if d is not None:
                decisions.append(d)
        for i in range(cfg.num_experts):
            if (
                self.hot[i]
                and self.hot_streak[i] > 0
                and self.hot_streak[i] % cfg.replicate_after == 0
                and len(self.replica_lanes[i]) < cfg.max_replicas
                and self.cooldown[i] == 0
            ):
                d = self._consider_replicate(i, now_s)
                if d is not None:
                    decisions.append(d)
        self.decisions.extend(decisions)
        return decisions

    # ---- summaries --------------------------------------------------------
    @property
    def decision_log(self) -> Tuple[tuple, ...]:
        """Comparable decision identities (same-seed determinism anchor)."""
        return tuple(d.key for d in self.decisions)

    def counters(self) -> Dict[str, int]:
        mig = sum(1 for d in self.decisions if d.kind == "migrate")
        rep = sum(1 for d in self.decisions if d.kind == "replicate")
        return {
            "migrations": mig,
            "replications": rep,
            "tokens_routed": self.tokens_routed,
            "tokens_processed": self.tokens_processed,
            "tokens_dropped": self.tokens_dropped,
        }


# ---------------------------------------------------------------------------
# Seeded Zipfian router traffic
# ---------------------------------------------------------------------------

def zipf_shares(num_experts: int, s: float) -> List[float]:
    """Normalized Zipf(s) popularity over ``num_experts`` ranks."""
    w = [1.0 / (i + 1) ** s for i in range(num_experts)]
    tot = sum(w)
    return [x / tot for x in w]

def zipf_histogram(
    rng: random.Random, num_experts: int, s: float, tokens: int
) -> List[int]:
    """One step's per-expert token histogram: ``tokens`` multinomial draws
    from the Zipf(s) popularity curve, deterministic given ``rng`` state."""
    cum = list(itertools.accumulate(zipf_shares(num_experts, s)))
    hist = [0] * num_experts
    for _ in range(tokens):
        i = bisect.bisect_left(cum, rng.random())
        hist[min(i, num_experts - 1)] += 1
    return hist


# ---------------------------------------------------------------------------
# The skewed-router workload (bench / tests / race-replay share it)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SkewedRunResult:
    """One seeded skewed-router run on a fresh modeled cluster."""

    zipf_s: float
    seed: int
    dynamic: bool
    num_lanes: int
    makespan_s: float
    migrations: int
    replications: int
    tokens_routed: int
    tokens_processed: int
    tokens_dropped: int
    decision_log: Tuple[tuple, ...]
    migration_edges: Tuple[MigrationEdge, ...]
    ticket_streams: Dict[int, List[LaunchTicket]]


def run_skewed_workload(
    *,
    zipf_s: float,
    seed: int = 0,
    dynamic: bool = True,
    steps: int = 96,
    tokens_per_step: int = 1024,
    num_experts: int = 16,
    num_lanes: int = 4,
    platform: str = "tpu-v5e",
    config: Optional[PlacementConfig] = None,
) -> SkewedRunResult:
    """Drive ``steps`` grouped-FFN dispatch steps of Zipf(s) router traffic
    through an :class:`ExpertPlacementPolicy` on a fresh ``num_lanes``
    modeled cluster; ``dynamic=False`` freezes the static contiguous-block
    homes (the baseline the headline divides by).  Same seed, same result —
    decisions, makespan and tickets are all modeled-deterministic."""
    cfg = config or PlacementConfig(num_experts=num_experts, enabled=dynamic)
    rng = random.Random(seed)
    with offload_policy(
        mode="device", platform=platform, num_devices=num_lanes,
        scheduler="least-loaded",
    ) as eng:
        pol = ExpertPlacementPolicy(cfg, cluster=eng)
        pol.attach(range(num_lanes))
        for step_i in range(steps):
            hist = zipf_histogram(rng, cfg.num_experts, zipf_s,
                                  tokens_per_step)
            pol.step(hist)
            plan = pol.plan(hist)
            if plan.sub_launches:
                eng.launch_fanout(
                    plan.sub_launches,
                    note=f"skewed-router step {step_i}")
        makespan = max(d.stream_makespan_s for d in eng.devices)
        streams = {d.device_id: list(d.inflight) for d in eng.devices}
        c = pol.counters()
    return SkewedRunResult(
        zipf_s=zipf_s, seed=seed, dynamic=dynamic, num_lanes=num_lanes,
        makespan_s=makespan,
        migrations=c["migrations"], replications=c["replications"],
        tokens_routed=c["tokens_routed"],
        tokens_processed=c["tokens_processed"],
        tokens_dropped=c["tokens_dropped"],
        decision_log=pol.decision_log,
        migration_edges=tuple(pol.migration_edges),
        ticket_streams=streams,
    )


def placement_sweep(
    *,
    zipf_points: Sequence[float] = (0.6, 1.2, 1.8),
    seed: int = 0,
    steps: int = 96,
    tokens_per_step: int = 1024,
    num_experts: int = 16,
    num_lanes: int = 4,
    platform: str = "tpu-v5e",
) -> dict:
    """Static-vs-dynamic makespan over a Zipf skew sweep (JSON-safe).

    The headline ``expert_placement_speedup`` is the dynamic/static
    makespan ratio at s=1.2 (the gated point); every point records its
    seed and full token conservation so the bench gate can assert zero
    unaccounted drops."""
    points = []
    for s in zipf_points:
        runs = {}
        for label, dyn in (("static", False), ("dynamic", True)):
            r = run_skewed_workload(
                zipf_s=s, seed=seed, dynamic=dyn, steps=steps,
                tokens_per_step=tokens_per_step, num_experts=num_experts,
                num_lanes=num_lanes, platform=platform)
            runs[label] = r
        stat, dyn = runs["static"], runs["dynamic"]
        speedup = (
            stat.makespan_s / dyn.makespan_s if dyn.makespan_s > 0 else 0.0
        )
        points.append({
            "zipf_s": s,
            "seed": seed,
            "static_makespan_s": stat.makespan_s,
            "dynamic_makespan_s": dyn.makespan_s,
            "speedup": speedup,
            "migrations": dyn.migrations,
            "replications": dyn.replications,
            "static": {
                "tokens_routed": stat.tokens_routed,
                "tokens_processed": stat.tokens_processed,
                "tokens_dropped": stat.tokens_dropped,
                "tokens_unaccounted": (
                    stat.tokens_routed - stat.tokens_processed
                    - stat.tokens_dropped),
            },
            "dynamic": {
                "tokens_routed": dyn.tokens_routed,
                "tokens_processed": dyn.tokens_processed,
                "tokens_dropped": dyn.tokens_dropped,
                "tokens_unaccounted": (
                    dyn.tokens_routed - dyn.tokens_processed
                    - dyn.tokens_dropped),
            },
        })
    headline = next(
        (p["speedup"] for p in points if abs(p["zipf_s"] - 1.2) < 1e-9),
        max((p["speedup"] for p in points), default=0.0),
    )
    return {
        "seed": seed,
        "steps": steps,
        "tokens_per_step": tokens_per_step,
        "num_experts": num_experts,
        "num_lanes": num_lanes,
        "platform": platform,
        "points": points,
        "expert_placement_speedup": headline,
    }
