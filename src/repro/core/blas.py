"""The BLAS seam — OpenBLAS analogue (paper Fig. 2, box 3), as a declarative
op registry.

One stable linear-algebra API that *all* higher layers call instead of raw
``jnp`` contractions.  Every op here is an :class:`~repro.core.dispatch.
OffloadOp` descriptor — its cost function, Pallas-eligibility predicate,
host (XLA) lowering and Pallas lowering — registered with
:mod:`repro.core.dispatch` at import time.  The public functions are thin
wrappers over the single :func:`~repro.core.dispatch.dispatch` path, which
scores the call, resolves routing (explicit-TP plan -> Pallas -> host),
threads the chosen ``device_id`` into the trace record, and runs the
winning lowering — exactly the role OpenBLAS plays in the paper, with the
OpenMP ``#pragma omp target`` replaced by the dispatch engine.

Extending the seam is declarative: write the lowerings, build an
``OffloadOp``, ``register`` it (and add the kernel to
``repro.kernels.ops.PALLAS_LOWERINGS`` if it has a device form).  No new
dispatch code — the cost -> plan -> launch -> lower ritual exists once, in
``core/dispatch.py``, so placement, accounting, and scheduling behave
identically for every op.

Host path    : ``lax.dot_general`` (XLA default lowering — the "rv64g host
               kernel").
Device path  : same graph, but accounted as an offload with the three-region
               breakdown (on a real TPU, host/device is a residency and
               lowering distinction, not a different chip).
Pallas path  : hand-tiled MXU kernels from ``repro.kernels`` (the "rv32 PMCA
               kernel"), selected when the policy enables them and the shape
               is tile-eligible.

``syrk`` is host-only (``host_only=True`` on its descriptor), mirroring the
paper compiling ``syrk.c`` only for the host.  Callers holding a
:class:`~repro.core.hero.DeviceHandle` (pinned KV cache, resident weights)
pass ``handle=`` to any op so schedulers route the work to the data.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cost_model as cm
from repro.core.dispatch import OffloadOp, dispatch, dispatch_placed, register
from repro.core.hero import DeviceHandle, engine  # noqa: F401 (re-export seam)

__all__ = [
    "gemm",
    "matmul",
    "gemm_batched",
    "linear",
    "mlp_block",
    "qkv_project",
    "ssd_scan",
    "moe_expert_ffn",
    "moe_expert_ffn_placed",
    "expert_matmul",
    "attention",
    "attention_math",
    "decode_attention",
    "psum_cast_dtype",
    "syrk",
    "gemv",
    "dot",
    "axpy",
    "scal",
    "nrm2",
    "reduce_sum",
    "reduce_mean",
    "relu",
    "silu",
    "rmsnorm_scale",
]

# Ops never offloaded to the Pallas path (paper keeps syrk host-only).
HOST_ONLY_OPS = frozenset({"syrk"})

_MXU_ALIGN = 128
# Host attention: direct masked einsum up to this kv length, chunked
# online-softmax scan beyond it (keeps memory linear in Skv).
_DIRECT_ATTN_MAX_KV = 8192
_CHUNKED_ATTN_BLOCK = 1024


def _kops():
    from repro.kernels import ops as kops  # lazy: avoid import cycle

    return kops


def _pallas_gemm_eligible(m: int, n: int, k: int, dtype) -> bool:
    """Tile-eligibility for the hand-written MXU GEMM kernel."""
    if dtype not in (jnp.float32, jnp.bfloat16):
        return False
    # The kernel pads internally; require dims large enough that a 128-tile
    # working set is meaningful (analogue of "fits the SPM blocking").
    return min(m, n, k) >= 8


def _accum_dot(a, b, dimension_numbers, out_dtype):
    """Contraction with fp32 accumulation (MXU semantics)."""
    acc = lax.dot_general(
        a,
        b,
        dimension_numbers=dimension_numbers,
        preferred_element_type=jnp.float32,
    )
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Level-3 descriptors
# ---------------------------------------------------------------------------

def _gemm_dims(a, b, transpose_a, transpose_b):
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"gemm takes 2-D operands, got {a.shape} @ {b.shape}")
    m, k = (a.shape[1], a.shape[0]) if transpose_a else a.shape
    kb, n = (b.shape[1], b.shape[0]) if transpose_b else b.shape
    if k != kb:
        raise ValueError(f"gemm contraction mismatch: {a.shape} @ {b.shape}")
    return m, n, k


def _gemm_cost(a, b, *, transpose_a=False, transpose_b=False, out_dtype=None):
    m, n, k = _gemm_dims(a, b, transpose_a, transpose_b)
    return cm.gemm_cost(m, n, k, jnp.dtype(a.dtype).itemsize)


def _gemm_eligible(a, b, *, transpose_a=False, transpose_b=False, out_dtype=None):
    m, n, k = _gemm_dims(a, b, transpose_a, transpose_b)
    return _pallas_gemm_eligible(m, n, k, a.dtype)


def _gemm_host(a, b, *, transpose_a=False, transpose_b=False, out_dtype=None):
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    ca = ((0,) if transpose_a else (1,), (1,) if transpose_b else (0,))
    return _accum_dot(a, b, (ca, ((), ())), out_dtype)


def _gemm_pallas(
    a, b, *, transpose_a=False, transpose_b=False, out_dtype=None,
    interpret=False,
):
    aa = a.T if transpose_a else a
    bb = b.T if transpose_b else b
    return _kops().pallas_lowering("gemm")(
        aa, bb,
        out_dtype=out_dtype or jnp.result_type(a.dtype, b.dtype),
        interpret=interpret,
    )


register(OffloadOp(
    name="gemm",
    cost=_gemm_cost,
    host=_gemm_host,
    pallas=_gemm_pallas,
    eligible=_gemm_eligible,
))


def _tp_mesh_info():
    """Ambient model-parallel topology, or None when no TP plan can apply.

    Returns ``(mesh, n_model, dp_axes, n_dp)`` — the shared applicability
    prologue of every descriptor's TP ``plan`` (pure inspection, safe at
    trace time).  A single-device model axis counts as "no topology".
    """
    from repro.sharding.annotate import _ambient_mesh

    mesh = _ambient_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return None
    n_model = mesh.shape["model"]
    if n_model <= 1:
        return None
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import numpy as _np

    n_dp = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    return mesh, n_model, dp, n_dp


def _tp_plan(x, w, mode: str):
    """Check whether the explicit-TP shard_map path applies.

    Returns ``(mesh, dp_axes)`` when it does, else None.  Pure inspection —
    no execution — so the dispatcher can resolve routing *before* recording
    a backend (the trace must name the path that actually ran).
    """
    if mode not in ("row", "col"):
        return None
    info = _tp_mesh_info()
    if info is None or x.ndim != 3:
        return None
    mesh, n_model, dp, n_dp = info
    if x.shape[0] % n_dp:
        return None
    if x.shape[-1] != w.shape[0]:
        return None
    if mode == "row" and w.shape[0] % n_model:
        return None
    if mode == "col" and w.shape[1] % n_model:
        return None
    return mesh, dp


def _tp_shard_map_matmul(x, w, mode: str, out_dtype, plan):
    """Explicit tensor-parallel matmul with bf16 cross-device reductions.

    GSPMD places the TP all-reduce on the fp32 dot product (before the bf16
    cast), doubling wire bytes.  Under shard_map the seam does: local matmul
    with fp32 accumulation -> cast -> psum in the output dtype.  ``row``:
    w's first (contracting) dim is model-sharded, psum in forward; ``col``:
    w's last dim is model-sharded, the (autodiff-generated) psum of dX in
    backward is bf16 for free because the local primal is already cast.
    ``plan`` comes from :func:`_tp_plan`.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh, dp = plan
    out_dtype = out_dtype or jnp.result_type(x.dtype, w.dtype)
    if mode == "row":

        def local(xl, wl):
            y = lax.dot_general(
                xl, wl, (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).astype(out_dtype)
            return lax.psum(y, "model")

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(dp, None, "model"), P("model", None)),
            out_specs=P(dp, None, None),
            check_rep=False,
        )(x, w)
    # col: output dim sharded; bwd dX psum happens in out_dtype

    def local_col(xl, wl):
        return lax.dot_general(
            xl, wl, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_dtype)

    return shard_map(
        local_col,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(None, "model")),
        out_specs=P(dp, None, "model"),
        check_rep=False,
    )(x, w)


def _matmul_dims(x, w):
    if w.ndim != 2:
        raise ValueError(f"matmul expects 2-D rhs, got {w.shape}")
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"matmul contraction mismatch: {x.shape} @ {w.shape}")
    m = 1
    for d in x.shape[:-1]:
        m *= d
    k, n = w.shape
    return m, k, n


def _matmul_cost(x, w, *, out_dtype=None, tp_mode=None):
    m, k, n = _matmul_dims(x, w)
    return cm.gemm_cost(m, n, k, jnp.dtype(x.dtype).itemsize)


def _matmul_plan(x, w, *, out_dtype=None, tp_mode=None):
    # A tensor-parallel matmul runs the shard_map XLA path, so routing must
    # resolve before the record is written (no phantom Pallas launches).
    return _tp_plan(x, w, tp_mode) if tp_mode in ("row", "col") else None


def _matmul_plan_lower(plan, x, w, *, out_dtype=None, tp_mode=None):
    return _tp_shard_map_matmul(x, w, tp_mode, out_dtype, plan)


def _matmul_eligible(x, w, *, out_dtype=None, tp_mode=None):
    m, k, n = _matmul_dims(x, w)
    return _pallas_gemm_eligible(m, n, k, x.dtype)


def _matmul_host(x, w, *, out_dtype=None, tp_mode=None):
    out_dtype = out_dtype or jnp.result_type(x.dtype, w.dtype)
    return _accum_dot(x, w, (((x.ndim - 1,), (0,)), ((), ())), out_dtype)


def _matmul_pallas(x, w, *, out_dtype=None, tp_mode=None, interpret=False):
    m, k, n = _matmul_dims(x, w)
    out = _kops().pallas_lowering("matmul")(
        x.reshape(m, k), w,
        out_dtype=out_dtype or jnp.result_type(x.dtype, w.dtype),
        interpret=interpret,
    )
    return out.reshape(*x.shape[:-1], n)


register(OffloadOp(
    name="matmul",
    cost=_matmul_cost,
    host=_matmul_host,
    pallas=_matmul_pallas,
    eligible=_matmul_eligible,
    plan=_matmul_plan,
    plan_lower=_matmul_plan_lower,
))


def psum_cast_dtype(dtype):
    """Reduction dtype for TP psums. bf16 on real hardware (halves wire
    bytes); f32 on the XLA:CPU emulation backend, whose AllReducePromotion
    pass crashes cloning bf16 all-reduces produced by partially-manual
    shard_maps (observed: 'Invalid binary instruction opcode copy')."""
    if jax.default_backend() == "cpu" and jnp.dtype(dtype) == jnp.bfloat16:
        return jnp.float32
    return dtype


# ---------------------------------------------------------------------------
# mlp_block — the whole dense FFN behind one descriptor.
#
# The model layers used to hand-roll this: raw `lax.dot_general` calls inside
# a shard_map (bypassing the seam entirely) plus a bare engine().launch for
# the cost.  As a registered op the block takes the same single
# cost -> plan -> launch -> lower path as everything else: the TP shard_map
# form is its `plan` (one bf16 psum per block), the dense fp32-accumulated
# form its host lowering, the hand-tiled MXU GEMMs its Pallas lowering.
# ---------------------------------------------------------------------------

def _mlp_dims(x, w_up, w_down, gate, kind):
    if x.ndim < 2:
        raise ValueError(f"mlp_block needs batched input, got {x.shape}")
    if kind not in ("swiglu", "gelu"):
        raise ValueError(f"mlp_block: unknown kind {kind!r}")
    d = x.shape[-1]
    if w_up.ndim != 2 or w_up.shape[0] != d:
        raise ValueError(f"mlp_block: bad up projection {x.shape} @ {w_up.shape}")
    d_ff = w_up.shape[1]
    if tuple(w_down.shape) != (d_ff, d):
        raise ValueError(
            f"mlp_block: bad down projection {w_down.shape}, want {(d_ff, d)}"
        )
    if kind == "swiglu" and (gate is None or tuple(gate.shape) != (d, d_ff)):
        raise ValueError("mlp_block: swiglu needs a (d, d_ff) gate")
    m = 1
    for dim in x.shape[:-1]:
        m *= dim
    return m, d, d_ff


def _mlp_cost(x, w_up, w_down, gate=None, b_up=None, b_down=None, *,
              kind="swiglu"):
    m, d, d_ff = _mlp_dims(x, w_up, w_down, gate, kind)
    n_mats = 3 if kind == "swiglu" else 2
    return cm.gemm_cost(
        m, d_ff * n_mats, d, jnp.dtype(x.dtype).itemsize, op="mlp_block"
    )


def _mlp_eligible(x, w_up, w_down, gate=None, b_up=None, b_down=None, *,
                  kind="swiglu"):
    m, d, d_ff = _mlp_dims(x, w_up, w_down, gate, kind)
    return _pallas_gemm_eligible(m, d_ff, d, x.dtype)


def _mlp_plan(x, w_up, w_down, gate=None, b_up=None, b_down=None, *,
              kind="swiglu"):
    """Whole-block tensor-parallel applicability (pure inspection).

    Returns ``(mesh, dp_axes)`` when the d_ff column/row slices can stay
    local under an ambient model-parallel mesh, else None."""
    import os

    if os.environ.get("REPRO_DISABLE_TP_MLP"):
        return None
    info = _tp_mesh_info()
    if info is None or x.ndim != 3:
        return None
    mesh, n_model, dp, n_dp = info
    d_ff = w_up.shape[1]
    if x.shape[0] % n_dp or d_ff % n_model:
        return None
    return mesh, dp


def _mlp_plan_lower(plan, x, w_up, w_down, gate=None, b_up=None, b_down=None,
                    *, kind="swiglu"):
    """Whole MLP under one shard_map: d_ff column/row slices stay local,
    ONE bf16 psum forward + one backward (§Perf hillclimb #2).  GSPMD's
    schedule all-reduces the fp32 products and pays per-projection dX
    reductions."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh, dp = plan
    if kind == "swiglu":

        def local(xl, wg, wu, wd):
            g = lax.dot_general(xl, wg, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            u = lax.dot_general(xl, wu, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            h = (jax.nn.silu(g) * u).astype(xl.dtype)
            y = lax.dot_general(h, wd, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            y = lax.psum(y.astype(psum_cast_dtype(xl.dtype)), "model")
            return y.astype(xl.dtype)

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(dp, None, None), P(None, "model"), P(None, "model"),
                      P("model", None)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )
        return fn(x, gate, w_up, w_down)

    def local_gelu(xl, wu, bu, wd, bd):
        h = lax.dot_general(xl, wu, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32) + bu
        h = jax.nn.gelu(h).astype(xl.dtype)
        y = lax.dot_general(h, wd, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        y = lax.psum(y.astype(psum_cast_dtype(xl.dtype)), "model")
        return y.astype(xl.dtype) + bd.astype(xl.dtype)

    fn = shard_map(
        local_gelu, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, "model"), P("model"),
                  P("model", None), P(None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )
    return fn(x, w_up, b_up, w_down, b_down)


def _mlp_host(x, w_up, w_down, gate=None, b_up=None, b_down=None, *,
              kind="swiglu"):
    dn = (((x.ndim - 1,), (0,)), ((), ()))
    if kind == "swiglu":
        g = _accum_dot(x, gate, dn, x.dtype)
        u = _accum_dot(x, w_up, dn, x.dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return _accum_dot(h, w_down, dn, x.dtype)
    h = _accum_dot(x, w_up, dn, x.dtype)
    if b_up is not None:
        h = h + b_up.astype(h.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = _accum_dot(h, w_down, dn, x.dtype)
    if b_down is not None:
        y = y + b_down.astype(y.dtype)
    return y


def _mlp_pallas(x, w_up, w_down, gate=None, b_up=None, b_down=None, *,
                kind="swiglu", interpret=False):
    m, d, d_ff = _mlp_dims(x, w_up, w_down, gate, kind)
    mm = _kops().pallas_lowering("matmul")
    xm = x.reshape(m, d)
    if kind == "swiglu":
        g = mm(xm, gate, out_dtype=x.dtype, interpret=interpret)
        u = mm(xm, w_up, out_dtype=x.dtype, interpret=interpret)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = mm(h, w_down, out_dtype=x.dtype, interpret=interpret)
    else:
        h = mm(xm, w_up, out_dtype=x.dtype, interpret=interpret)
        if b_up is not None:
            h = h + b_up.astype(h.dtype)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        y = mm(h, w_down, out_dtype=x.dtype, interpret=interpret)
        if b_down is not None:
            y = y + b_down.astype(y.dtype)
    return y.reshape(*x.shape[:-1], d)


register(OffloadOp(
    name="mlp_block",
    cost=_mlp_cost,
    host=_mlp_host,
    pallas=_mlp_pallas,
    eligible=_mlp_eligible,
    plan=_mlp_plan,
    plan_lower=_mlp_plan_lower,
))


# ---------------------------------------------------------------------------
# qkv_project — the fused 3-way attention input projection behind one
# descriptor (mirrors mlp_block).  The attention layer used to hand-roll a
# whole-block shard_map of raw `lax.dot_general` launches plus bare
# engine().launch accounting; as a registered op the projection takes the
# single cost -> plan -> launch -> lower path: the sequence-sharded TP
# shard_map is its `plan` (projection FLOPs divided over the model axis, one
# tiled all-gather of the small qkv activations), the concatenated-weight
# GEMM its host lowering, the hand-tiled MXU matmul its Pallas lowering.
# ---------------------------------------------------------------------------

def _qkv_dims(x, wq, wk, wv, *, bq=None, bk=None, bv=None):
    if x.ndim < 2:
        raise ValueError(f"qkv_project needs batched input, got {x.shape}")
    d = x.shape[-1]
    for name, w in (("wq", wq), ("wk", wk), ("wv", wv)):
        if w.ndim != 2 or w.shape[0] != d:
            raise ValueError(
                f"qkv_project: bad {name} {w.shape} for input {x.shape}"
            )
    for name, w, b in (("bq", wq, bq), ("bk", wk, bk), ("bv", wv, bv)):
        if b is not None and tuple(b.shape) != (w.shape[1],):
            raise ValueError(f"qkv_project: bad bias {name} {b.shape}")
    m = 1
    for dim in x.shape[:-1]:
        m *= dim
    n = wq.shape[1] + wk.shape[1] + wv.shape[1]
    return m, d, n


def _qkv_cost(x, wq, wk, wv, *, bq=None, bk=None, bv=None):
    m, d, n = _qkv_dims(x, wq, wk, wv, bq=bq, bk=bk, bv=bv)
    return cm.gemm_cost(m, n, d, jnp.dtype(x.dtype).itemsize, op="qkv_project")


def _qkv_eligible(x, wq, wk, wv, *, bq=None, bk=None, bv=None):
    m, d, n = _qkv_dims(x, wq, wk, wv, bq=bq, bk=bk, bv=bv)
    return _pallas_gemm_eligible(m, n, d, x.dtype)


def _qkv_concat(x, wq, wk, wv, bq, bk, bv):
    w = jnp.concatenate([wq, wk, wv], axis=1)
    if bq is None and bk is None and bv is None:
        return w, None
    parts = [
        b if b is not None else jnp.zeros((wt.shape[1],), x.dtype)
        for b, wt in ((bq, wq), (bk, wk), (bv, wv))
    ]
    return w, jnp.concatenate(parts)


def _qkv_host(x, wq, wk, wv, *, bq=None, bk=None, bv=None):
    w, b = _qkv_concat(x, wq, wk, wv, bq, bk, bv)
    y = _accum_dot(x, w, (((x.ndim - 1,), (0,)), ((), ())), x.dtype)
    return y if b is None else y + b.astype(y.dtype)


def _qkv_pallas(x, wq, wk, wv, *, bq=None, bk=None, bv=None, interpret=False):
    m, d, n = _qkv_dims(x, wq, wk, wv, bq=bq, bk=bk, bv=bv)
    w, b = _qkv_concat(x, wq, wk, wv, bq, bk, bv)
    y = _kops().pallas_lowering("qkv_project")(
        x.reshape(m, d), w, out_dtype=x.dtype, interpret=interpret
    )
    if b is not None:
        y = y + b.astype(y.dtype)
    return y.reshape(*x.shape[:-1], n)


def _qkv_plan(x, wq, wk, wv, *, bq=None, bk=None, bv=None):
    """Sequence-sharded TP applicability (pure inspection): each model shard
    projects its sequence slice and the small qkv activations are
    all-gathered — replicated compute would pay n_model x the FLOPs."""
    import os

    if os.environ.get("REPRO_DISABLE_TP_ATTN"):
        return None
    info = _tp_mesh_info()
    if info is None or x.ndim != 3:
        return None
    mesh, n_model, dp, n_dp = info
    if x.shape[0] % n_dp or x.shape[1] % n_model:
        return None
    return mesh, dp


def _qkv_plan_lower(plan, x, wq, wk, wv, *, bq=None, bk=None, bv=None):
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh, dp = plan
    n_model = mesh.shape["model"]
    w, b = _qkv_concat(x, wq, wk, wv, bq, bk, bv)
    if b is None:
        b = jnp.zeros((w.shape[1],), x.dtype)

    def local(xl, wl, bl):
        s = xl.shape[1]
        seg = s // n_model
        idx = lax.axis_index("model")
        xs = lax.dynamic_slice_in_dim(xl, idx * seg, seg, axis=1)
        y = lax.dot_general(
            xs, wl, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(xl.dtype) + bl.astype(xl.dtype)
        return lax.all_gather(y, "model", axis=1, tiled=True)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), P(None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )
    return fn(x, w, b)


register(OffloadOp(
    name="qkv_project",
    cost=_qkv_cost,
    host=_qkv_host,
    pallas=_qkv_pallas,
    eligible=_qkv_eligible,
    plan=_qkv_plan,
    plan_lower=_qkv_plan_lower,
))


def _gemm_batched_cost(a, b, *, out_dtype=None):
    if a.ndim != 3 or b.ndim != 3 or a.shape[0] != b.shape[0]:
        raise ValueError(f"gemm_batched: bad shapes {a.shape} @ {b.shape}")
    bsz, m, k = a.shape
    _, kb, n = b.shape
    if k != kb:
        raise ValueError(
            f"gemm_batched contraction mismatch: {a.shape} @ {b.shape}"
        )
    return cm.gemm_cost(
        m, n, k, jnp.dtype(a.dtype).itemsize, batch=bsz, op="gemm_batched"
    )


def _gemm_batched_eligible(a, b, *, out_dtype=None):
    _, m, k = a.shape
    n = b.shape[2]
    return _pallas_gemm_eligible(m, n, k, a.dtype)


def _gemm_batched_host(a, b, *, out_dtype=None):
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    return _accum_dot(a, b, (((2,), (1,)), ((0,), (0,))), out_dtype)


def _gemm_batched_pallas(a, b, *, out_dtype=None, interpret=False):
    return _kops().pallas_lowering("gemm_batched")(
        a, b,
        out_dtype=out_dtype or jnp.result_type(a.dtype, b.dtype),
        interpret=interpret,
    )


register(OffloadOp(
    name="gemm_batched",
    cost=_gemm_batched_cost,
    host=_gemm_batched_host,
    pallas=_gemm_batched_pallas,
    eligible=_gemm_batched_eligible,
))


def _expert_dims(x, w):
    if w.ndim != 3 or x.shape[0] != w.shape[0] or x.shape[-1] != w.shape[1]:
        raise ValueError(f"expert_matmul: bad shapes {x.shape} @ {w.shape}")
    e = x.shape[0]
    m = 1
    for dim in x.shape[1:-1]:
        m *= dim
    k, n = w.shape[1], w.shape[2]
    return e, m, k, n


def _expert_cost(x, w, *, out_dtype=None):
    e, m, k, n = _expert_dims(x, w)
    return cm.gemm_cost(
        m, n, k, jnp.dtype(x.dtype).itemsize, batch=e, op="moe_gemm"
    )


def _expert_eligible(x, w, *, out_dtype=None):
    e, m, k, n = _expert_dims(x, w)
    return _pallas_gemm_eligible(m, n, k, x.dtype)


def _expert_host(x, w, *, out_dtype=None):
    return _accum_dot(
        x, w, (((x.ndim - 1,), (1,)), ((0,), (0,))),
        out_dtype or jnp.result_type(x.dtype, w.dtype),
    )


def _expert_pallas(x, w, *, out_dtype=None, interpret=False):
    e, m, k, n = _expert_dims(x, w)
    out = _kops().pallas_lowering("moe_gemm")(
        x.reshape(e, m, k), w, out_dtype=out_dtype or x.dtype,
        interpret=interpret,
    )
    return out.reshape(*x.shape[:-1], n)


register(OffloadOp(
    name="expert_matmul",
    cost=_expert_cost,
    host=_expert_host,
    pallas=_expert_pallas,
    eligible=_expert_eligible,
))


# ---------------------------------------------------------------------------
# moe_expert_ffn — the whole grouped expert FFN (gate/up/silu/down) behind
# one descriptor.  The MoE layer used to issue three separate expert GEMM
# dispatches (and the explicit-collective path three bare engine().launch
# accounting calls); now the cost model sees the whole expert block at once
# and the expert-parallel shard_map — experts model-sharded, every GEMM
# chip-local, zero collectives — is its `plan`.
# ---------------------------------------------------------------------------

def _moe_ffn_dims(x, wg, wu, wd):
    if x.ndim < 3 or wg.ndim != 3 or wu.ndim != 3 or wd.ndim != 3:
        raise ValueError(
            f"moe_expert_ffn: bad ranks {x.shape} {wg.shape} {wu.shape} {wd.shape}"
        )
    e, d = x.shape[0], x.shape[-1]
    f = wg.shape[2]
    if wg.shape[:2] != (e, d) or wu.shape != wg.shape:
        raise ValueError(f"moe_expert_ffn: bad gate/up {wg.shape} {wu.shape}")
    if tuple(wd.shape) != (e, f, d):
        raise ValueError(f"moe_expert_ffn: bad down {wd.shape}, want {(e, f, d)}")
    m = 1
    for dim in x.shape[1:-1]:
        m *= dim
    return e, m, d, f


def _moe_ffn_cost(x, wg, wu, wd):
    e, m, d, f = _moe_ffn_dims(x, wg, wu, wd)
    return cm.gemm_cost(
        m, 3 * f, d, jnp.dtype(x.dtype).itemsize, batch=e, op="moe_expert_ffn"
    )


def _moe_ffn_eligible(x, wg, wu, wd):
    e, m, d, f = _moe_ffn_dims(x, wg, wu, wd)
    return _pallas_gemm_eligible(m, f, d, x.dtype)


def _moe_ffn_local(x, wg, wu, wd):
    """The expert FFN math itself (fp32 accumulation) — shared by the host
    lowering and the plan's shard_map body."""
    dn = (((x.ndim - 1,), (1,)), ((0,), (0,)))
    g = _accum_dot(x, wg, dn, x.dtype)
    u = _accum_dot(x, wu, dn, x.dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return _accum_dot(h, wd, dn, x.dtype)


def _moe_ffn_host(x, wg, wu, wd):
    return _moe_ffn_local(x, wg, wu, wd)


def _moe_ffn_pallas(x, wg, wu, wd, *, interpret=False):
    e, m, d, f = _moe_ffn_dims(x, wg, wu, wd)
    mm = _kops().pallas_lowering("moe_expert_ffn")
    xe = x.reshape(e, m, d)
    g = mm(xe, wg, out_dtype=x.dtype, interpret=interpret)
    u = mm(xe, wu, out_dtype=x.dtype, interpret=interpret)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = mm(h, wd, out_dtype=x.dtype, interpret=interpret)
    return y.reshape(*x.shape[:-1], d)


def _moe_ffn_plan(x, wg, wu, wd):
    """Expert-parallel applicability: experts shard over the model axis and
    every GEMM stays chip-local (zero collectives inside the plan).  The
    first free dim additionally shards over the data axes when it divides
    (the grouped/EP dispatch layouts both arrange for this)."""
    info = _tp_mesh_info()
    if info is None:
        return None
    mesh, n_model, dp, n_dp = info
    if x.shape[0] % n_model:
        return None
    shard_free = bool(dp) and x.ndim >= 3 and x.shape[1] % n_dp == 0
    return mesh, (dp if shard_free else ())


def _moe_ffn_plan_lower(plan, x, wg, wu, wd):
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh, dp = plan
    free = (dp if dp else None,) + (None,) * (x.ndim - 2)
    spec_x = P("model", *free)
    spec_w = P("model", None, None)
    fn = shard_map(
        _moe_ffn_local,
        mesh=mesh,
        in_specs=(spec_x, spec_w, spec_w, spec_w),
        out_specs=spec_x,
        check_vma=False,
    )
    return fn(x, wg, wu, wd)


register(OffloadOp(
    name="moe_expert_ffn",
    cost=_moe_ffn_cost,
    host=_moe_ffn_host,
    pallas=_moe_ffn_pallas,
    eligible=_moe_ffn_eligible,
    plan=_moe_ffn_plan,
    plan_lower=_moe_ffn_plan_lower,
))


# ---------------------------------------------------------------------------
# ssd_scan — the whole Mamba-2 SSD core (chunked quadratic term + inter-chunk
# state recurrence + D skip) behind one descriptor.  The SSM layer used to
# run this inside a hand-rolled whole-block shard_map with bare
# engine().launch accounting; now the per-head shard_map — SSM heads
# model-sharded, all math chip-local, zero collectives — is its `plan` and
# the ``ssd_chunk_diag`` Pallas kernel its device form.
# ---------------------------------------------------------------------------

def _ssd_dims(xh, dt, a, bh, ch, d_skip, *, chunk):
    if xh.ndim != 4:
        raise ValueError(f"ssd_scan: x must be (B, S, H, P), got {xh.shape}")
    bsz, s, h, pdim = xh.shape
    n = bh.shape[-1]
    if dt.shape != (bsz, s, h):
        raise ValueError(f"ssd_scan: dt {dt.shape} != {(bsz, s, h)}")
    if a.shape != (h,) or d_skip.shape != (h,):
        raise ValueError(f"ssd_scan: a/d_skip must be ({h},)")
    if bh.shape != (bsz, s, h, n) or ch.shape != (bsz, s, h, n):
        raise ValueError(f"ssd_scan: bad B/C {bh.shape} {ch.shape}")
    q = min(int(chunk), s)
    if s % q:
        raise ValueError(f"ssd_scan: seq {s} not divisible by chunk {q}")
    return bsz, s, h, pdim, n, q


def _ssd_cost(xh, dt, a, bh, ch, d_skip, *, chunk):
    bsz, s, h, pdim, n, q = _ssd_dims(xh, dt, a, bh, ch, d_skip, chunk=chunk)
    return cm.gemm_cost(
        bsz * s, 2 * n + pdim, q, jnp.dtype(xh.dtype).itemsize, batch=h,
        op="ssd_scan",
    )


def _ssd_eligible(xh, dt, a, bh, ch, d_skip, *, chunk):
    bsz, s, h, pdim, n, q = _ssd_dims(xh, dt, a, bh, ch, d_skip, chunk=chunk)
    return min(pdim, n, q) >= 8 and xh.dtype in (jnp.float32, jnp.bfloat16)


def _ssd_scan_math(xh, dt, a, bh, ch, d_skip, chunk, diag_fn):
    """Chunked SSD core: (B, S, H, P) -> (B, S, H, P) fp32.  ``diag_fn``
    computes the within-chunk quadratic term (jnp oracle or Pallas kernel);
    the (N, P)-state inter-chunk recurrence stays a ``lax.scan``.  All math
    is per-head — under the plan's shard_map each device runs this on its
    local heads with zero collectives."""
    bsz, s, h, pdim = xh.shape
    n = bh.shape[-1]
    q = min(int(chunk), s)
    nc = s // q
    da = dt * a                                               # (B, S, H)
    xdt = xh * dt[..., None]

    def to_bh(t):
        t = t.reshape(bsz, nc, q, h, -1).transpose(0, 3, 1, 2, 4)
        return t.reshape(bsz * h, nc, q, t.shape[-1])

    da_c = da.reshape(bsz, nc, q, h)
    cum_c = jnp.cumsum(da_c, axis=2)                          # (B, C, Q, H)
    cum_bh = cum_c.transpose(0, 3, 1, 2).reshape(bsz * h, nc, q)

    x_bh = to_bh(xdt).astype(jnp.float32)
    b_bh = to_bh(bh).astype(jnp.float32)
    c_bh = to_bh(ch).astype(jnp.float32)

    y_diag = diag_fn(x_bh, cum_bh, b_bh, c_bh)

    decay_to_end = jnp.exp(cum_bh[:, :, -1:] - cum_bh)
    states = jnp.einsum("zcq,zcqn,zcqp->zcnp", decay_to_end, b_bh, x_bh)
    chunk_decay = jnp.exp(cum_bh[:, :, -1])

    def scan_fn(carry, inp):
        st, dec = inp
        prev = carry
        return dec[:, None, None] * prev + st, prev

    init = jnp.zeros((bsz * h, n, pdim), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init, (states.transpose(1, 0, 2, 3), chunk_decay.T)
    )
    prev_states = prev_states.transpose(1, 0, 2, 3)

    y_off = jnp.einsum(
        "zcqn,zcnp,zcq->zcqp", c_bh, prev_states, jnp.exp(cum_bh)
    )
    y = (y_diag.astype(jnp.float32) + y_off)
    y = y.reshape(bsz, h, s, pdim).transpose(0, 2, 1, 3)
    return y + xh.astype(jnp.float32) * d_skip[None, None, :, None]


def _ssd_host(xh, dt, a, bh, ch, d_skip, *, chunk):
    from repro.kernels import ref as kref  # lazy: avoid import cycle

    return _ssd_scan_math(xh, dt, a, bh, ch, d_skip, chunk,
                          kref.ssd_chunk_diag_ref)


def _ssd_pallas(xh, dt, a, bh, ch, d_skip, *, chunk, interpret=False):
    kernel = _kops().pallas_lowering("ssd_scan")

    def diag(x_bh, cum_bh, b_bh, c_bh):
        return kernel(x_bh, cum_bh, b_bh, c_bh, interpret=interpret)

    return _ssd_scan_math(xh, dt, a, bh, ch, d_skip, chunk, diag)


def _ssd_plan(xh, dt, a, bh, ch, d_skip, *, chunk):
    """Head-sharded TP applicability: every piece of the SSD math is
    per-head and therefore chip-local under a model-sharded head axis."""
    info = _tp_mesh_info()
    if info is None or xh.ndim != 4:
        return None
    mesh, n_model, dp, n_dp = info
    bsz, s, h, _ = xh.shape
    if h % n_model or bsz % n_dp or s % min(int(chunk), s):
        return None
    return mesh, dp


def _ssd_plan_lower(plan, xh, dt, a, bh, ch, d_skip, *, chunk):
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh, dp = plan

    def local(xl, dtl, al, bl, cl, dl):
        from repro.kernels import ref as kref

        return _ssd_scan_math(xl, dtl, al, bl, cl, dl, chunk,
                              kref.ssd_chunk_diag_ref)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dp, None, "model", None), P(dp, None, "model"), P("model"),
            P(dp, None, "model", None), P(dp, None, "model", None),
            P("model"),
        ),
        out_specs=P(dp, None, "model", None),
        check_vma=False,
    )
    return fn(xh, dt, a, bh, ch, d_skip)


register(OffloadOp(
    name="ssd_scan",
    cost=_ssd_cost,
    host=_ssd_host,
    pallas=_ssd_pallas,
    eligible=_ssd_eligible,
    plan=_ssd_plan,
    plan_lower=_ssd_plan_lower,
))


def _syrk_cost(a, *, out_dtype=None):
    if a.ndim != 2:
        raise ValueError(f"syrk takes a 2-D operand, got {a.shape}")
    n, k = a.shape
    return cm.syrk_cost(n, k, jnp.dtype(a.dtype).itemsize)


def _syrk_host(a, *, out_dtype=None):
    return _accum_dot(a, a, (((1,), (1,)), ((), ())), out_dtype or a.dtype)


register(OffloadOp(
    name="syrk",
    cost=_syrk_cost,
    host=_syrk_host,
    host_only=True,
    note="host-only (syrk.c compiled for host, per paper)",
))


def _attention_cost(
    q, k, v, *, causal=True, window=None, sm_scale=None, kv_mask=None
):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    static_window = (
        window if (window is None or isinstance(window, int)) else None
    )
    return cm.attention_cost(
        b, sq, skv, hq, d, jnp.dtype(q.dtype).itemsize,
        window=static_window if static_window and static_window < skv else None,
    )


def _attention_eligible(
    q, k, v, *, causal=True, window=None, sm_scale=None, kv_mask=None
):
    # The Pallas flash kernel needs a static window (traced per-layer window
    # patterns fall back to the masked-einsum host path) and no kv_mask.
    static = window is None or isinstance(window, int)
    return (
        static
        and kv_mask is None
        and q.shape[-1] >= 8
        and q.dtype in (jnp.float32, jnp.bfloat16)
    )


def _attention_pallas(
    q, k, v, *, causal=True, window=None, sm_scale=None, kv_mask=None,
    interpret=False,
):
    skv = k.shape[2]
    eff_window = None if (window is None or window >= skv) else window
    return _kops().pallas_lowering("attention")(
        q, k, v,
        causal=causal,
        window=eff_window,
        sm_scale=sm_scale,
        interpret=interpret,
    )


def _attention_host(
    q, k, v, *, causal=True, window=None, sm_scale=None, kv_mask=None
):
    return attention_math(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        kv_mask=kv_mask,
    )


register(OffloadOp(
    name="attention",
    cost=_attention_cost,
    host=_attention_host,
    pallas=_attention_pallas,
    eligible=_attention_eligible,
))


# ---------------------------------------------------------------------------
# decode_attention — one-token attention against a (possibly rolling) KV
# cache with a [lo, hi) valid-slot range.  The decode layer used a bare
# engine().launch + hand-routed backend branch; as a descriptor the masked
# math is the host lowering and the flash-decode kernel (one HBM pass over
# the cache) the Pallas lowering.
# ---------------------------------------------------------------------------

def _decode_attn_cost(q, k, v, lo, hi):
    if q.ndim != 4 or q.shape[2] != 1:
        raise ValueError(f"decode_attention: q must be (B, Hq, 1, D), got {q.shape}")
    b, hq, _, d = q.shape
    if k.ndim != 4 or v.shape != k.shape or k.shape[0] != b or k.shape[3] != d:
        raise ValueError(f"decode_attention: bad cache {k.shape} / {v.shape}")
    skv = k.shape[2]
    return cm.attention_cost(b, 1, skv, hq, d, jnp.dtype(q.dtype).itemsize)


def _decode_attn_eligible(q, k, v, lo, hi):
    return q.shape[-1] >= 8 and q.dtype in (jnp.float32, jnp.bfloat16)


def _decode_attn_host(q, k, v, lo, hi):
    slots = jnp.arange(k.shape[2], dtype=jnp.int32)
    kv_valid = jnp.logical_and(slots >= lo, slots < hi)
    return attention_math(q, k, v, causal=False, kv_mask=kv_valid)


def _decode_attn_pallas(q, k, v, lo, hi, *, interpret=False):
    b = q.shape[0]
    lo_b = jnp.broadcast_to(lo, (b,)).astype(jnp.int32)
    hi_b = jnp.broadcast_to(hi, (b,)).astype(jnp.int32)
    out = _kops().pallas_lowering("decode_attention")(
        q[:, :, 0, :], k, v, lo_b, hi_b, interpret=interpret
    )
    return out[:, :, None, :]


register(OffloadOp(
    name="decode_attention",
    cost=_decode_attn_cost,
    host=_decode_attn_host,
    pallas=_decode_attn_pallas,
    eligible=_decode_attn_eligible,
))


# ---------------------------------------------------------------------------
# Level-2 / Level-1 descriptors (host lowering only; still scored + routed,
# so traces show whether the decision model would offload them)
# ---------------------------------------------------------------------------

def _gemv_cost(a, x, *, out_dtype=None):
    if a.ndim != 2 or x.ndim != 1 or a.shape[1] != x.shape[0]:
        raise ValueError(f"gemv: bad shapes {a.shape} @ {x.shape}")
    m, n = a.shape
    return cm.gemv_cost(m, n, jnp.dtype(a.dtype).itemsize)


def _gemv_host(a, x, *, out_dtype=None):
    out_dtype = out_dtype or jnp.result_type(a.dtype, x.dtype)
    return _accum_dot(a, x, (((1,), (0,)), ((), ())), out_dtype)


register(OffloadOp(name="gemv", cost=_gemv_cost, host=_gemv_host))


def _dot_cost(x, y):
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"dot: bad shapes {x.shape}, {y.shape}")
    return cm.vector_cost("dot", x.shape[0], jnp.dtype(x.dtype).itemsize)


def _dot_host(x, y):
    return jnp.sum(
        x.astype(jnp.float32) * y.astype(jnp.float32)
    ).astype(x.dtype)


register(OffloadOp(name="dot", cost=_dot_cost, host=_dot_host))


def _axpy_cost(alpha, x, y):
    return cm.vector_cost("axpy", x.size, jnp.dtype(x.dtype).itemsize)


def _axpy_host(alpha, x, y):
    return alpha * x + y


register(OffloadOp(name="axpy", cost=_axpy_cost, host=_axpy_host))


def _scal_cost(alpha, x):
    return cm.vector_cost("scal", x.size, jnp.dtype(x.dtype).itemsize, 1.0)


def _scal_host(alpha, x):
    return alpha * x


register(OffloadOp(name="scal", cost=_scal_cost, host=_scal_host))


def _nrm2_cost(x):
    return cm.vector_cost("nrm2", x.size, jnp.dtype(x.dtype).itemsize)


def _nrm2_host(x):
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)))).astype(x.dtype)


register(OffloadOp(name="nrm2", cost=_nrm2_cost, host=_nrm2_host))


# ---------------------------------------------------------------------------
# Light reductions / elementwise ops — host-only descriptors so the auto
# policy can score them and the trace sees them (they never pay to offload
# alone; the graph frontend fuses them into producer launches instead).
# ---------------------------------------------------------------------------

def _light_cost(op_name, flops_per_elem=2.0):
    def cost(x, *rest, **kwargs):
        return cm.vector_cost(
            op_name, x.size, jnp.dtype(x.dtype).itemsize, flops_per_elem
        )

    return cost


def _sum_host(x, *, axis=None, keepdims=False):
    return jnp.sum(x, axis=axis, keepdims=keepdims)


def _mean_host(x, *, axis=None, keepdims=False):
    return jnp.mean(x, axis=axis, keepdims=keepdims)


def _relu_host(x):
    return jax.nn.relu(x)


def _silu_host(x):
    return jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)


def _rmsnorm_cost(x, scale, *, eps=1e-6):
    if x.shape[-1] != scale.shape[-1]:
        raise ValueError(
            f"rmsnorm_scale: scale {scale.shape} does not match {x.shape}"
        )
    return cm.vector_cost(
        "rmsnorm_scale", x.size, jnp.dtype(x.dtype).itemsize, 4.0
    )


def _rmsnorm_host(x, scale, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


register(OffloadOp(name="sum", cost=_light_cost("sum", 1.0), host=_sum_host,
                   host_only=True, note="light reduction (host-only)"))
register(OffloadOp(name="mean", cost=_light_cost("mean", 1.0), host=_mean_host,
                   host_only=True, note="light reduction (host-only)"))
register(OffloadOp(name="relu", cost=_light_cost("relu", 1.0), host=_relu_host,
                   host_only=True, note="light elementwise (host-only)"))
register(OffloadOp(name="silu", cost=_light_cost("silu", 4.0), host=_silu_host,
                   host_only=True, note="light elementwise (host-only)"))
register(OffloadOp(name="rmsnorm_scale", cost=_rmsnorm_cost,
                   host=_rmsnorm_host, host_only=True,
                   note="norm epilogue (host-only)"))


# ---------------------------------------------------------------------------
# Public API — thin wrappers over dispatch()
# ---------------------------------------------------------------------------

def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    transpose_a: bool = False,
    transpose_b: bool = False,
    out_dtype=None,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """C = op(A) @ op(B) for 2-D operands, routed through the offload seam."""
    return dispatch(
        "gemm", a, b, transpose_a=transpose_a, transpose_b=transpose_b,
        out_dtype=out_dtype, handle=handle,
    )


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    out_dtype=None,
    tp_mode: Optional[str] = None,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """General (leading-batch, k) @ (k, n) — the framework's workhorse.

    Collapses leading dims into the GEMM ``m`` dimension, exactly how a BLAS
    binding flattens a NumPy ``ndarray @ matrix``.  ``tp_mode`` ("row"/"col")
    opts into the explicit tensor-parallel path with bf16 reductions when an
    ambient mesh allows it (§Perf hillclimb #2).
    """
    return dispatch(
        "matmul", x, w, out_dtype=out_dtype, tp_mode=tp_mode, handle=handle
    )


def gemm_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    out_dtype=None,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """(B, m, k) @ (B, k, n) batched GEMM (attention scores/values)."""
    return dispatch("gemm_batched", a, b, out_dtype=out_dtype, handle=handle)


def linear(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    out_dtype=None,
    tp_mode: Optional[str] = None,
) -> jax.Array:
    """y = x @ w (+ b) — convenience wrapper used by every model layer."""
    y = matmul(x, w, out_dtype=out_dtype, tp_mode=tp_mode)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def mlp_block(
    x: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    gate: Optional[jax.Array] = None,
    b_up: Optional[jax.Array] = None,
    b_down: Optional[jax.Array] = None,
    kind: str = "swiglu",
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """Whole dense FFN (SwiGLU / GELU) through the offload seam.

    One dispatch for the block: the cost model sees all 2–3 projections at
    once, the TP shard_map form (single bf16 psum) is resolved as a plan
    *before* the record is written, and the Pallas path runs the projections
    on the hand-tiled MXU GEMM kernel.  Replaces the model layers' raw
    ``lax.dot_general``-inside-``shard_map`` launch sites."""
    return dispatch(
        "mlp_block", x, w_up, w_down, gate, b_up, b_down, kind=kind,
        handle=handle,
    )


def qkv_project(
    x: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    *,
    bq: Optional[jax.Array] = None,
    bk: Optional[jax.Array] = None,
    bv: Optional[jax.Array] = None,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """Fused q/k/v input projection through the offload seam.

    Returns the concatenated ``(..., (Hq + 2·Hkv)·hd)`` projection; callers
    split and reshape into heads.  One dispatch for all three projections:
    the cost model sees the whole input-projection workload, the
    sequence-sharded TP shard_map is resolved as a plan *before* the record
    is written, and the Pallas path runs one hand-tiled MXU GEMM over the
    concatenated weights.  Replaces the attention layer's raw
    ``lax.dot_general``-inside-``shard_map`` launch sites."""
    return dispatch(
        "qkv_project", x, wq, wk, wv, bq=bq, bk=bk, bv=bv, handle=handle
    )


def ssd_scan(
    xh: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    bh: jax.Array,
    ch: jax.Array,
    d_skip: jax.Array,
    *,
    chunk: int,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """Whole Mamba-2 SSD core through the offload seam.

    xh: (B, S, H, P); dt: (B, S, H) fp32; a, d_skip: (H,); bh, ch:
    (B, S, H, N).  Returns the fp32 (B, S, H, P) mixer output (within-chunk
    quadratic term + inter-chunk state recurrence + D skip).  The head-
    sharded TP shard_map is its plan (zero collectives — all SSD math is
    per-head); the ``ssd_chunk_diag`` Pallas kernel its device form."""
    return dispatch(
        "ssd_scan", xh, dt, a, bh, ch, d_skip, chunk=chunk, handle=handle
    )


def moe_expert_ffn(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    *,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """Whole grouped expert FFN (E, ..., d) -> (E, ..., d) through the seam.

    One dispatch for gate/up/silu/down across all experts; the expert-
    parallel shard_map (experts model-sharded, zero collectives) is its
    plan, the grouped MXU GEMM kernel its Pallas lowering.  Keeps all free
    dims intact — merging a sharded dim in a reshape forces GSPMD to
    all-gather, so MoE layouts stay (E, G, C, d) through the block."""
    return dispatch("moe_expert_ffn", x, wg, wu, wd, handle=handle)


def moe_expert_ffn_placed(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    *,
    placement,
):
    """Grouped expert FFN with per-expert placed accounting.

    Same op, same math, same single dispatch graph as
    :func:`moe_expert_ffn` — but ``placement`` (an
    ``repro.core.placement.ExpertDispatchPlan``) fans the accounting out
    into one handle-affine sub-launch per expert copy, charged on the lane
    its weights live on.  Returns ``(out, launch)`` so callers can read
    the busiest lane back."""
    return dispatch_placed(
        "moe_expert_ffn", x, wg, wu, wd, placement=placement
    )


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """One-token decode attention against a KV cache through the seam.

    q: (B, Hq, 1, D); caches: (B, Hkv, S_cache, D); ``lo``/``hi`` bound the
    valid cache slots (rolling SWA buffers wrap).  Host form is the
    shardable masked math; the Pallas form streams the cache once
    (``flash_decode``).  ``handle`` pins the call to the device-resident
    cache so affinity scheduling routes decode to the data."""
    return dispatch(
        "decode_attention", q, k_cache, v_cache, lo, hi, handle=handle
    )


def expert_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    out_dtype=None,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """(E, ..., d) @ (E, d, f) -> (E, ..., f) — expert-batched contraction.

    Keeps all free dims intact (no reshape): merging a sharded dim in a
    reshape forces GSPMD to all-gather, so MoE keeps its (E, G, C, d)
    layout 4-D through the expert GEMMs."""
    return dispatch("expert_matmul", x, w, out_dtype=out_dtype, handle=handle)


def syrk(a: jax.Array, *, out_dtype=None) -> jax.Array:
    """C = A @ A.T — host-only, as in the paper's build."""
    return dispatch("syrk", a, out_dtype=out_dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    sm_scale: Optional[float] = None,
    kv_mask: Optional[jax.Array] = None,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    """Fused attention through the offload seam.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  ``window`` may be a static int
    or a *traced scalar* (per-layer local/global patterns scanned as data);
    the Pallas flash kernel requires a static window, so traced windows fall
    back to the masked-einsum host path (still fully shardable).
    Queries align to the end of kv when Sq < Skv (decode / suffix).
    ``handle`` pins the call to a device-resident buffer (e.g. a KV cache).
    """
    return dispatch(
        "attention", q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        kv_mask=kv_mask, handle=handle,
    )


def attention_math(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    sm_scale: Optional[float] = None,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Raw masked/online-softmax attention math (no dispatch/accounting).

    Used by the host path of :func:`attention` and, per-shard, by the
    tensor-parallel attention block (each device runs it on its local q
    heads)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    scale = sm_scale if sm_scale is not None else d ** -0.5
    group = hq // hkv
    # GQA via kv-repeat to q heads: attention then partitions on the q-head
    # dim (sharded over `model`); a (hkv, group) reshape of a sharded head
    # dim is not expressible as a PartitionSpec and forces an all-gather.
    qf = q.astype(jnp.float32)
    kf = (jnp.repeat(k, group, axis=1) if group > 1 else k).astype(jnp.float32)
    vf = (jnp.repeat(v, group, axis=1) if group > 1 else v).astype(jnp.float32)

    def mask_for(q_pos, kv_pos):
        m = jnp.ones(jnp.broadcast_shapes(q_pos.shape, kv_pos.shape), jnp.bool_)
        if causal:
            m = jnp.logical_and(m, kv_pos <= q_pos)
        if window is not None:
            w = jnp.asarray(window, jnp.int32)
            m = jnp.logical_and(m, (q_pos - kv_pos) < w)
        return m

    if skv <= _DIRECT_ATTN_MAX_KV or sq == 1:
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
        q_pos = (skv - sq) + lax.broadcasted_iota(jnp.int32, (sq, skv), 0)
        kv_pos = lax.broadcasted_iota(jnp.int32, (sq, skv), 1)
        s = jnp.where(mask_for(q_pos, kv_pos)[None, None], s, -1e30)
        if kv_mask is not None:  # (Skv,) or (B, Skv) slot validity (decode)
            km = kv_mask if kv_mask.ndim == 2 else kv_mask[None]
            s = jnp.where(km[:, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        # fully-masked rows contribute zeros (matches the kernel semantics)
        p = jnp.where(
            jnp.max(s, axis=-1, keepdims=True) <= -1e30 * 0.5, 0.0, p
        )
        out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
        return out.astype(q.dtype)

    if kv_mask is not None:
        raise NotImplementedError("kv_mask only supported on the direct path")
    bkv = min(_CHUNKED_ATTN_BLOCK, skv)
    while skv % bkv:
        bkv //= 2
    assert bkv >= 1
    nkv = skv // bkv
    kc = kf.reshape(b, hq, nkv, bkv, d).transpose(2, 0, 1, 3, 4)
    vc = vf.reshape(b, hq, nkv, bkv, d).transpose(2, 0, 1, 3, 4)
    q_pos = (skv - sq) + lax.broadcasted_iota(jnp.int32, (sq, 1), 0)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, j = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb) * scale  # noqa: F841 traced nkv times (scaled at launch above)
        kv_pos = j * bkv + lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        mask = mask_for(q_pos, kv_pos)  # (sq, bkv)
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pj = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(pj, axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum("bhqk,bhkd->bhqd", pj, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hq, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, sq, 1), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    (m_f, l_f, acc_f), _ = lax.scan(
        body, (m0, l0, acc0), (kc, vc, jnp.arange(nkv, dtype=jnp.int32))
    )
    out = acc_f / jnp.maximum(l_f, 1e-30)
    return out.astype(q.dtype)


def gemv(
    a: jax.Array,
    x: jax.Array,
    *,
    out_dtype=None,
    handle: Optional[DeviceHandle] = None,
) -> jax.Array:
    return dispatch("gemv", a, x, out_dtype=out_dtype, handle=handle)


def dot(x: jax.Array, y: jax.Array) -> jax.Array:
    return dispatch("dot", x, y)


def axpy(alpha, x: jax.Array, y: jax.Array) -> jax.Array:
    return dispatch("axpy", alpha, x, y)


def scal(alpha, x: jax.Array) -> jax.Array:
    return dispatch("scal", alpha, x)


def nrm2(x: jax.Array) -> jax.Array:
    return dispatch("nrm2", x)


def reduce_sum(x: jax.Array, *, axis=None, keepdims: bool = False) -> jax.Array:
    """Scored + traced sum reduction (host-only descriptor)."""
    return dispatch("sum", x, axis=axis, keepdims=keepdims)


def reduce_mean(x: jax.Array, *, axis=None, keepdims: bool = False) -> jax.Array:
    """Scored + traced mean reduction (host-only descriptor)."""
    return dispatch("mean", x, axis=axis, keepdims=keepdims)


def relu(x: jax.Array) -> jax.Array:
    return dispatch("relu", x)


def silu(x: jax.Array) -> jax.Array:
    return dispatch("silu", x)


def rmsnorm_scale(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """RMSNorm (fp32 internals) through the seam — the norm epilogue every
    block pays, visible to the trace and scoreable by the auto policy."""
    return dispatch("rmsnorm_scale", x, scale, eps=eps)
