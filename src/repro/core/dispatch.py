"""Declarative offload-op registry — one dispatch path for every BLAS op.

The paper's architecture is a *single* stable seam (OpenBLAS behind
``#pragma omp target``) where all offload decisions live.  Before this
module, each op in ``repro.core.blas`` hand-rolled the same ritual —
score the call, ask the engine for a backend, branch to a lowering,
record the trace — and the copies had drifted (some dropped the device
placement, some never could go to Pallas).  Here the ritual exists once:

* an :class:`OffloadOp` *describes* an op — how to cost it, how to lower
  it on the host (XLA) path, how to lower it through the hand-written
  Pallas kernels, when the Pallas form is legal, and whether the op is
  host-only (the paper compiles ``syrk.c`` for the host alone);
* :func:`register` puts the descriptor in the process-wide table;
* :func:`dispatch` is the engine: it resolves routing (explicit-TP plan
  -> Pallas -> host) *before* recording, threads the chosen ``device_id``
  into every trace record via :meth:`HeroCluster.launch`, and runs the
  winning lowering.

Adding an op to the seam is now declarative: write its lowerings, build
an ``OffloadOp``, ``register`` it — no new dispatch code.  Callers that
hold a :class:`~repro.core.hero.DeviceHandle` (a device-residency token,
e.g. a pinned KV cache) pass it through ``dispatch(..., handle=...)`` so
placement-affine schedulers route the work to the data.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.cost_model import OpCost
from repro.core.hero import DeviceHandle, engine
from repro.obs import spans as _spans

__all__ = [
    "DeviceHandle",
    "OffloadOp",
    "dispatch",
    "dispatch_placed",
    "get_op",
    "register",
    "registered_ops",
]


def shape_key(*arrs) -> str:
    """Canonical static-shape signature of the operands (ledger key)."""
    return ";".join("x".join(map(str, a.shape)) + f":{a.dtype}" for a in arrs)


@dataclasses.dataclass(frozen=True)
class OffloadOp:
    """Descriptor for one op behind the offload seam.

    ``cost``, ``eligible`` and ``plan`` see the op's full call signature
    (``(*args, **kwargs)``) and must be pure shape-level functions — they
    run at trace time.  ``cost`` also owns operand validation, so a bad
    call fails before anything is scheduled or recorded.

    host       — XLA lowering; also serves the plain "device" backend
                 (residency/accounting distinction, same graph).
    pallas     — hand-tiled kernel lowering; receives ``interpret=`` from
                 the active policy.  None => op never takes the Pallas path.
    eligible   — shape/dtype legality gate for ``pallas`` (tile fit etc.).
    plan       — optional pre-route inspection (e.g. explicit-TP shard_map
                 applicability); a non-None plan wins over Pallas and is
                 lowered by ``plan_lower(plan, *args, **kwargs)``.
    host_only  — never offloaded (recorded with the host backend).
    """

    name: str
    cost: Callable[..., OpCost]
    host: Callable[..., Any]
    pallas: Optional[Callable[..., Any]] = None
    eligible: Optional[Callable[..., bool]] = None
    plan: Optional[Callable[..., Any]] = None
    plan_lower: Optional[Callable[..., Any]] = None
    host_only: bool = False
    note: str = ""


_REGISTRY: Dict[str, OffloadOp] = {}


def _descriptor_sig(op: OffloadOp) -> tuple:
    """Source-level identity of a descriptor (stable across module reloads,
    where re-executed ``def``s produce fresh function objects)."""

    def fsig(f):
        if f is None:
            return None
        # module + qualname alone would collapse all module-level lambdas to
        # ('<mod>', '<lambda>'); the code location keeps *different* lambdas
        # distinct while staying stable across importlib reloads (re-executed
        # defs keep their file and line).
        code = getattr(f, "__code__", None)
        loc = (code.co_filename, code.co_firstlineno) if code else None
        return (
            getattr(f, "__module__", None),
            getattr(f, "__qualname__", None),
            loc,
        )

    return (
        op.name, op.host_only, op.note,
        fsig(op.cost), fsig(op.host), fsig(op.pallas),
        fsig(op.eligible), fsig(op.plan), fsig(op.plan_lower),
    )


def register(op: OffloadOp) -> OffloadOp:
    """Add a descriptor to the op table.

    Idempotent for the same descriptor, including across ``importlib``
    reloads of the defining module (functions are compared by
    module + qualname, not object identity); registering a *different*
    descriptor under a taken name raises.
    """
    prev = _REGISTRY.get(op.name)
    if (
        prev is not None
        and prev != op
        and _descriptor_sig(prev) != _descriptor_sig(op)
    ):
        raise ValueError(f"op {op.name!r} already registered")
    _REGISTRY[op.name] = op
    return op


def get_op(name: str) -> OffloadOp:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown offload op {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_ops() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def dispatch(
    name: str,
    *args,
    handle: Optional[DeviceHandle] = None,
    resident_fraction: Optional[float] = None,
    validate: bool = False,
    **kwargs,
):
    """Route one registered op through the offload seam and execute it.

    The single cost -> plan -> launch -> lower path every op shares:

    1. ``op.cost(*args, **kwargs)`` validates operands and scores the call;
    2. ``op.plan`` (if any) resolves special routing *before* the record is
       written — the trace must name the path that actually runs;
    3. ``engine().launch`` picks backend + device, records the
       :class:`~repro.core.accounting.OffloadRecord` (always carrying the
       placement) and queues the modeled ticket;
    4. the winning lowering runs: plan > pallas > host.
    """
    out, _ = dispatch_placed(
        name, *args, handle=handle, resident_fraction=resident_fraction,
        validate=validate, **kwargs,
    )
    return out


def dispatch_placed(
    name: str,
    *args,
    handle: Optional[DeviceHandle] = None,
    resident_fraction: Optional[float] = None,
    validate: bool = False,
    placement: Optional[Any] = None,
    **kwargs,
):
    """Graph-aware dispatch entry: like :func:`dispatch`, but returns
    ``(result, launch)`` where ``launch`` is the
    :class:`~repro.core.hero.LaunchResult` naming the backend and device the
    call landed on.

    The ``hnp`` graph scheduler lowers whole expression graphs through this
    entry: it threads the exact per-node ``resident_fraction`` (which
    operand/result bytes stay device-resident) and reads the placement back
    so the produced intermediate can be pinned where it actually lives and
    its consumers routed (or d2d-migrated) to the data.

    ``placement`` is a per-expert fan-out plan (an
    ``repro.core.placement.ExpertDispatchPlan``): instead of one whole-op
    launch, each expert's token block is charged on its home/replica lane
    via :meth:`~repro.core.hero.HeroCluster.launch_fanout` — all still
    under this ONE dispatch graph, and the math lowering is exactly the
    unplaced one, so the placed result is bitwise-equal to the static
    path.  Under ``mode="host"`` (or an empty plan) the fan-out degrades
    to the normal single launch.

    ``validate=True`` runs the :mod:`repro.analysis.graph` pre-dispatch
    checks on this call — op known, ``handle`` alive and engine-owned,
    operand specs accepted by the host lowering — raising
    ``GraphVerificationError`` with named violations before any cost is
    scored or any record written.
    """
    if validate:
        from repro.analysis.graph import assert_call_valid

        assert_call_valid(name, args, kwargs, handle=handle)
    tr = _spans.current_tracer()
    if tr is None:
        return _dispatch_impl(name, args, kwargs, handle,
                              resident_fraction, None, placement)
    with tr.span(f"dispatch:{name}", cat="dispatch", lane="host"):
        return _dispatch_impl(name, args, kwargs, handle,
                              resident_fraction, tr, placement)


def _dispatch_impl(
    name: str,
    args: tuple,
    kwargs: dict,
    handle: Optional[DeviceHandle],
    resident_fraction: Optional[float],
    tr: Optional["_spans.SpanTracer"],
    placement: Optional[Any] = None,
):
    """The cost -> plan -> launch -> lower pipeline, with optional phase
    markers (``tr`` is the active tracer or None — never looked up here,
    so the traced and untraced paths run the same code)."""
    op = get_op(name)
    cost = op.cost(*args, **kwargs)
    if tr is not None:
        tr.instant("cost", cat="dispatch", lane="host",
                   t=_spans.modeled_now(),
                   attrs={"op": name, "flops": cost.flops,
                          "staged_bytes": cost.staged_bytes})
    arrays = [a for a in args if hasattr(a, "shape") and hasattr(a, "dtype")]
    # Array-valued keyword operands (fused biases, masks) are part of the
    # call's static signature too — key the ledger on them, in name order.
    arrays += [
        v for _, v in sorted(kwargs.items())
        if hasattr(v, "shape") and hasattr(v, "dtype")
    ]
    plan = None
    if op.plan is not None:
        plan = op.plan(*args, **kwargs)
    eligible = (
        plan is None
        and op.pallas is not None
        and not op.host_only
        and (op.eligible is None or bool(op.eligible(*args, **kwargs)))
    )
    if tr is not None:
        tr.instant("plan", cat="dispatch", lane="host",
                   t=_spans.modeled_now(),
                   attrs={"op": name, "planned": plan is not None,
                          "pallas_eligible": eligible})
    fanout = (
        placement is not None
        and getattr(placement, "sub_launches", ())
        and not op.host_only
        and engine().policy.mode != "host"
    )
    if fanout:
        # Per-expert sub-launch fan-out under this one dispatch graph: the
        # plan pre-placed each expert's token block on its handle's lane;
        # accounting fans out, the lowering below stays the unplaced one.
        launch = engine().launch_fanout(
            placement.sub_launches,
            dtype=str(arrays[0].dtype) if arrays else "",
            note=f"expert-placed:{name}",
        )
    else:
        launch = engine().launch(
            cost,
            dtype=str(arrays[0].dtype) if arrays else "",
            shape_key=shape_key(*arrays),
            pallas_eligible=eligible,
            force_host=op.host_only,
            note="tp-shard-map" if plan is not None else op.note,
            handle=handle,
            resident_fraction=resident_fraction,
        )
    if tr is not None:
        tr.instant("launch", cat="dispatch", lane="host",
                   t=_spans.modeled_now(),
                   attrs={"op": name, "backend": str(launch),
                          "device_id": launch.device_id},
                   device_id=launch.device_id)
    if plan is not None:
        out = op.plan_lower(plan, *args, **kwargs)
        lowering = "plan"
    elif launch.backend == "device-pallas":
        out = op.pallas(*args, interpret=engine().policy.interpret, **kwargs)
        lowering = "pallas"
    else:
        out = op.host(*args, **kwargs)
        lowering = "host"
    if tr is not None:
        tr.instant("lower", cat="dispatch", lane="host",
                   t=_spans.modeled_now(),
                   attrs={"op": name, "lowering": lowering})
    return out, launch
