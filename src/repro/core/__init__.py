"""repro.core — the paper's contribution: a heterogeneous BLAS offload seam.

Layers (mirroring the paper's Fig. 2):
  platform    — analytic hardware models (heSoC from the paper, TPU v5e)
  cost_model  — three-region offload cost model (copy / fork-join / compute)
  hero        — offload cluster: N virtual PMCAs, residency ledgers,
                device-resident handles, pluggable scheduler, launch records
  dispatch    — declarative op registry: OffloadOp descriptors + the single
                cost -> plan -> launch -> lower dispatch path
  blas        — the BLAS API every model layer calls (thin wrappers over
                registered descriptors)
  accounting  — per-call offload trace (the paper's Fig. 3 instrumentation,
                with per-device rollups and an overlap timeline)
"""

from repro.core import blas
from repro.core.accounting import (
    DeviceAggregate,
    DeviceTimeline,
    OffloadRecord,
    OffloadTrace,
    offload_trace,
)
from repro.core.cost_model import (
    OpCost,
    PipelinedBreakdown,
    RegionBreakdown,
    attention_cost,
    breakdown,
    crossover_size,
    decide_offload,
    gemm_cost,
    gemv_cost,
    pipeline_makespan,
    pipelined_breakdown,
    staging_legs,
    syrk_cost,
)
from repro.core import dispatch
from repro.core.dispatch import OffloadOp, registered_ops
from repro.core.hero import (
    SCHEDULERS,
    DeviceHandle,
    HeroCluster,
    HeroEngine,
    LaunchResult,
    LaunchTicket,
    OffloadPolicy,
    VirtualDevice,
    engine,
    offload_policy,
)
from repro.core.platform import CPU_HOST, HESOC_VCU128, TPU_V5E, Platform, get_platform

__all__ = [
    "blas",
    "dispatch",
    "DeviceHandle",
    "OffloadOp",
    "registered_ops",
    "OffloadRecord",
    "OffloadTrace",
    "offload_trace",
    "OpCost",
    "PipelinedBreakdown",
    "RegionBreakdown",
    "attention_cost",
    "breakdown",
    "crossover_size",
    "decide_offload",
    "gemm_cost",
    "gemv_cost",
    "pipeline_makespan",
    "pipelined_breakdown",
    "staging_legs",
    "syrk_cost",
    "DeviceAggregate",
    "DeviceTimeline",
    "HeroCluster",
    "HeroEngine",
    "LaunchResult",
    "LaunchTicket",
    "OffloadPolicy",
    "SCHEDULERS",
    "VirtualDevice",
    "engine",
    "offload_policy",
    "CPU_HOST",
    "HESOC_VCU128",
    "TPU_V5E",
    "Platform",
    "get_platform",
]
