"""Offload engine — the HeroSDK analogue, scaled to a multi-PMCA cluster.

HeroSDK's ``libhero`` boots *one* PMCA, manages its manually-partitioned
device DRAM and copies shared structures into it before the first offload.
HERO (Kurth et al.) and ESP both show the natural next step: one host
orchestrating *many* accelerator clusters.  This module is that seam.

A :class:`HeroCluster` owns N :class:`VirtualDevice` s.  Each virtual
device keeps what the paper's runtime kept per PMCA:

* a **residency ledger** — which logical buffers (weights, caches) live in
  that device's DRAM and therefore never pay the ``data copy`` region again;
* **boot state** — the PMCA boot + L2 image copy happens lazily on the
  first offload routed to the device, exactly as in HeroSDK;
* an **in-flight launch queue** — modeled outstanding work, which is what
  the schedulers balance and what fault tolerance reschedules on loss.

Every offload goes through :func:`HeroCluster.launch`, which scores the
call with the cost model, picks a device through the pluggable scheduler
(``round-robin`` / ``least-loaded`` / ``cost-aware``) and appends an
:class:`accounting.OffloadRecord` tagged with the device id to the active
trace — the paper's instrumentation, per device.

``launch`` returns a :class:`LaunchResult`: a ``str`` subclass equal to the
chosen backend name (``"host"`` / ``"device"`` / ``"device-pallas"``) that
also carries ``device_id`` and unpacks as ``(backend, device_id)``, so the
BLAS seam reads the placement while older call sites keep comparing it to
the backend string.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

from repro.core import accounting
from repro.core.cost_model import (
    OpCost,
    PipelinedBreakdown,
    RegionBreakdown,
    breakdown,
    d2d_breakdown,
    d2d_cost,
    decide_offload,
    pipelined_breakdown,
)
from repro.core.platform import CPU_HOST, Platform, TPU_V5E, get_platform
from repro.obs import flight as _flight
from repro.obs import metrics as _metrics
from repro.obs import spans as _spans

__all__ = [
    "DeviceHandle",
    "HeroCluster",
    "HeroEngine",
    "LaunchResult",
    "LaunchTicket",
    "OffloadPolicy",
    "SCHEDULERS",
    "VirtualDevice",
    "engine",
    "offload_policy",
]

HOST_DEVICE_ID = -1


@dataclasses.dataclass
class DeviceHandle:
    """Residency token for one logical buffer pinned to a device.

    The handle *is* the placement contract: as long as it is valid, the
    named buffer lives in ``device_id``'s DRAM, launches keyed on it skip
    the copy region there, and the ``cost-aware`` scheduler is drawn to
    that device.  Migration (:meth:`HeroCluster.migrate_handle`) moves the
    buffer over the device-to-device link; device loss invalidates the
    handle (``device_id`` becomes the host sentinel) until it is re-staged.
    """

    name: str
    device_id: int
    nbytes: float
    # Replica handles (second copies of a hot buffer on another lane) name
    # the primary they were cloned from; primaries carry None.  A replica
    # is a full first-class handle — it can be released or migrated on its
    # own — but schedulers treat it as the same logical bytes.
    replica_of: Optional[str] = None

    @property
    def valid(self) -> bool:
        return self.device_id != HOST_DEVICE_ID

    @property
    def is_replica(self) -> bool:
        return self.replica_of is not None


@dataclasses.dataclass
class OffloadPolicy:
    """How the dispatcher routes BLAS calls.

    mode:
      * ``"host"``   — never offload (paper's host-only baseline)
      * ``"device"`` — always offload (paper's offloaded run)
      * ``"auto"``   — offload iff the cost model predicts >= ``min_speedup``
    """

    mode: str = "auto"
    zero_copy: bool = False
    min_speedup: float = 1.0
    # Fraction of operand bytes assumed device-resident (weights in a
    # training step are resident; activations are produced on device too, so
    # inside jit everything is resident and the copy region vanishes — the
    # paper's IOMMU end-state).
    resident_fraction: float = 0.0
    # Prefer hand-written Pallas kernels over plain XLA lowering when legal.
    use_pallas: bool = False
    # Run Pallas kernels in interpret mode (CPU validation).
    interpret: bool = False
    # Chunked, double-buffered staging: tile each launch's operand set into
    # DMA legs that stream in *while* the MXU computes, so offload_s
    # approaches max(copy, compute) instead of copy + compute.  Scoring,
    # the auto decision and the cost-aware scheduler all see the pipelined
    # cost; the overlap timeline shingles the DMA legs under compute.
    pipeline_staging: bool = True
    # DMA chunk size override, bytes (None = the platform's natural
    # double-buffer tile, ``Platform.dma_chunk_bytes``).
    pipeline_chunk_bytes: Optional[float] = None
    # Cross-wave prefetch: the graph scheduler may stage wave k+1's leaf
    # operands while wave k computes (charged as ``prefetch_stage`` records
    # riding the DMA stream; the consuming launch gets the residency
    # credit, so no byte is charged twice).
    prefetch_staging: bool = False

    def validate(self) -> None:
        if self.mode not in ("host", "device", "auto"):
            raise ValueError(f"bad offload mode {self.mode!r}")

    def score(
        self,
        cost: OpCost,
        platform: Platform,
        *,
        resident_fraction: Optional[float] = None,
    ) -> RegionBreakdown:
        """Score one call under this policy: pipelined when staging overlap
        is on, the paper's serial three-region model otherwise."""
        rf = (
            self.resident_fraction
            if resident_fraction is None
            else resident_fraction
        )
        if self.pipeline_staging:
            return pipelined_breakdown(
                cost,
                platform,
                chunk_bytes=self.pipeline_chunk_bytes,
                zero_copy=self.zero_copy,
                resident_fraction=rf,
            )
        return breakdown(
            cost,
            platform,
            zero_copy=self.zero_copy,
            resident_fraction=rf,
        )


class LaunchResult(str):
    """Backend name + placement.  Compares as the backend string."""

    device_id: int

    def __new__(cls, backend: str, device_id: int = HOST_DEVICE_ID):
        self = super().__new__(cls, backend)
        self.device_id = device_id
        return self

    @property
    def backend(self) -> str:
        return str(self)

    def __iter__(self):
        # allow `backend, device_id = cluster.launch(...)`
        return iter((str(self), self.device_id))


@dataclasses.dataclass(frozen=True)
class LaunchTicket:
    """One modeled in-flight offload on a device's queue.

    Tickets are *events*, not just durations: :meth:`VirtualDevice.issue`
    stamps each one with where it lands on the device's two modeled streams
    (DMA engine / compute cluster).  ``copy_ready_s`` is when the first
    staged chunk is on device — with pipelined staging that is one DMA leg
    after issue, not the whole copy, which is what lets the compute stream
    start under the remaining transfer.  Queue-depth accounting (serving
    admission control) reads ``complete_s`` off the in-flight window.
    """

    op: str
    shape_key: str
    offload_s: float
    issue_s: float = 0.0         # DMA stream start (device clock, seconds)
    copy_ready_s: float = 0.0    # first operand chunk landed; compute may start
    copy_done_s: float = 0.0     # staging + d2d stream fully drained
    complete_s: float = 0.0      # compute retired (launch completion event)
    # Compute-stream start: max(compute engine free, copy_ready).  Stamped so
    # the happens-before checker (repro.analysis.races) can verify compute
    # never races its staging instead of re-deriving the schedule.
    compute_start_s: float = 0.0
    # Which modeled path issued the ticket: "launch" (offloaded op),
    # "prefetch" (cross-wave staging), "d2d" (handle migration), "restage"
    # (host re-stage after loss/shrink), "requeue" (orphan reschedule).
    kind: str = "launch"
    # Residency credit the launch was scored with (>=1.0 must charge no DMA).
    resident_fraction: float = 0.0
    # Device the ticket was issued on (stamped by VirtualDevice.issue).
    device_id: int = HOST_DEVICE_ID


class VirtualDevice:
    """One PMCA-analogue: boot state, residency ledger, in-flight queue.

    The in-flight queue is a bounded window (``MAX_INFLIGHT``): enqueuing
    past the bound retires the oldest ticket into the completed counters,
    as a real device's bounded command queue would.  ``pending_s`` therefore
    reflects *outstanding* work, not all work ever assigned, and long-lived
    processes don't accumulate tickets without bound.
    """

    MAX_INFLIGHT = 128

    def __init__(self, device_id: int, platform: Platform = TPU_V5E) -> None:
        self.device_id = device_id
        self.platform = platform
        self.alive = True
        self._booted = False
        self._l2_image_loaded = False
        self._resident: Set[str] = set()
        self.inflight: List[LaunchTicket] = []
        self.completed_s = 0.0          # modeled seconds of retired work
        self.completed_launches = 0
        # Event-driven stream clocks: the frontier of each modeled engine.
        # ``issue`` advances them per launch; their gap is hidden copy time.
        self.dma_free_s = 0.0
        self.compute_free_s = 0.0

    # ---- lifecycle (mirrors hero_snitch.c boot / hero_allocator.c) -------
    def boot(self) -> None:
        """Analogue of booting the PMCA + copying device functions to L2."""
        if not self.alive:
            raise RuntimeError(f"device {self.device_id} is failed")
        self._booted = True
        self._l2_image_loaded = True

    def reset(self) -> None:
        self.alive = True
        self._booted = False
        self._l2_image_loaded = False
        self._resident.clear()
        self.inflight.clear()
        self.completed_s = 0.0
        self.completed_launches = 0
        self.dma_free_s = 0.0
        self.compute_free_s = 0.0

    @property
    def booted(self) -> bool:
        return self._booted

    # ---- residency ledger -------------------------------------------------
    def mark_resident(self, name: str) -> None:
        self._resident.add(name)

    def evict(self, name: str) -> None:
        self._resident.discard(name)

    def is_resident(self, name: str) -> bool:
        return name in self._resident

    @property
    def resident(self) -> frozenset:
        return frozenset(self._resident)

    # ---- in-flight queue --------------------------------------------------
    @property
    def pending_s(self) -> float:
        """Modeled seconds of queued-but-unretired work."""
        return sum(t.offload_s for t in self.inflight)

    def enqueue(self, ticket: LaunchTicket) -> None:
        while len(self.inflight) >= self.MAX_INFLIGHT:
            oldest = self.inflight.pop(0)
            self.completed_s += oldest.offload_s
            self.completed_launches += 1
        self.inflight.append(ticket)

    @property
    def stream_makespan_s(self) -> float:
        """Frontier of the later modeled stream (DMA vs compute)."""
        return max(self.dma_free_s, self.compute_free_s)

    def advance_clocks(self, t: float) -> None:
        """Advance both stream clocks to at least ``t`` (modeled idle gap).

        Streaming consumers live on a wall of *arrival* time: a request that
        lands at t=5 cannot issue before t=5 even on an idle device.  The
        gap is pure idleness — clocks only ever move forward, so the
        happens-before monotonicity checks are unaffected."""
        t = float(t)
        if t > self.dma_free_s:
            self.dma_free_s = t
        if t > self.compute_free_s:
            self.compute_free_s = t

    def issue(
        self,
        cost: OpCost,
        bd: RegionBreakdown,
        shape_key: str,
        *,
        kind: str = "launch",
        resident_fraction: float = 0.0,
    ) -> LaunchTicket:
        """Issue one launch event-wise: charge its staging (plus any d2d
        leg) to the DMA stream, gate compute on the *first* landed chunk
        when the breakdown is pipelined (the whole copy otherwise), and
        enqueue the stamped ticket.  The completion event is what retires
        through :meth:`retire_all` / cluster ``sync``.
        """
        copy = bd.copy_s + bd.d2d_s
        gate = bd.d2d_s + (
            bd.first_copy_leg_s
            if isinstance(bd, PipelinedBreakdown) and bd.chunks > 1
            else bd.copy_s
        )
        work = bd.fork_join_s + bd.compute_s
        issue_s = self.dma_free_s
        self.dma_free_s = issue_s + copy
        ready = issue_s + gate
        compute_start = max(self.compute_free_s, ready)
        self.compute_free_s = compute_start + work
        if isinstance(bd, PipelinedBreakdown):
            # compute cannot retire before its last chunk has landed
            self.compute_free_s = max(self.compute_free_s, self.dma_free_s)
        ticket = LaunchTicket(
            op=cost.op,
            shape_key=shape_key,
            offload_s=bd.offload_s,
            issue_s=issue_s,
            copy_ready_s=ready,
            copy_done_s=self.dma_free_s,
            complete_s=self.compute_free_s,
            compute_start_s=compute_start,
            kind=kind,
            resident_fraction=float(resident_fraction),
            device_id=self.device_id,
        )
        self.enqueue(ticket)
        _flight.note_ticket(ticket)
        _metrics.counter("stream.tickets", kind=kind).inc()
        if bd.copy_s > 0 and cost.staged_bytes > 0:
            charged = cost.staged_bytes * (1.0 - float(resident_fraction))
            chunks = bd.chunks if isinstance(bd, PipelinedBreakdown) else 1
            if charged > 0:
                _metrics.histogram("staging.leg_bytes").observe(
                    charged / chunks, n=chunks)
        tr = _spans.current_tracer()
        if tr is not None:
            _trace_ticket(tr, ticket, bd)
            tr.counter(f"dev{self.device_id}/inflight", ticket.issue_s,
                       float(len(self.inflight)), device_id=self.device_id)
        return ticket

    def requeue(self, ticket: LaunchTicket) -> LaunchTicket:
        """Re-issue an orphaned ticket on this device (failure/resize
        rescheduling): its staging was charged where it first ran, so only
        the modeled completion occupies this device's compute stream."""
        start = max(self.compute_free_s, self.dma_free_s)
        self.compute_free_s = start + ticket.offload_s
        moved = dataclasses.replace(
            ticket,
            issue_s=start,
            copy_ready_s=start,
            copy_done_s=start,
            complete_s=self.compute_free_s,
            compute_start_s=start,
            kind="requeue",
            device_id=self.device_id,
        )
        self.enqueue(moved)
        _flight.note_ticket(moved)
        _metrics.counter("stream.tickets", kind="requeue").inc()
        tr = _spans.current_tracer()
        if tr is not None:
            _trace_ticket(tr, moved, None)
            tr.counter(f"dev{self.device_id}/inflight", moved.issue_s,
                       float(len(self.inflight)), device_id=self.device_id)
        return moved

    def breakdown_for(
        self, cost: OpCost, policy: OffloadPolicy, shape_key: str
    ) -> RegionBreakdown:
        """Score a call on this device with its residency credit applied:
        operands already resident here never pay the copy region.  Scoring
        goes through :meth:`OffloadPolicy.score`, so schedulers comparing
        completion times see the pipelined cost when staging overlap is on.
        """
        return policy.score(
            cost,
            self.platform,
            resident_fraction=(
                1.0 if self.is_resident(shape_key) else None
            ),
        )

    def retire_all(self) -> int:
        """Drain the queue (modeled completion); returns launches retired."""
        n = len(self.inflight)
        self.completed_s += self.pending_s
        self.completed_launches += n
        self.inflight.clear()
        return n

    def fail(self) -> List[LaunchTicket]:
        """Device loss: mark dead, drop residency, surrender in-flight work."""
        self.alive = False
        self._booted = False
        self._l2_image_loaded = False
        self._resident.clear()
        orphans = list(self.inflight)
        self.inflight.clear()
        return orphans


# Cap on per-chunk child spans under one pipelined staging span: keeps the
# trace readable for multi-hundred-chunk copies (the parent span's attrs
# carry the exact chunk count either way).
_MAX_LEG_SPANS = 16


def _trace_ticket(
    tr: "_spans.SpanTracer",
    ticket: LaunchTicket,
    bd: Optional[RegionBreakdown],
) -> None:
    """Emit the stream-lane span(s) for one stamped ticket.

    Only called with an active tracer.  Spans mirror the ticket's event
    pairs exactly — DMA window ``[issue_s, copy_done_s]``, compute window
    ``[compute_start_s, complete_s]`` — and carry the ticket identity in
    attrs so the ``check_obs`` gate can match every ticket to a span.
    """
    dev = ticket.device_id
    attrs = {
        "ticket": True,
        "kind": ticket.kind,
        "op": ticket.op,
        "shape_key": ticket.shape_key,
        "issue_s": ticket.issue_s,
        "complete_s": ticket.complete_s,
        "resident_fraction": ticket.resident_fraction,
    }
    name = f"{ticket.kind}:{ticket.op}"
    copy_dur = ticket.copy_done_s - ticket.issue_s
    if copy_dur > 0:
        staging = tr.emit(name, cat="stream", lane=f"dev{dev}/dma",
                          t0=ticket.issue_s, t1=ticket.copy_done_s,
                          attrs=attrs, device_id=dev)
        if (isinstance(bd, PipelinedBreakdown) and bd.chunks > 1
                and bd.copy_s > 0):
            staging.attrs["chunks"] = bd.chunks
            if bd.chunks <= _MAX_LEG_SPANS:
                t = ticket.issue_s
                rest = max(bd.copy_s - bd.first_copy_leg_s, 0.0)
                leg = rest / (bd.chunks - 1)
                for k in range(bd.chunks):
                    dur = bd.first_copy_leg_s if k == 0 else leg
                    tr.emit(f"leg{k}", cat="stream", lane=f"dev{dev}/dma",
                            t0=t, t1=t + dur, parent_id=staging.span_id,
                            device_id=dev)
                    t += dur
    work_dur = ticket.complete_s - ticket.compute_start_s
    if work_dur > 0 or copy_dur <= 0:
        tr.emit(name, cat="stream", lane=f"dev{dev}/compute",
                t0=ticket.compute_start_s, t1=ticket.complete_s,
                attrs=attrs, device_id=dev)


# ---------------------------------------------------------------------------
# Schedulers.  select(devices, cost, policy) -> VirtualDevice
# ---------------------------------------------------------------------------

def _round_robin():
    counter = itertools.count()

    def select(
        devices: List[VirtualDevice], cost: OpCost, policy: OffloadPolicy,
        shape_key: str,
    ) -> VirtualDevice:
        return devices[next(counter) % len(devices)]

    return select


def _least_loaded():
    def select(
        devices: List[VirtualDevice], cost: OpCost, policy: OffloadPolicy,
        shape_key: str,
    ) -> VirtualDevice:
        # deterministic tie-break by device id
        return min(devices, key=lambda d: (d.pending_s, d.device_id))

    return select


def _cost_aware():
    def select(
        devices: List[VirtualDevice], cost: OpCost, policy: OffloadPolicy,
        shape_key: str,
    ) -> VirtualDevice:
        def completion(d: VirtualDevice) -> float:
            # residency affinity: operands already on the device skip the
            # copy region entirely (paper's resident-buffer observation)
            return d.pending_s + d.breakdown_for(cost, policy, shape_key).offload_s

        return min(devices, key=lambda d: (completion(d), d.device_id))

    return select


SCHEDULERS: Dict[str, Callable[[], Callable]] = {
    "round-robin": _round_robin,
    "least-loaded": _least_loaded,
    "cost-aware": _cost_aware,
}


class HeroCluster:
    """Host-side orchestrator for N virtual PMCA devices (singleton)."""

    def __init__(
        self,
        num_devices: int = 1,
        platform: Platform = TPU_V5E,
        scheduler: str = "least-loaded",
    ) -> None:
        self.platform = platform
        self.policy = OffloadPolicy()
        self._scheduler_name = ""
        self._select: Optional[Callable] = None
        self._pinned: Optional[VirtualDevice] = None
        self.devices: List[VirtualDevice] = []
        self._handles: Dict[str, DeviceHandle] = {}
        self.resize(num_devices)
        self.set_scheduler(scheduler)

    # ---- topology ---------------------------------------------------------
    @property
    def num_devices(self) -> int:
        return len(self.devices)

    def _rebuild(self, num_devices: int) -> None:
        """Tear down and rebuild the topology (scoped ``offload_policy``
        entry): every device starts cold and the handle ledger clears."""
        if num_devices < 1:
            raise ValueError(f"cluster needs >= 1 device, got {num_devices}")
        self.devices = [
            VirtualDevice(i, self.platform) for i in range(num_devices)
        ]
        self._handles.clear()       # fresh devices hold nothing yet

    def resize(self, num_devices: int) -> List[Tuple[str, int]]:
        """Elastically grow/shrink the cluster (checkpoint-boundary replan).

        Grow appends cold devices; existing devices keep their queues,
        residency and pinned handles.  Shrink drains the removed devices
        first: their in-flight launches reschedule onto the keepers through
        the active scheduler, and every pinned handle homed on a removed
        device is re-staged onto a keeper (full host->device copy, recorded
        on the new lane — the same path the :class:`ClusterSupervisor`
        takes on device loss).  Returns ``[(handle name, new device), ...]``
        for the re-staged handles (empty on grow).
        """
        if num_devices < 1:
            raise ValueError(f"cluster needs >= 1 device, got {num_devices}")
        cur = len(self.devices)
        if num_devices == cur:
            return []
        if not self.devices:        # first build (from __init__)
            self._rebuild(num_devices)
            return []
        if num_devices > cur:
            self.devices = self.devices + [
                VirtualDevice(i, self.platform)
                for i in range(cur, num_devices)
            ]
            return []
        if not any(d.alive for d in self.devices[:num_devices]):
            raise RuntimeError(
                "cannot shrink: no alive device among the keepers"
            )
        # Drain removed lanes: mark failed (evicts residency, surrenders
        # queues), truncate, then restage handles / reschedule orphans onto
        # the survivors via the active scheduler.
        orphans: List[LaunchTicket] = []
        for d in self.devices[num_devices:]:
            orphans.extend(d.fail())
        lost = [
            h for h in self._handles.values() if h.device_id >= num_devices
        ]
        self.devices = self.devices[:num_devices]
        moves: List[Tuple[str, int]] = []
        for h in lost:
            h.device_id = HOST_DEVICE_ID   # bytes live only in host DRAM now
            self.restage_handle(h)
            moves.append((h.name, h.device_id))
        for t in orphans:
            cost = OpCost(
                op=t.op, flops=0.0, staged_bytes=0.0, touched_bytes=0.0
            )
            target = self._pick(cost, t.shape_key)
            if not target.booted:
                target.boot()
            old_dev = t.device_id
            target.requeue(t)
            self._record_requeue(t, old_dev, target.device_id)
        return moves

    def _record_requeue(self, ticket: LaunchTicket, old_dev: int,
                        new_dev: int) -> None:
        """Account a rescheduled orphan on its surviving device.

        The original launch record keeps the aborted attempt on the lost
        lane; the re-execution charges its compute once, on the survivor —
        with no copy/fork-join regions, matching ``VirtualDevice.requeue``
        which occupies only the compute stream.  Without this record,
        ``OffloadTrace.summary()`` / ``device_timelines()`` silently
        dropped requeued work from the busy-time rollups.
        """
        accounting.record(
            accounting.OffloadRecord(
                op=ticket.op,
                shape_key=ticket.shape_key,
                dtype="",
                backend="device",
                cost=OpCost(op=ticket.op, flops=0.0, staged_bytes=0.0,
                            touched_bytes=0.0),
                regions=RegionBreakdown(
                    copy_s=0.0, fork_join_s=0.0,
                    compute_s=ticket.offload_s, host_s=0.0,
                ),
                zero_copy=self.policy.zero_copy,
                note=f"requeue {old_dev}->{new_dev}",
                device_id=new_dev,
            )
        )

    def set_scheduler(self, name: str) -> None:
        if name not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {name!r}; have {sorted(SCHEDULERS)}"
            )
        self._scheduler_name = name
        self._select = SCHEDULERS[name]()

    @property
    def scheduler(self) -> str:
        return self._scheduler_name

    def set_platform(self, platform: Platform) -> None:
        self.platform = platform
        for d in self.devices:
            d.platform = platform

    def alive_devices(self) -> List[VirtualDevice]:
        return [d for d in self.devices if d.alive]

    def device(self, device_id: int) -> VirtualDevice:
        return self.devices[device_id]

    # ---- lifecycle --------------------------------------------------------
    def boot(self) -> None:
        for d in self.alive_devices():
            d.boot()

    def reset(self) -> None:
        for d in self.devices:
            d.reset()
        self._handles.clear()
        if self._select is not None:
            self.set_scheduler(self._scheduler_name)  # fresh RR counter

    @property
    def booted(self) -> bool:
        return any(d.booted for d in self.devices)

    # ---- residency (cluster-wide convenience; per-device via .device()) ---
    def mark_resident(self, name: str, device_id: Optional[int] = None) -> None:
        """Pin a logical buffer: one device, or all alive devices (None)."""
        targets = (
            [self.devices[device_id]] if device_id is not None
            else self.alive_devices()
        )
        for d in targets:
            d.mark_resident(name)

    def evict(self, name: str, device_id: Optional[int] = None) -> None:
        targets = (
            [self.devices[device_id]] if device_id is not None
            else self.devices
        )
        for d in targets:
            d.evict(name)

    def is_resident(self, name: str, device_id: Optional[int] = None) -> bool:
        if device_id is not None:
            return self.devices[device_id].is_resident(name)
        return any(d.is_resident(name) for d in self.alive_devices())

    # ---- device-resident handles (first-class placement tokens) -----------
    def pin_handle(
        self, name: str, nbytes: float, device_id: Optional[int] = None
    ) -> DeviceHandle:
        """Pin a logical buffer to one device and return its handle.

        ``device_id=None`` lets the active scheduler choose (so pinning a
        KV cache at prefill lands on the least-costly lane).  Re-pinning an
        existing name moves the residency mark to the new home.
        """
        if device_id is not None:
            dev = self.devices[device_id]
            if not dev.alive:
                raise RuntimeError(f"cannot pin to failed device {device_id}")
        else:
            dev = self._pick(d2d_cost(nbytes, op="pin"), name)
        old = self._handles.get(name)
        if old is not None and old.valid and old.device_id != dev.device_id:
            self.devices[old.device_id].evict(name)
        if not dev.booted:
            dev.boot()
        dev.mark_resident(name)
        handle = DeviceHandle(name=name, device_id=dev.device_id,
                              nbytes=float(nbytes))
        self._handles[name] = handle
        self._note_resident_bytes(dev.device_id)
        return handle

    def _note_resident_bytes(self, device_id: int) -> None:
        """Counter-track sample of pinned bytes on one device (traced runs
        only — a single guarded call at every residency transition)."""
        tr = _spans.current_tracer()
        if tr is None or not (0 <= device_id < len(self.devices)):
            return
        total = sum(h.nbytes for h in self.handles_on(device_id))
        tr.counter(f"dev{device_id}/resident_bytes",
                   self.devices[device_id].stream_makespan_s, total,
                   device_id=device_id)

    def handle(self, name: str) -> Optional[DeviceHandle]:
        return self._handles.get(name)

    def handles_on(self, device_id: int) -> List[DeviceHandle]:
        return [h for h in self._handles.values() if h.device_id == device_id]

    def unstage_handle(self, handle: DeviceHandle) -> None:
        """Drain a pinned buffer back to host DRAM, keeping the handle known.

        The unstaged handle stays in the ledger (``valid`` becomes False);
        a later :meth:`restage_handle` pays the host->device copy to bring
        it back.  This is the "don't pin" serving baseline and the state a
        handle enters when its device is lost.
        """
        if self._handles.get(handle.name) is not handle:
            raise KeyError(f"unknown handle {handle.name!r}")
        old_dev = handle.device_id
        if handle.valid and handle.device_id < len(self.devices):
            self.devices[handle.device_id].evict(handle.name)
        handle.device_id = HOST_DEVICE_ID
        self._note_resident_bytes(old_dev)

    def release_handle(self, handle: DeviceHandle) -> None:
        old_dev = handle.device_id
        if handle.valid and handle.device_id < len(self.devices):
            self.devices[handle.device_id].evict(handle.name)
        self._handles.pop(handle.name, None)
        handle.device_id = HOST_DEVICE_ID
        self._note_resident_bytes(old_dev)

    def migrate_handle(
        self, handle: DeviceHandle, device_id: int
    ) -> RegionBreakdown:
        """Move a pinned buffer to another device over the d2d link.

        Charges the ``d2d_copy`` region on the *destination* lane (its DMA
        engine receives the bytes) and records it on the active trace, so
        migrations show up in per-device rollups and the overlap timeline.
        No-op (zero breakdown) when the handle already lives there.
        """
        if self._handles.get(handle.name) is not handle:
            raise KeyError(f"unknown handle {handle.name!r}")
        if not handle.valid:
            raise RuntimeError(
                f"handle {handle.name!r} is unstaged; use restage_handle()"
            )
        if device_id == handle.device_id:
            return RegionBreakdown(0.0, 0.0, 0.0, 0.0)
        dst = self.devices[device_id]
        if not dst.alive:
            raise RuntimeError(f"cannot migrate to failed device {device_id}")
        bd = d2d_breakdown(handle.nbytes, self.platform)
        self.devices[handle.device_id].evict(handle.name)
        if not dst.booted:
            dst.boot()
        dst.mark_resident(handle.name)
        cost = d2d_cost(handle.nbytes)
        ticket = dst.issue(cost, bd, handle.name, kind="d2d")
        tr = _spans.current_tracer()
        if tr is not None:
            # Arrow from the source lane to the receiving DMA window: the
            # bytes leave where the handle lived and land on dst's stream.
            tr.flow(f"d2d:{handle.name}", cat="stream",
                    src_lane=f"dev{handle.device_id}/compute",
                    src_t=ticket.issue_s,
                    dst_lane=f"dev{device_id}/dma",
                    dst_t=ticket.copy_done_s,
                    attrs={"nbytes": handle.nbytes,
                           "src": handle.device_id, "dst": device_id})
        accounting.record(
            accounting.OffloadRecord(
                op=cost.op, shape_key=handle.name, dtype="",
                backend="device", cost=cost, regions=bd,
                zero_copy=self.policy.zero_copy,
                note=f"handle migration {handle.device_id}->{device_id}",
                device_id=device_id,
            )
        )
        old_dev = handle.device_id
        handle.device_id = device_id
        self._note_resident_bytes(old_dev)
        self._note_resident_bytes(device_id)
        return bd

    def replicate_handle(
        self, handle: DeviceHandle, device_id: int
    ) -> DeviceHandle:
        """Clone a pinned buffer onto a second device over the d2d link.

        Unlike :meth:`migrate_handle` the source stays pinned and valid —
        replication is how a persistently-hot buffer (e.g. a popular
        expert's weights) serves launches from two lanes at once.  The d2d
        copy is charged on the *replica* lane's DMA stream (its engine
        receives the bytes) and recorded on the active trace.  Returns the
        replica handle (``replica_of`` names the primary); re-replicating
        onto a lane that already holds the replica returns it unchanged.
        """
        if self._handles.get(handle.name) is not handle:
            raise KeyError(f"unknown handle {handle.name!r}")
        if not handle.valid:
            raise RuntimeError(
                f"handle {handle.name!r} is unstaged; use restage_handle()"
            )
        if device_id == handle.device_id:
            raise ValueError(
                f"handle {handle.name!r} already lives on device {device_id}"
            )
        name = f"{handle.name}@dev{device_id}"
        existing = self._handles.get(name)
        if existing is not None and existing.device_id == device_id:
            return existing
        dst = self.devices[device_id]
        if not dst.alive:
            raise RuntimeError(
                f"cannot replicate to failed device {device_id}")
        bd = d2d_breakdown(handle.nbytes, self.platform)
        if not dst.booted:
            dst.boot()
        dst.mark_resident(name)
        cost = d2d_cost(handle.nbytes)
        ticket = dst.issue(cost, bd, name, kind="d2d")
        tr = _spans.current_tracer()
        if tr is not None:
            tr.flow(f"d2d:{name}", cat="stream",
                    src_lane=f"dev{handle.device_id}/compute",
                    src_t=ticket.issue_s,
                    dst_lane=f"dev{device_id}/dma",
                    dst_t=ticket.copy_done_s,
                    attrs={"nbytes": handle.nbytes,
                           "src": handle.device_id, "dst": device_id})
        accounting.record(
            accounting.OffloadRecord(
                op=cost.op, shape_key=name, dtype="",
                backend="device", cost=cost, regions=bd,
                zero_copy=self.policy.zero_copy,
                note=f"handle replication {handle.device_id}->{device_id}",
                device_id=device_id,
            )
        )
        replica = DeviceHandle(name=name, device_id=device_id,
                               nbytes=handle.nbytes,
                               replica_of=handle.name)
        self._handles[name] = replica
        self._note_resident_bytes(device_id)
        return replica

    def replicas_of(self, name: str) -> List[DeviceHandle]:
        """All live replica handles cloned from the named primary."""
        return [
            h for h in self._handles.values()
            if h.replica_of == name and h.valid
        ]

    def restage_handle(
        self, handle: DeviceHandle, device_id: Optional[int] = None
    ) -> RegionBreakdown:
        """Re-stage an unstaged handle from host memory onto a device.

        Used after device loss: the dead device's buffers exist only in
        host DRAM again, so the survivor pays the full host->device copy
        region (the d2d path needs a live source).
        """
        if self._handles.get(handle.name) is not handle:
            raise KeyError(f"unknown handle {handle.name!r}")
        cost = d2d_cost(handle.nbytes, op="restage")
        if device_id is not None:
            dev = self.devices[device_id]
            if not dev.alive:
                raise RuntimeError(
                    f"cannot restage to failed device {device_id}"
                )
        else:
            dev = self._pick(cost, handle.name)
        bd = RegionBreakdown(
            copy_s=self.platform.t_copy(handle.nbytes,
                                        zero_copy=self.policy.zero_copy),
            fork_join_s=self.platform.t_fork_join(),
            compute_s=0.0,
            host_s=0.0,
        )
        if not dev.booted:
            dev.boot()
        dev.mark_resident(handle.name)
        dev.issue(cost, bd, handle.name, kind="restage")
        accounting.record(
            accounting.OffloadRecord(
                op=cost.op, shape_key=handle.name, dtype="",
                backend="device", cost=cost, regions=bd,
                zero_copy=self.policy.zero_copy,
                note="host re-stage after device loss",
                device_id=dev.device_id,
            )
        )
        handle.device_id = dev.device_id
        return bd

    def prefetch_stage(
        self, name: str, nbytes: float, device_id: Optional[int] = None
    ) -> DeviceHandle:
        """Stage a buffer onto a device *ahead of* the op that consumes it.

        This is the cross-wave half of the DMA pipeline: the graph frontend
        calls it for wave k+1's unresident operands while wave k's compute
        is still in flight, so the copy rides the DMA stream under compute
        instead of serializing in front of the consumer.  The copy is
        charged on the chosen lane's DMA clock (no fork/join — nothing
        launches) and the returned handle carries the residency credit the
        consumer's ``resident_fraction`` math then picks up.
        """
        handle = self.pin_handle(name, nbytes, device_id=device_id)
        dev = self.devices[handle.device_id]
        cost = OpCost(
            op="prefetch_stage",
            flops=0.0,
            staged_bytes=float(nbytes),
            touched_bytes=float(nbytes),
        )
        bd = RegionBreakdown(
            copy_s=self.platform.t_copy(nbytes, zero_copy=self.policy.zero_copy),
            fork_join_s=0.0,
            compute_s=0.0,
            host_s=0.0,
        )
        dev.issue(cost, bd, name, kind="prefetch")
        accounting.record(
            accounting.OffloadRecord(
                op=cost.op, shape_key=name, dtype="",
                backend="device", cost=cost, regions=bd,
                zero_copy=self.policy.zero_copy,
                note="cross-wave prefetch",
                device_id=dev.device_id,
            )
        )
        return handle

    @contextlib.contextmanager
    def handle_scope(self) -> Iterator[None]:
        """Scope the lifetime of handles pinned inside to the block.

        The graph frontend pins one handle per device-resident intermediate
        so multi-op chains reuse placement; those buffers are dead once the
        graph (or an ``hnp.offload_region``) finishes.  On exit, every handle
        pinned inside the scope is released and its residency mark evicted —
        handles pinned before the scope (weights, KV caches) survive.
        """
        before = set(self._handles)
        try:
            yield
        finally:
            for name in [n for n in self._handles if n not in before]:
                self.release_handle(self._handles[name])

    # ---- fault tolerance --------------------------------------------------
    def fail_device(self, device_id: int) -> List[Tuple[LaunchTicket, int]]:
        """Device loss: evict + reschedule its in-flight work.

        Returns ``[(ticket, new_device_id), ...]`` — each orphaned launch
        re-placed on a surviving device through the active scheduler.
        Handles homed on the lost device become unstaged (their bytes only
        exist in host memory now); re-placing them is the supervisor's call
        (:meth:`restage_handle`), since it costs a full host copy.
        """
        survivors = [
            d for d in self.alive_devices() if d.device_id != device_id
        ]
        if not survivors:
            raise RuntimeError("all devices failed; no reschedule target")
        orphans = self.devices[device_id].fail()
        for h in self.handles_on(device_id):
            h.device_id = HOST_DEVICE_ID
        moved: List[Tuple[LaunchTicket, int]] = []
        for t in orphans:
            cost = OpCost(op=t.op, flops=0.0, staged_bytes=0.0, touched_bytes=0.0)
            target = self._select(survivors, cost, self.policy, t.shape_key)
            if not target.booted:
                target.boot()
            target.requeue(t)
            self._record_requeue(t, device_id, target.device_id)
            moved.append((t, target.device_id))
        return moved

    def restore_device(self, device_id: int) -> None:
        """Bring a failed device back (cold: empty ledger, unbooted)."""
        self.devices[device_id].reset()

    @contextlib.contextmanager
    def pin_device(self, device_id: int) -> Iterator[VirtualDevice]:
        """Force every launch in the scope onto one device.

        Batch-level consumers place a unit of work with :meth:`assign` and
        then execute it under this pin, so the fine-grained launches the
        work issues land on — and are traced against — its assigned lane.
        The pin only affects *placement* of new launches; failure
        rescheduling (:meth:`fail_device`) always goes through the real
        scheduler over the survivors.
        """
        dev = self.devices[device_id]
        if not dev.alive:
            raise RuntimeError(f"device {device_id} is failed")
        saved = self._pinned
        self._pinned = dev
        try:
            yield dev
        finally:
            self._pinned = saved

    def _pick(
        self, cost: OpCost, shape_key: str
    ) -> VirtualDevice:
        """Placement for one new launch: the pinned device if any, else the
        scheduler's choice over the alive devices."""
        if self._pinned is not None:
            if not self._pinned.alive:
                raise RuntimeError(
                    f"pinned device {self._pinned.device_id} failed mid-scope"
                )
            return self._pinned
        alive = self.alive_devices()
        if not alive:
            raise RuntimeError("no alive devices in cluster")
        return self._select(alive, cost, self.policy, shape_key)

    def assign(
        self,
        cost: OpCost,
        shape_key: str,
        handle: Optional[DeviceHandle] = None,
    ) -> Tuple[int, RegionBreakdown]:
        """Place one unit of work (e.g. a serving batch) on a device.

        Scheduler-driven placement without an offload record: boots the
        chosen device, enqueues a ticket for its modeled time, and returns
        ``(device_id, breakdown)`` — the breakdown is exactly what the
        ticket was sized with, so callers account lanes with the same
        numbers the scheduler saw.  Used by batch-level consumers
        (``launch/serve.py``).  ``handle`` declares a data dependency on a
        pinned buffer: placement-affine schedulers (``cost-aware``) see the
        residency credit and are drawn to the device holding it; oblivious
        ones (``round-robin``) are not.
        """
        device_id, bd, _ = self.assign_at(cost, shape_key, handle=handle)
        return device_id, bd

    def assign_at(
        self,
        cost: OpCost,
        shape_key: str,
        *,
        ready_s: float = 0.0,
        device_id: Optional[int] = None,
        handle: Optional[DeviceHandle] = None,
        resident_fraction: Optional[float] = None,
    ) -> Tuple[int, RegionBreakdown, LaunchTicket]:
        """Place one unit of work that becomes *ready* at ``ready_s``.

        The streaming serve engine's issue path: identical to
        :meth:`assign`, but (a) the chosen device's stream clocks are first
        advanced to ``ready_s`` (a request cannot issue before it arrives —
        the gap is modeled idleness, never wall clock), (b) the stamped
        :class:`LaunchTicket` is returned so the caller can read the modeled
        completion event (``complete_s``) for SLO accounting and queue-depth
        admission control, and (c) ``device_id``/``resident_fraction`` may
        be forced (slot-refill launches land on their lane with the weights'
        residency credit, not the scheduler's choice).
        """
        key = (
            handle.name if handle is not None and handle.valid else shape_key
        )
        if device_id is not None:
            dev = self.devices[device_id]
            if not dev.alive:
                raise RuntimeError(f"cannot assign to failed device {device_id}")
        else:
            dev = self._pick(cost, key)
        if not dev.booted:
            dev.boot()
        if ready_s > 0.0:
            dev.advance_clocks(ready_s)
        if resident_fraction is None:
            rf = 1.0 if dev.is_resident(key) else 0.0
            bd = dev.breakdown_for(cost, self.policy, key)
        else:
            rf = min(max(float(resident_fraction), 0.0), 1.0)
            bd = self.policy.score(cost, dev.platform, resident_fraction=rf)
        ticket = dev.issue(cost, bd, key, resident_fraction=rf)
        return dev.device_id, bd, ticket

    # ---- modeled completion ----------------------------------------------
    def sync(self) -> int:
        """Retire every in-flight launch (modeled barrier). Returns count."""
        return sum(d.retire_all() for d in self.devices)

    # ---- the offload decision + bookkeeping -------------------------------
    def launch(
        self,
        cost: OpCost,
        *,
        dtype: str,
        shape_key: str,
        pallas_eligible: bool = False,
        force_host: bool = False,
        note: str = "",
        handle: Optional[DeviceHandle] = None,
        resident_fraction: Optional[float] = None,
    ) -> LaunchResult:
        """Route one BLAS call.  Returns backend + device placement.

        Called at trace time from the :mod:`repro.core.dispatch` registry;
        side effect is one :class:`accounting.OffloadRecord` on the active
        trace (if any) and one :class:`LaunchTicket` on the chosen device's
        in-flight queue.  ``handle`` keys scheduling and residency credit on
        a pinned buffer instead of the operand shapes.

        ``resident_fraction`` overrides the policy's blanket fraction with an
        exact per-call value — the graph frontend computes, per node, how
        many operand/result bytes already live (or will stay) in device
        memory and threads that through here, so intermediates consumed
        on-device never pay the host staging region.  When given, it also
        replaces the all-or-nothing ledger bump (the caller already did the
        bookkeeping at byte granularity).
        """
        pol = self.policy
        pol.validate()
        key = (
            handle.name if handle is not None and handle.valid else shape_key
        )
        rf = (
            pol.resident_fraction
            if resident_fraction is None
            else min(max(float(resident_fraction), 0.0), 1.0)
        )
        if force_host:  # ops compiled host-only (paper: syrk.c)
            bd = pol.score(cost, self.platform, resident_fraction=rf)
            _metrics.counter("dispatch.calls", op=cost.op).inc()
            accounting.record(
                accounting.OffloadRecord(
                    op=cost.op, shape_key=shape_key, dtype=dtype,
                    backend="host", cost=cost, regions=bd,
                    zero_copy=pol.zero_copy, note=note or "host-only op",
                    device_id=HOST_DEVICE_ID, resident_fraction=rf,
                )
            )
            return LaunchResult("host")
        if pol.mode == "host":
            offload = False
            bd = pol.score(cost, self.platform, resident_fraction=rf)
        elif pol.mode == "device":
            offload = True
            bd = pol.score(cost, self.platform, resident_fraction=rf)
        else:  # auto — the paper's size-dependent decision
            offload, bd = decide_offload(
                cost,
                self.platform,
                zero_copy=pol.zero_copy,
                resident_fraction=rf,
                min_speedup=pol.min_speedup,
                pipeline=pol.pipeline_staging,
                chunk_bytes=pol.pipeline_chunk_bytes,
            )

        device_id = HOST_DEVICE_ID
        if offload:
            dev = self._pick(cost, key)
            device_id = dev.device_id
            if not dev.booted:
                dev.boot()  # first offload boots the device, as in HeroSDK
            # residency affinity credit on the chosen device (skipped when
            # the caller supplied the exact fraction itself)
            if resident_fraction is None and dev.is_resident(key):
                bd = dev.breakdown_for(cost, pol, key)
                rf = 1.0
            dev.issue(cost, bd, key, resident_fraction=rf)

        if not offload:
            backend = "host"
        elif pallas_eligible and pol.use_pallas:
            backend = "device-pallas"
        else:
            backend = "device"
        _metrics.counter("dispatch.calls", op=cost.op).inc()
        if offload:
            _metrics.counter("dispatch.offloaded", op=cost.op).inc()
        accounting.record(
            accounting.OffloadRecord(
                op=cost.op,
                shape_key=shape_key,
                dtype=dtype,
                backend=backend,
                cost=cost,
                regions=bd,
                zero_copy=pol.zero_copy,
                note=note,
                device_id=device_id,
                resident_fraction=rf,
            )
        )
        return LaunchResult(backend, device_id)

    def launch_fanout(
        self,
        subs,
        *,
        dtype: str = "",
        note: str = "expert-placed",
        ready_s: float = 0.0,
    ) -> LaunchResult:
        """Issue one pre-placed sub-launch per entry (handle-affine fan-out).

        ``subs`` is a sequence of placed sub-launch records (duck-typed:
        ``cost``, ``device_id``, ``shape_key``, ``resident_fraction`` — see
        ``repro.core.placement.PlacedSubLaunch``).  Each entry is charged on
        its assigned lane's stream clocks and written to the trace exactly
        like a scheduler-placed launch, so a grouped op whose placement
        policy fans it out per-expert produces per-lane rollups the overlap
        timeline and race checkers can read.  Returns a device
        :class:`LaunchResult` naming the busiest lane of the fan-out (the
        one that bounds the step's makespan).
        """
        pol = self.policy
        pol.validate()
        busiest_id, busiest_s = HOST_DEVICE_ID, -1.0
        for s in subs:
            dev = self.devices[s.device_id]
            if not dev.alive:
                raise RuntimeError(
                    f"cannot fan out to failed device {s.device_id}")
            if not dev.booted:
                dev.boot()
            if ready_s > 0.0:
                dev.advance_clocks(ready_s)
            rf = min(max(float(s.resident_fraction), 0.0), 1.0)
            bd = pol.score(s.cost, dev.platform, resident_fraction=rf)
            dev.issue(s.cost, bd, s.shape_key, resident_fraction=rf)
            _metrics.counter("dispatch.calls", op=s.cost.op).inc()
            _metrics.counter("dispatch.offloaded", op=s.cost.op).inc()
            accounting.record(
                accounting.OffloadRecord(
                    op=s.cost.op, shape_key=s.shape_key, dtype=dtype,
                    backend="device", cost=s.cost, regions=bd,
                    zero_copy=pol.zero_copy, note=note,
                    device_id=dev.device_id, resident_fraction=rf,
                )
            )
            if bd.offload_s > busiest_s:
                busiest_id, busiest_s = dev.device_id, bd.offload_s
        return LaunchResult("device", busiest_id)


# Back-compat alias: the single-PMCA engine is a 1-device cluster.
HeroEngine = HeroCluster

# Singleton cluster — the process's host-side orchestrator.
_ENGINE = HeroCluster()


def engine() -> HeroCluster:
    return _ENGINE


class offload_policy:
    """Context manager to scope policy/platform/topology changes.

    ::

        with offload_policy(mode="auto", platform="hesoc-vcu128",
                            num_devices=4, scheduler="cost-aware"):
            ...
    """

    def __init__(
        self,
        mode: Optional[str] = None,
        *,
        platform: Optional[str] = None,
        zero_copy: Optional[bool] = None,
        min_speedup: Optional[float] = None,
        resident_fraction: Optional[float] = None,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        num_devices: Optional[int] = None,
        scheduler: Optional[str] = None,
        pipeline_staging: Optional[bool] = None,
        pipeline_chunk_bytes: Optional[float] = None,
        prefetch_staging: Optional[bool] = None,
    ) -> None:
        self._overrides = {
            k: v
            for k, v in dict(
                mode=mode,
                zero_copy=zero_copy,
                min_speedup=min_speedup,
                resident_fraction=resident_fraction,
                use_pallas=use_pallas,
                interpret=interpret,
                pipeline_staging=pipeline_staging,
                pipeline_chunk_bytes=pipeline_chunk_bytes,
                prefetch_staging=prefetch_staging,
            ).items()
            if v is not None
        }
        self._platform = get_platform(platform) if platform else None
        self._num_devices = num_devices
        self._scheduler = scheduler
        self._saved_policy: Optional[OffloadPolicy] = None
        self._saved_platform: Optional[Platform] = None
        self._saved_devices: Optional[List[VirtualDevice]] = None
        self._saved_scheduler: Optional[str] = None
        self._saved_handles: Optional[Dict[str, DeviceHandle]] = None

    def __enter__(self) -> HeroCluster:
        eng = engine()
        self._saved_policy = dataclasses.replace(eng.policy)
        self._saved_platform = eng.platform
        self._saved_devices = eng.devices
        self._saved_scheduler = eng.scheduler
        self._saved_handles = dict(eng._handles)
        eng.policy = dataclasses.replace(eng.policy, **self._overrides)
        if self._platform is not None:
            eng.set_platform(self._platform)
        if self._num_devices is not None:
            eng._rebuild(self._num_devices)  # scoped topology: fresh devices
        if self._scheduler is not None:
            eng.set_scheduler(self._scheduler)
        return eng

    def __exit__(self, *exc) -> None:
        eng = engine()
        assert self._saved_policy is not None
        eng.policy = self._saved_policy
        eng.platform = self._saved_platform
        eng.devices = self._saved_devices
        for d in eng.devices:
            d.platform = self._saved_platform
        # handles pinned inside the scope die with it (their devices may be
        # scoped); residency marks they left on outer devices are evicted
        for name in set(eng._handles) - set(self._saved_handles):
            eng.evict(name)
        eng._handles = self._saved_handles
        if self._scheduler is not None:
            # only rebuild when overridden — rebuilding resets stateful
            # schedulers (round-robin's counter) in the outer scope
            eng.set_scheduler(self._saved_scheduler)
