"""Offload engine — the HeroSDK analogue (paper Fig. 2, boxes 1-2).

HeroSDK's ``libhero`` boots the PMCA, manages the manually-partitioned device
DRAM (``hero_allocator.c``) and copies shared structures into it before the
first offload; the OpenMP target library then launches kernels through it.

On the TPU target the XLA runtime owns physical allocation, so the engine's
job shifts to what still matters at framework scale:

* a **residency ledger** — which logical buffers (weights, caches) live on
  device and therefore never pay the ``data copy`` region again.  This is the
  device-DRAM partition bookkeeping, one level up;
* **zero-copy mode** — the paper's projected IOMMU path (donated / resident
  buffers instead of staged copies);
* **launch records** — every offload goes through :func:`HeroEngine.launch`,
  which scores it with the cost model and appends to the active trace,
  reproducing the paper's instrumentation.

The engine is deliberately stateful-but-tiny: it is the seam where a real
deployment would hang buffer donation, device health checks and retry logic,
and the fault-tolerance runtime (``repro.runtime``) drives it that way.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Set

from repro.core import accounting
from repro.core.cost_model import OpCost, RegionBreakdown, breakdown, decide_offload
from repro.core.platform import CPU_HOST, Platform, TPU_V5E, get_platform

__all__ = ["HeroEngine", "OffloadPolicy", "engine", "offload_policy"]


@dataclasses.dataclass
class OffloadPolicy:
    """How the dispatcher routes BLAS calls.

    mode:
      * ``"host"``   — never offload (paper's host-only baseline)
      * ``"device"`` — always offload (paper's offloaded run)
      * ``"auto"``   — offload iff the cost model predicts >= ``min_speedup``
    """

    mode: str = "auto"
    zero_copy: bool = False
    min_speedup: float = 1.0
    # Fraction of operand bytes assumed device-resident (weights in a
    # training step are resident; activations are produced on device too, so
    # inside jit everything is resident and the copy region vanishes — the
    # paper's IOMMU end-state).
    resident_fraction: float = 0.0
    # Prefer hand-written Pallas kernels over plain XLA lowering when legal.
    use_pallas: bool = False
    # Run Pallas kernels in interpret mode (CPU validation).
    interpret: bool = False

    def validate(self) -> None:
        if self.mode not in ("host", "device", "auto"):
            raise ValueError(f"bad offload mode {self.mode!r}")


class HeroEngine:
    """Device manager + offload router (singleton per process)."""

    def __init__(self, platform: Platform = TPU_V5E) -> None:
        self.platform = platform
        self.policy = OffloadPolicy()
        self._booted = False
        self._resident: Set[str] = set()
        self._l2_image_loaded = False

    # ---- lifecycle (mirrors hero_snitch.c boot / hero_allocator.c) -------
    def boot(self) -> None:
        """Analogue of booting the PMCA + copying device functions to L2."""
        self._booted = True
        self._l2_image_loaded = True

    def reset(self) -> None:
        self._booted = False
        self._l2_image_loaded = False
        self._resident.clear()

    @property
    def booted(self) -> bool:
        return self._booted

    # ---- residency ledger -------------------------------------------------
    def mark_resident(self, name: str) -> None:
        """Declare a logical buffer (e.g. 'params', 'kv_cache') device-resident."""
        self._resident.add(name)

    def evict(self, name: str) -> None:
        self._resident.discard(name)

    def is_resident(self, name: str) -> bool:
        return name in self._resident

    # ---- the offload decision + bookkeeping -------------------------------
    def launch(
        self,
        cost: OpCost,
        *,
        dtype: str,
        shape_key: str,
        pallas_eligible: bool = False,
        force_host: bool = False,
        note: str = "",
    ) -> str:
        """Route one BLAS call. Returns the chosen backend name.

        Called at trace time from ``repro.core.blas``; side effect is one
        :class:`accounting.OffloadRecord` on the active trace (if any).
        """
        pol = self.policy
        pol.validate()
        if force_host:  # ops compiled host-only (paper: syrk.c)
            bd = breakdown(
                cost,
                self.platform,
                zero_copy=pol.zero_copy,
                resident_fraction=pol.resident_fraction,
            )
            accounting.record(
                accounting.OffloadRecord(
                    op=cost.op, shape_key=shape_key, dtype=dtype,
                    backend="host", cost=cost, regions=bd,
                    zero_copy=pol.zero_copy, note=note or "host-only op",
                )
            )
            return "host"
        if pol.mode == "host":
            offload = False
            bd = breakdown(
                cost,
                self.platform,
                zero_copy=pol.zero_copy,
                resident_fraction=pol.resident_fraction,
            )
        elif pol.mode == "device":
            offload = True
            bd = breakdown(
                cost,
                self.platform,
                zero_copy=pol.zero_copy,
                resident_fraction=pol.resident_fraction,
            )
        else:  # auto — the paper's size-dependent decision
            offload, bd = decide_offload(
                cost,
                self.platform,
                zero_copy=pol.zero_copy,
                resident_fraction=pol.resident_fraction,
                min_speedup=pol.min_speedup,
            )
        if offload and not self._booted:
            self.boot()  # first offload boots the device, as in HeroSDK

        if not offload:
            backend = "host"
        elif pallas_eligible and pol.use_pallas:
            backend = "device-pallas"
        else:
            backend = "device"
        accounting.record(
            accounting.OffloadRecord(
                op=cost.op,
                shape_key=shape_key,
                dtype=dtype,
                backend=backend,
                cost=cost,
                regions=bd,
                zero_copy=pol.zero_copy,
                note=note,
            )
        )
        return backend


# Singleton engine — the process's one "device".
_ENGINE = HeroEngine()


def engine() -> HeroEngine:
    return _ENGINE


class offload_policy:
    """Context manager to scope policy/platform changes.

    ::

        with offload_policy(mode="auto", platform="hesoc-vcu128"):
            ...
    """

    def __init__(
        self,
        mode: Optional[str] = None,
        *,
        platform: Optional[str] = None,
        zero_copy: Optional[bool] = None,
        min_speedup: Optional[float] = None,
        resident_fraction: Optional[float] = None,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
    ) -> None:
        self._overrides = {
            k: v
            for k, v in dict(
                mode=mode,
                zero_copy=zero_copy,
                min_speedup=min_speedup,
                resident_fraction=resident_fraction,
                use_pallas=use_pallas,
                interpret=interpret,
            ).items()
            if v is not None
        }
        self._platform = get_platform(platform) if platform else None
        self._saved_policy: Optional[OffloadPolicy] = None
        self._saved_platform: Optional[Platform] = None

    def __enter__(self) -> HeroEngine:
        eng = engine()
        self._saved_policy = dataclasses.replace(eng.policy)
        self._saved_platform = eng.platform
        eng.policy = dataclasses.replace(eng.policy, **self._overrides)
        if self._platform is not None:
            eng.platform = self._platform
        return eng

    def __exit__(self, *exc) -> None:
        eng = engine()
        assert self._saved_policy is not None
        eng.policy = self._saved_policy
        eng.platform = self._saved_platform
