"""Three-region offload cost model — the paper's offload decision, generalized.

The paper decomposes offloaded runtime into ``data copy`` + ``fork/join`` +
``compute`` and shows offload pays off only once the compute gain outweighs
the two overhead regions (2.71x at n=128 on their heSoC).  This module turns
that observation into the dispatch policy: every BLAS call-site is scored
analytically from its static shapes and the active :class:`Platform`, and the
dispatcher offloads iff the model predicts a win.

All quantities are derived at *trace time* from static shapes — nothing here
touches device data, so the model is free to run inside ``jax.jit`` tracing.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.platform import Platform

__all__ = [
    "OpCost",
    "RegionBreakdown",
    "gemm_cost",
    "syrk_cost",
    "gemv_cost",
    "vector_cost",
    "attention_cost",
    "d2d_cost",
    "d2d_breakdown",
    "decide_offload",
]


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Static workload description of one BLAS-level call."""

    op: str
    flops: float            # useful FLOPs
    staged_bytes: float     # host<->device traffic if operands not resident
    touched_bytes: float    # device memory traffic (inputs+outputs, ideal)
    out_shape: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class RegionBreakdown:
    """The paper's Figure-3 decomposition for one call.

    ``d2d_s`` is a fourth region introduced for the cluster: device-to-device
    traffic when a pinned (resident) buffer migrates between PMCAs.  It rides
    the DMA engine like the host copy region, so the overlap timeline treats
    both as copy-stream work.
    """

    copy_s: float
    fork_join_s: float
    compute_s: float
    host_s: float           # host-only alternative
    d2d_s: float = 0.0      # device-to-device migration traffic

    @property
    def offload_s(self) -> float:
        return self.copy_s + self.fork_join_s + self.compute_s + self.d2d_s

    @property
    def speedup(self) -> float:
        return self.host_s / self.offload_s if self.offload_s > 0 else math.inf

    @property
    def copy_fraction(self) -> float:
        return self.copy_s / self.offload_s if self.offload_s > 0 else 0.0


def d2d_cost(nbytes: float, *, op: str = "d2d_copy") -> OpCost:
    """Workload of migrating one resident buffer device-to-device."""
    nbytes = float(nbytes)
    return OpCost(op=op, flops=0.0, staged_bytes=nbytes, touched_bytes=nbytes)


def d2d_breakdown(nbytes: float, platform: Platform) -> RegionBreakdown:
    """Score a pinned-buffer migration on ``platform``.

    The transfer occupies the DMA stream (``d2d_s``), plus one fork/join for
    the transfer descriptors.  ``host_s`` is the alternative the ROADMAP item
    calls out: dropping the buffer and re-staging it from host memory.
    """
    return RegionBreakdown(
        copy_s=0.0,
        fork_join_s=platform.t_fork_join(),
        compute_s=0.0,
        host_s=platform.t_copy(float(nbytes)),
        d2d_s=platform.t_d2d(float(nbytes)),
    )


# ---------------------------------------------------------------------------
# Workload models per BLAS op.
# ---------------------------------------------------------------------------

def gemm_cost(
    m: int,
    n: int,
    k: int,
    itemsize: int,
    *,
    batch: int = 1,
    op: str = "gemm",
) -> OpCost:
    """C[m,n] += A[m,k] @ B[k,n] — 2mnk flops, A+B in, C out."""
    flops = 2.0 * batch * m * n * k
    in_bytes = batch * (m * k + k * n) * itemsize
    out_bytes = batch * m * n * itemsize
    return OpCost(
        op=op,
        flops=flops,
        staged_bytes=in_bytes + out_bytes,
        touched_bytes=in_bytes + out_bytes,
        out_shape=(batch, m, n) if batch > 1 else (m, n),
    )


def syrk_cost(n: int, k: int, itemsize: int) -> OpCost:
    """C[n,n] = A[n,k] @ A.T — n^2 k flops (symmetric half)."""
    flops = float(n) * n * k
    in_bytes = n * k * itemsize
    out_bytes = n * n * itemsize
    return OpCost("syrk", flops, in_bytes + out_bytes, in_bytes + out_bytes, (n, n))


def gemv_cost(m: int, n: int, itemsize: int) -> OpCost:
    flops = 2.0 * m * n
    bytes_ = (m * n + n + m) * itemsize
    return OpCost("gemv", flops, bytes_, bytes_, (m,))


def vector_cost(op: str, n: int, itemsize: int, flops_per_elem: float = 2.0) -> OpCost:
    bytes_ = 2.0 * n * itemsize
    return OpCost(op, flops_per_elem * n, bytes_, bytes_, (n,))


def attention_cost(
    batch: int,
    q_len: int,
    kv_len: int,
    num_q_heads: int,
    head_dim: int,
    itemsize: int,
    *,
    window: Optional[int] = None,
) -> OpCost:
    """Flash-attention workload (QK^T + PV), window-clipped if sliding."""
    eff_kv = min(kv_len, window) if window else kv_len
    flops = 4.0 * batch * num_q_heads * q_len * eff_kv * head_dim
    io = batch * num_q_heads * (q_len + 2 * eff_kv + q_len) * head_dim * itemsize
    return OpCost("attention", flops, io, io)


# ---------------------------------------------------------------------------
# The offload decision.
# ---------------------------------------------------------------------------

def breakdown(
    cost: OpCost,
    platform: Platform,
    *,
    zero_copy: bool = False,
    resident_fraction: float = 0.0,
) -> RegionBreakdown:
    """Score one call on one platform.

    ``resident_fraction`` marks the share of ``staged_bytes`` already living
    in device memory (weights during training/serving): those never cross the
    host<->device link, reproducing the paper's observation that the copy
    region only exists for non-resident operands.
    """
    staged = cost.staged_bytes * (1.0 - resident_fraction)
    return RegionBreakdown(
        copy_s=platform.t_copy(staged, zero_copy=zero_copy),
        fork_join_s=platform.t_fork_join(),
        compute_s=platform.t_compute(cost.flops, cost.touched_bytes),
        host_s=platform.t_host(cost.flops),
    )


def decide_offload(
    cost: OpCost,
    platform: Platform,
    *,
    zero_copy: bool = False,
    resident_fraction: float = 0.0,
    min_speedup: float = 1.0,
) -> Tuple[bool, RegionBreakdown]:
    """Offload iff the modeled offload time beats host by ``min_speedup``."""
    bd = breakdown(
        cost,
        platform,
        zero_copy=zero_copy,
        resident_fraction=resident_fraction,
    )
    return bd.speedup >= min_speedup, bd


def crossover_size(
    platform: Platform,
    itemsize: int = 8,
    *,
    zero_copy: bool = False,
    lo: int = 2,
    hi: int = 1 << 16,
) -> int:
    """Smallest square GEMM size for which offload wins (paper's crossover)."""
    n = lo
    while n <= hi:
        ok, _ = decide_offload(gemm_cost(n, n, n, itemsize), platform, zero_copy=zero_copy)
        if ok:
            return n
        n *= 2
    return -1
