"""Three-region offload cost model — the paper's offload decision, generalized.

The paper decomposes offloaded runtime into ``data copy`` + ``fork/join`` +
``compute`` and shows offload pays off only once the compute gain outweighs
the two overhead regions (2.71x at n=128 on their heSoC).  This module turns
that observation into the dispatch policy: every BLAS call-site is scored
analytically from its static shapes and the active :class:`Platform`, and the
dispatcher offloads iff the model predicts a win.

All quantities are derived at *trace time* from static shapes — nothing here
touches device data, so the model is free to run inside ``jax.jit`` tracing.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

from repro.core.platform import Platform

__all__ = [
    "OpCost",
    "PipelinedBreakdown",
    "RegionBreakdown",
    "gemm_cost",
    "syrk_cost",
    "gemv_cost",
    "vector_cost",
    "attention_cost",
    "d2d_cost",
    "d2d_breakdown",
    "decide_offload",
    "pipeline_makespan",
    "pipelined_breakdown",
    "staging_legs",
]

# Backstop on the modeled chunk count: past this the per-chunk legs are so
# small the closed-form bubble is negligible, and O(chunks) simulation time
# stays bounded for huge staged_bytes / tiny chunk tiles.
MAX_PIPELINE_CHUNKS = 64


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Static workload description of one BLAS-level call."""

    op: str
    flops: float            # useful FLOPs
    staged_bytes: float     # host<->device traffic if operands not resident
    touched_bytes: float    # device memory traffic (inputs+outputs, ideal)
    out_shape: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class RegionBreakdown:
    """The paper's Figure-3 decomposition for one call.

    ``d2d_s`` is a fourth region introduced for the cluster: device-to-device
    traffic when a pinned (resident) buffer migrates between PMCAs.  It rides
    the DMA engine like the host copy region, so the overlap timeline treats
    both as copy-stream work.
    """

    copy_s: float
    fork_join_s: float
    compute_s: float
    host_s: float           # host-only alternative
    d2d_s: float = 0.0      # device-to-device migration traffic

    @property
    def offload_s(self) -> float:
        return self.copy_s + self.fork_join_s + self.compute_s + self.d2d_s

    @property
    def speedup(self) -> float:
        return self.host_s / self.offload_s if self.offload_s > 0 else math.inf

    @property
    def copy_fraction(self) -> float:
        return self.copy_s / self.offload_s if self.offload_s > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class PipelinedBreakdown(RegionBreakdown):
    """Region breakdown whose copy region overlaps compute (chunked staging).

    ``copy_s`` / ``compute_s`` keep their serial meaning (total DMA-stream
    seconds, total compute-engine seconds) so rollups that sum regions stay
    comparable with serial records; what changes is the *makespan*:
    ``offload_s`` is the double-buffered pipeline schedule of the two
    streams, not their sum.  The operand set is tiled into ``chunks`` DMA
    legs and the compute engine starts as soon as the first leg lands
    (prologue bubble) and finishes one compute leg after the last one
    (epilogue bubble) — the classic Pallas DMA-pipeline shape the
    ``kernels/gemm.py`` / flash kernels tile for.
    """

    chunks: int = 1
    # Copy+compute pipeline makespan, seconds (excludes fork/join and d2d).
    overlapped_s: float = 0.0
    # First DMA leg: compute is gated on this, not on the whole copy.
    first_copy_leg_s: float = 0.0

    @property
    def offload_s(self) -> float:
        return self.fork_join_s + self.overlapped_s + self.d2d_s

    @property
    def serial_s(self) -> float:
        """What the same call costs without overlap (the pre-pipeline model)."""
        return self.copy_s + self.fork_join_s + self.compute_s + self.d2d_s

    @property
    def hidden_copy_s(self) -> float:
        """Copy-stream seconds hidden under compute by the pipeline."""
        return max(self.copy_s + self.compute_s - self.overlapped_s, 0.0)

    @property
    def bubble_s(self) -> float:
        """Prologue + epilogue exposure beyond the dominant stream."""
        return max(self.overlapped_s - max(self.copy_s, self.compute_s), 0.0)

    @property
    def exposed_copy_s(self) -> float:
        """Copy time still on the critical path (not hidden under compute)."""
        return max(self.overlapped_s - self.compute_s, 0.0)

    @property
    def copy_fraction(self) -> float:
        """Share of offload time spent copying with the compute engine idle
        — the pipelined successor of the paper's T_copy/T_offload."""
        return self.exposed_copy_s / self.offload_s if self.offload_s > 0 else 0.0

    @property
    def pipelined_speedup(self) -> float:
        """Serial offload time over pipelined offload time (>= 1)."""
        return self.serial_s / self.offload_s if self.offload_s > 0 else 1.0


def staging_legs(staged_bytes: float, chunk_bytes: float) -> Tuple[float, ...]:
    """Split a staging transfer into DMA chunk legs (bytes per leg).

    ``chunk_bytes``-sized legs plus one remainder leg when the transfer does
    not divide evenly; degenerate inputs (zero bytes, non-positive chunk
    size, single-chunk transfers) collapse to one leg.  When the chunk tile
    would produce more than :data:`MAX_PIPELINE_CHUNKS` legs, the split
    falls back to that many equal legs (the modeled bubbles are already
    negligible at that depth).
    """
    staged_bytes = max(float(staged_bytes), 0.0)
    if staged_bytes <= 0.0:
        return (0.0,)
    if chunk_bytes is None or chunk_bytes <= 0.0 or chunk_bytes >= staged_bytes:
        return (staged_bytes,)
    n_full = int(staged_bytes // chunk_bytes)
    rem = staged_bytes - n_full * chunk_bytes
    k = n_full + (1 if rem > 0 else 0)
    if k > MAX_PIPELINE_CHUNKS:
        k = MAX_PIPELINE_CHUNKS
        return (staged_bytes / k,) * k
    legs = [float(chunk_bytes)] * n_full
    if rem > 0:
        legs.append(rem)
    return tuple(legs)


def pipeline_makespan(
    copy_legs: Sequence[float],
    compute_legs: Sequence[float],
    *,
    buffers: int = 2,
) -> float:
    """Makespan of a chunked copy->compute pipeline with ``buffers`` staging
    slots (double-buffering by default).

    Chunk i's compute starts once its copy has landed and chunk i-1's
    compute is done; its copy may start once a staging buffer frees up
    (chunk i-``buffers``'s compute done).  Always lies in
    ``[max(sum(copy), sum(compute)), sum(copy) + sum(compute)]``.
    """
    buffers = max(int(buffers), 1)
    dma = 0.0
    comp = 0.0
    ends: list = []
    for i, (c, w) in enumerate(zip(copy_legs, compute_legs)):
        start = dma if i < buffers else max(dma, ends[i - buffers])
        dma = start + c
        comp = max(comp, dma) + w
        ends.append(comp)
    return max(comp, dma)


def pipelined_breakdown(
    cost: OpCost,
    platform: Platform,
    *,
    chunks: Optional[int] = None,
    chunk_bytes: Optional[float] = None,
    zero_copy: bool = False,
    resident_fraction: float = 0.0,
) -> PipelinedBreakdown:
    """Score one call with chunked, double-buffered staging.

    The operand set is tiled into DMA legs (``chunks`` equal legs when
    given explicitly, else ``chunk_bytes``-sized legs — defaulting to the
    platform's ``dma_chunk_bytes``) and each leg's compute share overlaps
    the next leg's transfer.  Degenerate cases (one chunk, zero staged
    bytes, fully-resident operands) collapse to the serial model with no
    division hazards; ``copy_fraction`` is clamped non-negative.
    """
    resident_fraction = min(max(float(resident_fraction), 0.0), 1.0)
    staged = cost.staged_bytes * (1.0 - resident_fraction)
    copy_s = platform.t_copy(staged, zero_copy=zero_copy)
    compute_s = platform.t_compute(cost.flops, cost.touched_bytes)
    if chunks is not None:
        k = min(max(int(chunks), 1), MAX_PIPELINE_CHUNKS)
        byte_legs: Tuple[float, ...] = (
            (staged / k,) * k if staged > 0 else (0.0,) * k
        )
    else:
        qb = platform.dma_chunk_bytes if chunk_bytes is None else chunk_bytes
        byte_legs = staging_legs(staged, qb)
    k = len(byte_legs)
    copy_legs = [platform.t_copy(b, zero_copy=zero_copy) for b in byte_legs]
    # Each chunk's compute share is proportional to its byte share: the MXU
    # consumes the operands the DMA just landed.
    if staged > 0:
        compute_legs = [compute_s * (b / staged) for b in byte_legs]
    else:
        compute_legs = [compute_s / k] * k
    overlapped = pipeline_makespan(copy_legs, compute_legs)
    return PipelinedBreakdown(
        copy_s=copy_s,
        fork_join_s=platform.t_fork_join(),
        compute_s=compute_s,
        host_s=platform.t_host(cost.flops),
        chunks=k,
        overlapped_s=overlapped,
        first_copy_leg_s=copy_legs[0] if copy_legs else 0.0,
    )


def d2d_cost(nbytes: float, *, op: str = "d2d_copy") -> OpCost:
    """Workload of migrating one resident buffer device-to-device."""
    nbytes = float(nbytes)
    return OpCost(op=op, flops=0.0, staged_bytes=nbytes, touched_bytes=nbytes)


def d2d_breakdown(nbytes: float, platform: Platform) -> RegionBreakdown:
    """Score a pinned-buffer migration on ``platform``.

    The transfer occupies the DMA stream (``d2d_s``), plus one fork/join for
    the transfer descriptors.  ``host_s`` is the alternative the ROADMAP item
    calls out: dropping the buffer and re-staging it from host memory.
    """
    return RegionBreakdown(
        copy_s=0.0,
        fork_join_s=platform.t_fork_join(),
        compute_s=0.0,
        host_s=platform.t_copy(float(nbytes)),
        d2d_s=platform.t_d2d(float(nbytes)),
    )


# ---------------------------------------------------------------------------
# Workload models per BLAS op.
# ---------------------------------------------------------------------------

def gemm_cost(
    m: int,
    n: int,
    k: int,
    itemsize: int,
    *,
    batch: int = 1,
    op: str = "gemm",
) -> OpCost:
    """C[m,n] += A[m,k] @ B[k,n] — 2mnk flops, A+B in, C out."""
    flops = 2.0 * batch * m * n * k
    in_bytes = batch * (m * k + k * n) * itemsize
    out_bytes = batch * m * n * itemsize
    return OpCost(
        op=op,
        flops=flops,
        staged_bytes=in_bytes + out_bytes,
        touched_bytes=in_bytes + out_bytes,
        out_shape=(batch, m, n) if batch > 1 else (m, n),
    )


def syrk_cost(n: int, k: int, itemsize: int) -> OpCost:
    """C[n,n] = A[n,k] @ A.T — n^2 k flops (symmetric half)."""
    flops = float(n) * n * k
    in_bytes = n * k * itemsize
    out_bytes = n * n * itemsize
    return OpCost("syrk", flops, in_bytes + out_bytes, in_bytes + out_bytes, (n, n))


def gemv_cost(m: int, n: int, itemsize: int) -> OpCost:
    flops = 2.0 * m * n
    bytes_ = (m * n + n + m) * itemsize
    return OpCost("gemv", flops, bytes_, bytes_, (m,))


def vector_cost(op: str, n: int, itemsize: int, flops_per_elem: float = 2.0) -> OpCost:
    bytes_ = 2.0 * n * itemsize
    return OpCost(op, flops_per_elem * n, bytes_, bytes_, (n,))


def attention_cost(
    batch: int,
    q_len: int,
    kv_len: int,
    num_q_heads: int,
    head_dim: int,
    itemsize: int,
    *,
    window: Optional[int] = None,
) -> OpCost:
    """Flash-attention workload (QK^T + PV), window-clipped if sliding."""
    eff_kv = min(kv_len, window) if window else kv_len
    flops = 4.0 * batch * num_q_heads * q_len * eff_kv * head_dim
    io = batch * num_q_heads * (q_len + 2 * eff_kv + q_len) * head_dim * itemsize
    return OpCost("attention", flops, io, io)


# ---------------------------------------------------------------------------
# The offload decision.
# ---------------------------------------------------------------------------

def breakdown(
    cost: OpCost,
    platform: Platform,
    *,
    zero_copy: bool = False,
    resident_fraction: float = 0.0,
) -> RegionBreakdown:
    """Score one call on one platform.

    ``resident_fraction`` marks the share of ``staged_bytes`` already living
    in device memory (weights during training/serving): those never cross the
    host<->device link, reproducing the paper's observation that the copy
    region only exists for non-resident operands.
    """
    staged = cost.staged_bytes * (1.0 - resident_fraction)
    return RegionBreakdown(
        copy_s=platform.t_copy(staged, zero_copy=zero_copy),
        fork_join_s=platform.t_fork_join(),
        compute_s=platform.t_compute(cost.flops, cost.touched_bytes),
        host_s=platform.t_host(cost.flops),
    )


def decide_offload(
    cost: OpCost,
    platform: Platform,
    *,
    zero_copy: bool = False,
    resident_fraction: float = 0.0,
    min_speedup: float = 1.0,
    pipeline: bool = False,
    chunk_bytes: Optional[float] = None,
) -> Tuple[bool, RegionBreakdown]:
    """Offload iff the modeled offload time beats host by ``min_speedup``.

    With ``pipeline=True`` the decision is scored against the chunked
    double-buffered staging model — overlap lowers ``offload_s``, so the
    paper's crossover moves down when the runtime can pipeline.
    """
    if pipeline:
        bd: RegionBreakdown = pipelined_breakdown(
            cost,
            platform,
            chunk_bytes=chunk_bytes,
            zero_copy=zero_copy,
            resident_fraction=resident_fraction,
        )
    else:
        bd = breakdown(
            cost,
            platform,
            zero_copy=zero_copy,
            resident_fraction=resident_fraction,
        )
    return bd.speedup >= min_speedup, bd


def crossover_size(
    platform: Platform,
    itemsize: int = 8,
    *,
    zero_copy: bool = False,
    lo: int = 2,
    hi: int = 1 << 16,
) -> int:
    """Smallest square GEMM size for which offload wins (paper's crossover)."""
    n = lo
    while n <= hi:
        ok, _ = decide_offload(gemm_cost(n, n, n, itemsize), platform, zero_copy=zero_copy)
        if ok:
            return n
        n *= 2
    return -1
