"""Offload accounting — the paper's three-region runtime instrumentation.

The paper measures each offloaded call as ``data copy`` / ``fork-join`` /
``compute`` regions.  We reproduce that bookkeeping at the BLAS seam: every
dispatched call appends an :class:`OffloadRecord` carrying the op, static
shapes, chosen backend, and the modeled region breakdown.  Recording happens
at trace time (shapes are static), so the trace is available both for eager
NumPy-style use and for jitted training steps.

Usage::

    with offload_trace() as trace:
        y = blas.gemm(a, b)
    print(trace.summary())
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, List, Optional, Tuple

from repro.core.cost_model import OpCost, RegionBreakdown

__all__ = [
    "OffloadRecord",
    "OffloadTrace",
    "offload_trace",
    "current_trace",
    "scaled",
    "current_scale",
]


@dataclasses.dataclass(frozen=True)
class OffloadRecord:
    op: str
    shape_key: str
    dtype: str
    backend: str                # "host" | "device" | "device-pallas"
    cost: OpCost
    regions: RegionBreakdown
    zero_copy: bool
    note: str = ""
    # Structural multiplier: a record captured inside a lax.scan body is
    # traced once but executes `count` times (layer stacks, microbatches,
    # kv chunks).  Aggregations weight by this.
    count: float = 1.0


class OffloadTrace:
    """Accumulates records for one traced region of the application."""

    def __init__(self) -> None:
        self.records: List[OffloadRecord] = []

    def add(self, rec: OffloadRecord) -> None:
        self.records.append(rec)

    # ---- aggregation ----------------------------------------------------
    def totals(self) -> Tuple[float, float, float, float]:
        """(copy_s, fork_join_s, compute_s, host_only_s) over offloaded calls."""
        copy = fork = comp = host = 0.0
        for r in self.records:
            if r.backend.startswith("device"):
                copy += r.regions.copy_s * r.count
                fork += r.regions.fork_join_s * r.count
                comp += r.regions.compute_s * r.count
            host += r.regions.host_s * r.count
        return copy, fork, comp, host

    def offloaded(self) -> List[OffloadRecord]:
        return [r for r in self.records if r.backend.startswith("device")]

    def host_only(self) -> List[OffloadRecord]:
        return [r for r in self.records if not r.backend.startswith("device")]

    def total_flops(self) -> float:
        return sum(r.cost.flops * r.count for r in self.records)

    def total_touched_bytes(self) -> float:
        """Kernel-ideal device-memory traffic: each op streams its operands
        and results exactly once (the SPM/VMEM-tiled execution the paper's
        device kernels implement)."""
        return sum(r.cost.touched_bytes * r.count for r in self.records)

    def total_staged_bytes(self) -> float:
        return sum(r.cost.staged_bytes * r.count for r in self.offloaded())

    def summary(self) -> str:
        copy, fork, comp, host = self.totals()
        off = copy + fork + comp
        lines = [
            f"offload trace: {len(self.records)} calls "
            f"({len(self.offloaded())} offloaded, {len(self.host_only())} host)",
            f"  regions  copy={copy:.6f}s  fork/join={fork:.6f}s  compute={comp:.6f}s",
            f"  offload total={off:.6f}s   host-only equivalent={host:.6f}s",
        ]
        if off > 0:
            lines.append(
                f"  modeled speedup={host / off:.2f}x   copy fraction={copy / off:.1%}"
            )
        return "\n".join(lines)

    def by_op(self) -> dict:
        agg: dict = {}
        for r in self.records:
            d = agg.setdefault(r.op, {"calls": 0, "flops": 0.0, "offloaded": 0})
            d["calls"] += 1
            d["flops"] += r.cost.flops
            d["offloaded"] += int(r.backend.startswith("device"))
        return agg


# Module-level stacks (single-threaded tracing; matches JAX's own model).
_TRACE_STACK: List[OffloadTrace] = []
_SCALE_STACK: List[float] = []


def current_trace() -> Optional[OffloadTrace]:
    return _TRACE_STACK[-1] if _TRACE_STACK else None


def current_scale() -> float:
    s = 1.0
    for m in _SCALE_STACK:
        s *= m
    return s


@contextlib.contextmanager
def scaled(mult: float) -> Iterator[None]:
    """Mark the enclosed trace region as executing ``mult`` times (scan body)."""
    _SCALE_STACK.append(float(mult))
    try:
        yield
    finally:
        _SCALE_STACK.pop()


@contextlib.contextmanager
def offload_trace() -> Iterator[OffloadTrace]:
    t = OffloadTrace()
    _TRACE_STACK.append(t)
    try:
        yield t
    finally:
        _TRACE_STACK.pop()


def record(rec: OffloadRecord) -> None:
    t = current_trace()
    if t is not None:
        t.add(dataclasses.replace(rec, count=current_scale()))
