"""Offload accounting — the paper's three-region runtime instrumentation.

The paper measures each offloaded call as ``data copy`` / ``fork-join`` /
``compute`` regions.  We reproduce that bookkeeping at the BLAS seam: every
dispatched call appends an :class:`OffloadRecord` carrying the op, static
shapes, chosen backend, and the modeled region breakdown.  Recording happens
at trace time (shapes are static), so the trace is available both for eager
NumPy-style use and for jitted training steps.

Usage::

    with offload_trace() as trace:
        y = blas.gemm(a, b)
    print(trace.summary())
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.cost_model import OpCost, RegionBreakdown

__all__ = [
    "DeviceAggregate",
    "DeviceTimeline",
    "GraphAggregate",
    "LatencyStats",
    "OffloadRecord",
    "OffloadTrace",
    "RequestMetrics",
    "SLOReport",
    "SLOStats",
    "offload_trace",
    "current_trace",
    "percentile",
    "scaled",
    "current_scale",
    "graph_region",
    "current_graph",
    "slo_report",
]


@dataclasses.dataclass(frozen=True)
class OffloadRecord:
    op: str
    shape_key: str
    dtype: str
    backend: str                # "host" | "device" | "device-pallas"
    cost: OpCost
    regions: RegionBreakdown
    zero_copy: bool
    note: str = ""
    # Structural multiplier: a record captured inside a lax.scan body is
    # traced once but executes `count` times (layer stacks, microbatches,
    # kv chunks).  Aggregations weight by this.
    count: float = 1.0
    # Cluster placement: which virtual PMCA ran the call (-1 = host).
    device_id: int = -1
    # Effective operand-residency credit the launch applied: the fraction of
    # ``cost.staged_bytes`` that never crossed the host<->device link (graph
    # scheduling threads exact per-call fractions; eager calls carry the
    # policy default).
    resident_fraction: float = 0.0
    # Graph scope this call was lowered under ("" = eager call site).  Set by
    # the ambient :func:`graph_region`, the way ``count`` is set by `scaled`.
    graph: str = ""

    @property
    def staged_bytes_charged(self) -> float:
        """Host<->device bytes actually paid after the residency credit."""
        return self.cost.staged_bytes * (1.0 - self.resident_fraction)


@dataclasses.dataclass
class DeviceAggregate:
    """Per-device rollup of offloaded calls (the paper's regions, per PMCA)."""

    device_id: int
    calls: float = 0.0
    copy_s: float = 0.0
    fork_join_s: float = 0.0
    compute_s: float = 0.0
    flops: float = 0.0
    staged_bytes: float = 0.0
    d2d_s: float = 0.0          # inbound pinned-buffer migrations

    @property
    def offload_s(self) -> float:
        return self.copy_s + self.fork_join_s + self.compute_s + self.d2d_s


@dataclasses.dataclass
class GraphAggregate:
    """Rollup of one graph region's offloaded calls (``repro.hnp`` lowers a
    whole expression graph under one :func:`graph_region` scope)."""

    graph: str
    calls: float = 0.0
    copy_s: float = 0.0
    fork_join_s: float = 0.0
    compute_s: float = 0.0
    d2d_s: float = 0.0
    host_s: float = 0.0
    flops: float = 0.0
    staged_bytes: float = 0.0           # bytes the eager path would stage
    staged_bytes_charged: float = 0.0   # bytes actually staged after credit

    @property
    def offload_s(self) -> float:
        return self.copy_s + self.fork_join_s + self.compute_s + self.d2d_s

    @property
    def staged_bytes_saved(self) -> float:
        return self.staged_bytes - self.staged_bytes_charged


@dataclasses.dataclass
class DeviceTimeline:
    """Modeled copy/compute overlap on one device's launch stream.

    Two resources per PMCA, as on the real part: the DMA engine (data
    copy) and the compute cluster (fork/join + kernel).  Launch k's copy
    streams while launch k-1 computes (double-buffering); its compute
    starts once both its copy is done and the compute engine frees up.
    ``makespan_s <= serial_s`` always; the gap is hidden copy time.
    """

    device_id: int
    makespan_s: float
    serial_s: float
    # Stream occupancy: seconds each engine was busy across the stream.
    # ``dma_busy_s`` counts host staging + inbound d2d; a fully-resident
    # launch contributes zero here.  ``makespan_s >= max(dma_busy_s,
    # compute_busy_s)`` and ``dma_busy_s + compute_busy_s >= serial_s`` need
    # not hold individually — the two engines run concurrently.
    dma_busy_s: float = 0.0
    compute_busy_s: float = 0.0

    @property
    def hidden_copy_s(self) -> float:
        return self.serial_s - self.makespan_s

    @property
    def overlap_efficiency(self) -> float:
        return self.serial_s / self.makespan_s if self.makespan_s > 0 else 1.0


class OffloadTrace:
    """Accumulates records for one traced region of the application."""

    def __init__(self) -> None:
        self.records: List[OffloadRecord] = []

    def add(self, rec: OffloadRecord) -> None:
        self.records.append(rec)

    # ---- aggregation ----------------------------------------------------
    def totals(self) -> Tuple[float, float, float, float]:
        """(copy_s, fork_join_s, compute_s, host_only_s) over offloaded calls."""
        copy = fork = comp = host = 0.0
        for r in self.records:
            if r.backend.startswith("device"):
                copy += r.regions.copy_s * r.count
                fork += r.regions.fork_join_s * r.count
                comp += r.regions.compute_s * r.count
            host += r.regions.host_s * r.count
        return copy, fork, comp, host

    def offloaded(self) -> List[OffloadRecord]:
        return [r for r in self.records if r.backend.startswith("device")]

    def host_only(self) -> List[OffloadRecord]:
        return [r for r in self.records if not r.backend.startswith("device")]

    def total_flops(self) -> float:
        return sum(r.cost.flops * r.count for r in self.records)

    def total_touched_bytes(self) -> float:
        """Kernel-ideal device-memory traffic: each op streams its operands
        and results exactly once (the SPM/VMEM-tiled execution the paper's
        device kernels implement)."""
        return sum(r.cost.touched_bytes * r.count for r in self.records)

    def total_staged_bytes(self) -> float:
        return sum(r.cost.staged_bytes * r.count for r in self.offloaded())

    def summary(self) -> str:
        copy, fork, comp, host = self.totals()
        d2d = self.total_d2d_s()
        # d2d migrations are part of what the offload path pays, so they
        # belong in the total and the speedup denominator (keeps this line
        # consistent with the per-device offload_s rollups below).
        off = copy + fork + comp + d2d
        lines = [
            f"offload trace: {len(self.records)} calls "
            f"({len(self.offloaded())} offloaded, {len(self.host_only())} host)",
            f"  regions  copy={copy:.6f}s  fork/join={fork:.6f}s  compute={comp:.6f}s",
            f"  offload total={off:.6f}s   host-only equivalent={host:.6f}s",
        ]
        if off > 0:
            lines.append(
                f"  modeled speedup={host / off:.2f}x   copy fraction={copy / off:.1%}"
            )
        if d2d > 0:
            lines.append(f"  d2d migrations={d2d:.6f}s")
        devs = self.by_device()
        if len(devs) > 1 or (devs and next(iter(devs)) != 0):
            for did in sorted(devs):
                d = devs[did]
                lines.append(
                    f"  device {did}: {d.calls:.0f} launches  "
                    f"offload={d.offload_s:.6f}s  flops={d.flops:.3e}"
                )
            lines.append(
                f"  cluster makespan={self.cluster_makespan_s():.6f}s "
                f"(copy/compute overlap modeled)"
            )
        return "\n".join(lines)

    # ---- per-device aggregation (cluster view) --------------------------
    def by_device(self) -> Dict[int, DeviceAggregate]:
        """Offloaded work grouped by virtual device (host records excluded).

        Invariant: summing any region over the aggregates equals the same
        region in :meth:`totals` — per-device traces add up to the cluster
        total (asserted in tests/test_cluster.py).
        """
        agg: Dict[int, DeviceAggregate] = {}
        for r in self.offloaded():
            d = agg.setdefault(r.device_id, DeviceAggregate(r.device_id))
            d.calls += r.count
            d.copy_s += r.regions.copy_s * r.count
            d.fork_join_s += r.regions.fork_join_s * r.count
            d.compute_s += r.regions.compute_s * r.count
            d.flops += r.cost.flops * r.count
            d.staged_bytes += r.cost.staged_bytes * r.count
            d.d2d_s += r.regions.d2d_s * r.count
        return agg

    def by_graph(self) -> Dict[str, GraphAggregate]:
        """Offloaded work grouped by graph region (eager records under "").

        The per-graph rollup is what the ``hnp`` frontend reports: how much
        staging the residency threading actually saved for one lowered
        expression graph, next to the region seconds it paid."""
        agg: Dict[str, GraphAggregate] = {}
        for r in self.offloaded():
            g = agg.setdefault(r.graph, GraphAggregate(r.graph))
            g.calls += r.count
            g.copy_s += r.regions.copy_s * r.count
            g.fork_join_s += r.regions.fork_join_s * r.count
            g.compute_s += r.regions.compute_s * r.count
            g.d2d_s += r.regions.d2d_s * r.count
            g.host_s += r.regions.host_s * r.count
            g.flops += r.cost.flops * r.count
            g.staged_bytes += r.cost.staged_bytes * r.count
            g.staged_bytes_charged += r.staged_bytes_charged * r.count
        return agg

    def total_staged_bytes_charged(self) -> float:
        """Host<->device bytes actually paid (residency credits applied)."""
        return sum(r.staged_bytes_charged * r.count for r in self.offloaded())

    def total_d2d_s(self) -> float:
        """Modeled device-to-device migration seconds (pinned-handle moves)."""
        return sum(r.regions.d2d_s * r.count for r in self.offloaded())

    def device_timelines(self) -> Dict[int, DeviceTimeline]:
        """Modeled copy/compute-overlap timeline per device.

        Records repeated ``count`` times (scan bodies) are unrolled as
        ``count`` back-to-back launches of the same shape.
        """
        streams: Dict[int, List[OffloadRecord]] = {}
        for r in self.offloaded():
            streams.setdefault(r.device_id, []).append(r)
        out: Dict[int, DeviceTimeline] = {}
        for dev, recs in streams.items():
            dma_free = 0.0
            compute_free = 0.0
            serial = 0.0
            dma_busy = 0.0
            compute_busy = 0.0
            for r in recs:
                n = max(int(round(r.count)), 1)
                # A fully-resident launch stages nothing: its operands
                # already live in device memory, so it must not occupy the
                # DMA engine (regression: satellite of ISSUE 6).
                staging = 0.0 if r.resident_fraction >= 1.0 else r.regions.copy_s
                # host staging and d2d migration both occupy the DMA engine
                copy = staging + r.regions.d2d_s
                work = r.regions.fork_join_s + r.regions.compute_s
                # Chunk-gated start: a pipelined launch's compute may begin
                # once its *first* staging leg lands (double-buffered DMA);
                # a monolithic launch waits for the whole copy.
                first = getattr(r.regions, "first_copy_leg_s", None)
                chunks = getattr(r.regions, "chunks", 1)
                gate = (
                    first if (first is not None and chunks > 1) else staging
                ) + r.regions.d2d_s
                # first repeat explicitly...
                start = dma_free
                dma_free += copy
                compute_free = max(compute_free, start + gate) + work
                # ...then n-1 identical repeats in closed form: each adds
                # `copy` to the DMA stream, and the compute stream is
                # whichever resource is the bottleneck (O(1), not O(n) —
                # scan-body records can carry counts in the thousands)
                if n > 1:
                    k = n - 1
                    dma_free += k * copy
                    compute_free = max(
                        compute_free + k * work,
                        dma_free - copy + gate + work,
                    )
                serial += n * (staging + r.regions.d2d_s + work)
                dma_busy += n * copy
                compute_busy += n * work
            out[dev] = DeviceTimeline(
                device_id=dev,
                makespan_s=max(compute_free, dma_free),
                serial_s=serial,
                dma_busy_s=dma_busy,
                compute_busy_s=compute_busy,
            )
        return out

    def cluster_makespan_s(self) -> float:
        """Modeled wall-clock of the offloaded work: devices run in
        parallel, each overlapping copy with compute."""
        tls = self.device_timelines()
        return max((t.makespan_s for t in tls.values()), default=0.0)

    def by_op(self) -> dict:
        agg: dict = {}
        for r in self.records:
            d = agg.setdefault(r.op, {"calls": 0, "flops": 0.0, "offloaded": 0})
            d["calls"] += 1
            d["flops"] += r.cost.flops
            d["offloaded"] += int(r.backend.startswith("device"))
        return agg


# ---------------------------------------------------------------------------
# Per-request SLO accounting (the streaming serve engine's ledger).
#
# ``serve_cluster`` reports one makespan; production serving is judged per
# *request*: time to first token (TTFT), per-token decode latency, and their
# tail percentiles per request class.  These records are modeled seconds off
# the LaunchTicket event clocks — never wall clock — so two runs with the
# same seed produce byte-identical reports.
# ---------------------------------------------------------------------------

def percentile(values: Sequence[float], q: float) -> float:
    """Deterministic linear-interpolation percentile (``q`` in [0, 100]).

    Stdlib-only twin of ``numpy.percentile(..., method="linear")`` so the
    accounting layer stays import-light and the SLO math has no backend
    drift.  Empty input returns 0.0 (an empty class shows empty stats, not
    a crash)."""
    vals = sorted(float(v) for v in values)
    if not vals:
        return 0.0
    if len(vals) == 1:
        return vals[0]
    q = min(max(float(q), 0.0), 100.0)
    pos = (len(vals) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """p50/p95/p99 + mean over one latency population (modeled seconds)."""

    n: int
    mean_s: float
    p50_s: float
    p95_s: float
    p99_s: float
    max_s: float

    @classmethod
    def from_values(cls, values: Sequence[float]) -> "LatencyStats":
        vals = [float(v) for v in values]
        if not vals:
            return cls(0, 0.0, 0.0, 0.0, 0.0, 0.0)
        return cls(
            n=len(vals),
            mean_s=sum(vals) / len(vals),
            p50_s=percentile(vals, 50),
            p95_s=percentile(vals, 95),
            p99_s=percentile(vals, 99),
            max_s=max(vals),
        )

    def as_dict(self) -> Dict[str, float]:
        return {
            "n": self.n, "mean_s": self.mean_s, "p50_s": self.p50_s,
            "p95_s": self.p95_s, "p99_s": self.p99_s, "max_s": self.max_s,
        }


@dataclasses.dataclass
class RequestMetrics:
    """One served (or rejected) request's modeled lifecycle timestamps."""

    rid: int
    req_class: str
    arrival_s: float
    prompt_len: int
    output_len: int
    admitted: bool = True
    prefill_done_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0
    tokens_out: int = 0
    # Completion-to-completion gap of each decode token after the first
    # (the population the per-token percentiles are computed over).
    token_latencies_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def completed(self) -> bool:
        return self.admitted and self.tokens_out >= self.output_len

    @property
    def ttft_s(self) -> float:
        """Arrival -> first emitted token (queueing + prefill + first step)."""
        return self.first_token_s - self.arrival_s

    @property
    def e2e_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass(frozen=True)
class SLOStats:
    """Latency rollup for one request class (or ``"all"``)."""

    req_class: str
    requests: int               # admitted requests of this class
    completed: int
    ttft: LatencyStats
    per_token: LatencyStats
    e2e: LatencyStats

    def as_dict(self) -> dict:
        return {
            "class": self.req_class,
            "requests": self.requests,
            "completed": self.completed,
            "ttft": self.ttft.as_dict(),
            "per_token": self.per_token.as_dict(),
            "e2e": self.e2e.as_dict(),
        }


@dataclasses.dataclass(frozen=True)
class SLOReport:
    """Per-class + overall SLO accounting for one serving run.

    ``meets_slo`` is the serving acceptance question: did the p99 tails of
    the *completed* population stay inside the stated TTFT and per-token
    budgets?  (Rejected requests are counted by the engine's reject rate,
    not here — an admission-controlled server keeps its served tails inside
    SLO precisely by shedding load.)"""

    classes: Dict[str, SLOStats]
    ttft_slo_s: float = 0.0
    per_token_slo_s: float = 0.0

    @property
    def overall(self) -> SLOStats:
        return self.classes["all"]

    @property
    def meets_slo(self) -> bool:
        o = self.overall
        if o.completed == 0:
            return False
        ok = True
        if self.ttft_slo_s > 0:
            ok = ok and o.ttft.p99_s <= self.ttft_slo_s
        if self.per_token_slo_s > 0:
            ok = ok and o.per_token.p99_s <= self.per_token_slo_s
        return ok

    def as_dict(self) -> dict:
        return {
            "ttft_slo_s": self.ttft_slo_s,
            "per_token_slo_s": self.per_token_slo_s,
            "meets_slo": self.meets_slo,
            "classes": {k: v.as_dict() for k, v in self.classes.items()},
        }


def _class_stats(req_class: str, metrics: List[RequestMetrics]) -> SLOStats:
    done = [m for m in metrics if m.completed]
    return SLOStats(
        req_class=req_class,
        requests=len(metrics),
        completed=len(done),
        ttft=LatencyStats.from_values([m.ttft_s for m in done]),
        per_token=LatencyStats.from_values(
            [lat for m in done for lat in m.token_latencies_s]
        ),
        e2e=LatencyStats.from_values([m.e2e_s for m in done]),
    )


def slo_report(
    metrics: Sequence[RequestMetrics],
    *,
    ttft_slo_s: float = 0.0,
    per_token_slo_s: float = 0.0,
) -> SLOReport:
    """Roll per-request metrics up into per-class p50/p95/p99 SLO stats.

    Rejected requests (``admitted=False``) are excluded from the latency
    populations — they never produced a token; the engine reports them as
    its reject rate."""
    admitted = [m for m in metrics if m.admitted]
    classes: Dict[str, List[RequestMetrics]] = {}
    for m in admitted:
        classes.setdefault(m.req_class, []).append(m)
    out = {c: _class_stats(c, ms) for c, ms in sorted(classes.items())}
    out["all"] = _class_stats("all", admitted)
    return SLOReport(
        classes=out, ttft_slo_s=ttft_slo_s, per_token_slo_s=per_token_slo_s
    )


# Module-level stacks (single-threaded tracing; matches JAX's own model).
_TRACE_STACK: List[OffloadTrace] = []
_SCALE_STACK: List[float] = []
_GRAPH_STACK: List[str] = []


def current_trace() -> Optional[OffloadTrace]:
    return _TRACE_STACK[-1] if _TRACE_STACK else None


def current_scale() -> float:
    s = 1.0
    for m in _SCALE_STACK:
        s *= m
    return s


@contextlib.contextmanager
def scaled(mult: float) -> Iterator[None]:
    """Mark the enclosed trace region as executing ``mult`` times (scan body)."""
    _SCALE_STACK.append(float(mult))
    try:
        yield
    finally:
        _SCALE_STACK.pop()


def current_graph() -> str:
    return _GRAPH_STACK[-1] if _GRAPH_STACK else ""


@contextlib.contextmanager
def graph_region(name: str) -> Iterator[None]:
    """Stamp every record in the scope as belonging to graph ``name``.

    Entered by the ``hnp`` scheduler around one lowered expression graph
    (including the d2d migrations its residency threading triggers), so
    :meth:`OffloadTrace.by_graph` can roll the whole graph up."""
    _GRAPH_STACK.append(str(name))
    try:
        yield
    finally:
        _GRAPH_STACK.pop()


@contextlib.contextmanager
def offload_trace() -> Iterator[OffloadTrace]:
    t = OffloadTrace()
    _TRACE_STACK.append(t)
    try:
        yield t
    finally:
        _TRACE_STACK.pop()


def record(rec: OffloadRecord) -> None:
    t = current_trace()
    if t is not None:
        t.add(
            dataclasses.replace(
                rec, count=current_scale(), graph=rec.graph or current_graph()
            )
        )
