"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

At 1000+ nodes the DP gradient all-reduce is the largest recurring
collective.  This module quantizes each gradient leaf to int8 with a per-leaf
fp32 scale *before* the reduction (4x wire-bytes reduction on the ICI) and
keeps the quantization error in a local error-feedback buffer added back the
next step — the standard convergence-preserving trick (1-bit Adam lineage).

Two entry points:
  * ``compress_decompress``   — quantize→dequantize with error feedback,
    used inside a pjit'd train step (XLA still all-reduces fp32 wires, but
    numerics match the compressed path; the wire win needs shard_map).
  * ``compressed_psum``       — the real thing under ``shard_map``: int8
    psum over the ``data`` axis (int32 accumulator), then dequantize.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_buffer", "compress_decompress", "compressed_psum"]


def init_error_buffer(grads) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )


def _quant_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(g / safe), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, err) -> Tuple[Any, Any]:
    """Returns (compressed-then-restored grads, new error buffers)."""

    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant_leaf(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])


def compressed_psum(grads, err, axis_name: str) -> Tuple[Any, Any]:
    """int8 psum over ``axis_name`` with error feedback (use under shard_map).

    The quantization scale must be SHARED across participants before
    quantizing (one tiny scalar pmax per leaf) — summing int8 payloads
    quantized at per-device scales and rescaling afterwards is not a sum.
    """

    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        local_scale = jnp.max(jnp.abs(gf)) / 127.0
        scale = jax.lax.pmax(local_scale, axis_name)       # scalar exchange
        safe = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(gf / safe), -127, 127).astype(jnp.int8)
        # int32 accumulate avoids overflow for <= 2^24 participants
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq = tot.astype(jnp.float32) * safe
        local_restored = q.astype(jnp.float32) * safe
        return deq.astype(g.dtype), gf - local_restored

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])
