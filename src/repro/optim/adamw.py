"""AdamW (+ blockwise-int8 moment variant) — hand-rolled, pure pytrees.

The 8-bit variant stores both Adam moments as int8 with per-block fp32
scales (bitsandbytes-style blockwise dynamic quantization).  It exists for
the ≥398B MoE architectures, where fp32 moments alone (8 bytes/param) exceed
the 256-chip pod's HBM — with int8 moments the arctic-480b / jamba-398b
training cells fit (see EXPERIMENTS.md §Dry-run memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "adamw8bit", "clip_by_global_norm", "OptState"]

QBLOCK = 256  # quantization block (elements)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# fp32-moment AdamW
# ---------------------------------------------------------------------------

def adamw(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state: OptState, params) -> Tuple[Any, OptState]:
        grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * jnp.square(gf)
            mh = m2 / c1
            vh = v2 / c2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state.mu)
        flat_v = jax.tree_util.tree_leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, mu=new_m, nu=new_v)

    return init, update


# ---------------------------------------------------------------------------
# blockwise int8 moments
# ---------------------------------------------------------------------------

class QTensor(NamedTuple):
    q: jax.Array        # int8, original shape
    scale: jax.Array    # fp32, (..., last_dim / qblock) — axis-aligned blocks


def _qblock_for(last_dim: int) -> int:
    """Largest power-of-two block ≤ QBLOCK dividing the last dim.

    Blocks are axis-aligned (the last dim is split, never the whole leaf
    flattened): a flatten-reshape destroys the parameter's sharding and
    GSPMD then REPLICATES the fp32 moment buffers on every device —
    measured 6.9 TiB/device on arctic-480b before this layout."""
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if last_dim % cand == 0:
            return cand
    return 1


def _quantize(x: jax.Array) -> QTensor:
    if x.ndim == 0:
        x = x.reshape(1)
    last = x.shape[-1]
    qb = _qblock_for(last)
    g = x.reshape(*x.shape[:-1], last // qb, qb)
    scale = jnp.max(jnp.abs(g), axis=-1) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(g / safe[..., None]), -127, 127).astype(jnp.int8)
    return QTensor(q=q.reshape(x.shape), scale=scale)


def _dequantize(qt: QTensor, shape) -> jax.Array:
    last = shape[-1] if shape else 1
    qb = last // qt.scale.shape[-1]
    g = qt.q.reshape(*shape[:-1], last // qb, qb).astype(jnp.float32)
    out = g * qt.scale[..., None]
    return out.reshape(shape)


def adamw8bit(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params) -> OptState:
        qz = lambda p: _quantize(jnp.zeros(p.shape, jnp.float32))
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(qz, params),
            nu=jax.tree_util.tree_map(qz, params),
        )

    def update(grads, state: OptState, params) -> Tuple[Any, OptState]:
        grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, mq, vq):
            gf = g.astype(jnp.float32)
            m = _dequantize(mq, p.shape)
            # v is stored on a sqrt scale: int8-linear quantization of the
            # raw second moment distorts small v badly (1/sqrt(v) amplifies);
            # sqrt-compressed storage halves the dynamic range.
            v = jnp.square(_dequantize(vq, p.shape))
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * jnp.square(gf)
            mh = m2 / c1
            vh = v2 / c2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                _quantize(m2),
                _quantize(jnp.sqrt(v2)),
            )

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        is_qt = lambda x: isinstance(x, QTensor)
        flat_m = jax.tree_util.tree_leaves(state.mu, is_leaf=is_qt)
        flat_v = jax.tree_util.tree_leaves(state.nu, is_leaf=is_qt)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, mu=new_m, nu=new_v)

    return init, update


def make_optimizer(cfg, lr):
    """Optimizer factory keyed by ``cfg.optimizer``."""
    if cfg.optimizer == "adamw8bit":
        return adamw8bit(lr)
    return adamw(lr)
