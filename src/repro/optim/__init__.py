"""repro.optim — optimizers, schedules, gradient compression."""

from repro.optim.adamw import OptState, adamw, adamw8bit, clip_by_global_norm, make_optimizer
from repro.optim.compression import compress_decompress, compressed_psum, init_error_buffer
from repro.optim.schedules import constant, warmup_cosine

__all__ = [
    "OptState",
    "adamw",
    "adamw8bit",
    "clip_by_global_norm",
    "make_optimizer",
    "compress_decompress",
    "compressed_psum",
    "init_error_buffer",
    "constant",
    "warmup_cosine",
]
