"""Trip-count-aware HLO module analysis.

``compiled.cost_analysis()`` counts every ``while`` (lax.scan) body ONCE —
useless for 80-layer scanned stacks.  This parser segments the post-SPMD HLO
text into computations, extracts per-computation

  * dot FLOPs           (2 · prod(result dims) · prod(contracting dims))
  * HBM traffic proxy   (operand + result bytes of every scheduled op;
                         fusions are the reuse unit)
  * collective bytes    (result sizes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute)

then rolls them up through the call graph (fusion ``calls=``, reduce
``to_apply=``, while ``body=/condition=``) multiplying loop bodies by their
static trip counts (the ``constant(N)`` in the cond computation).

All shapes in the partitioned module are per-device, so totals are
per-device quantities — exactly what the roofline terms need.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_module", "ModuleCosts"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# op line: `%name = TYPE opcode(%a, %b, ...), attrs`
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<operands>[^)]*)\)(?P<attrs>.*)$"
)
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\((?P<params>.*)\)\s*->.*\{"
)
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|[\w\[\],{}]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_operands(operands: str) -> List[str]:
    """Split an HLO operand list on top-level commas only.

    Modern HLO prints operands with inline types — e.g.
    ``dot(f32[64,32]{1,0} %a, f32[32,16]{1,0} %b)`` — so commas inside
    ``[...]`` / ``{...}`` / ``(...)`` are part of a shape, not separators.
    """
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in operands:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


def _operand_type(operand: str, shapes: Dict[str, str]) -> str:
    """Resolve one operand's type string: inline if present, else by name."""
    if _ARRAY_RE.search(operand):
        return operand
    name = operand.split()[-1].lstrip("%") if operand else ""
    return shapes.get(name, "")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    total_b = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
    return 0, total_b


def _dims_of(type_str: str) -> Optional[List[int]]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Comp:
    name: str
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    calls: List[str] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_constant: int = 0


@dataclasses.dataclass
class ModuleCosts:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, float]
    num_whiles: int


_SKIP_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _parse_computations(text: str) -> Dict[str, Comp]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    shapes: Dict[str, str] = {}
    for raw in text.splitlines():
        hdr = _COMP_HDR_RE.match(raw)
        if hdr and "{" in raw:
            cur = Comp(name=hdr.group("name"))
            comps[cur.name] = cur
            shapes = {}
            for pname, ptype in _PARAM_RE.findall(hdr.group("params")):
                shapes[pname] = ptype
            continue
        if cur is None:
            continue
        if raw.startswith("}"):
            cur = None
            continue
        m = _OPLINE_RE.match(raw)
        if not m:
            c = _CONST_RE.search(raw)
            if c:
                cur.max_constant = max(cur.max_constant, int(c.group(1)))
            continue
        name, type_str = m.group("name"), m.group("type")
        opcode, attrs = m.group("opcode"), m.group("attrs")
        shapes[name] = type_str
        c = _CONST_RE.search(raw)
        if c:
            cur.max_constant = max(cur.max_constant, int(c.group(1)))

        for cal in _CALL_RE.findall(attrs):
            cur.calls.append(cal)
        w = _WHILE_RE.search(attrs)
        if opcode == "while" and w:
            cur.whiles.append((w.group(1), w.group(2)))

        _, out_bytes = _shape_elems_bytes(type_str)
        base = opcode.replace("-start", "")
        if base in _COLLECTIVES:
            cur.collective_bytes += out_bytes
            cur.collective_counts[base] = cur.collective_counts.get(base, 0) + 1

        if opcode == "dot":
            res_dims = _dims_of(type_str) or []
            ops_list = _split_operands(m.group("operands"))
            lhs_dims = (
                _dims_of(_operand_type(ops_list[0], shapes)) if ops_list else None
            ) or []
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
            k = 1
            if cdims and lhs_dims:
                for idx in cdims.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
            n_out = 1
            for d in res_dims:
                n_out *= d
            cur.dot_flops += 2.0 * n_out * k

        if opcode not in _SKIP_TRAFFIC_OPS and not opcode.endswith("-done"):
            tb = out_bytes
            for operand in _split_operands(m.group("operands")):
                _, ob = _shape_elems_bytes(_operand_type(operand, shapes))
                tb += ob
            cur.traffic_bytes += tb
    return comps


def analyze_module(text: str) -> ModuleCosts:
    comps = _parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group("name")
            break
    if entry is None or entry not in comps:
        # fall back: treat every computation with multiplier 1
        totals = ModuleCosts(0.0, 0.0, 0.0, {}, 0)
        for c in comps.values():
            totals = ModuleCosts(
                totals.dot_flops + c.dot_flops,
                totals.traffic_bytes + c.traffic_bytes,
                totals.collective_bytes + c.collective_bytes,
                totals.collective_counts,
                totals.num_whiles,
            )
        return totals

    flops = 0.0
    traffic = 0.0
    coll = 0.0
    counts: Dict[str, float] = {}
    num_whiles = 0
    seen_stack: List[str] = []

    def visit(name: str, mult: float) -> None:
        nonlocal flops, traffic, coll, num_whiles
        c = comps.get(name)
        if c is None or name in seen_stack:
            return
        seen_stack.append(name)
        flops += c.dot_flops * mult
        traffic += c.traffic_bytes * mult
        coll += c.collective_bytes * mult
        for k, v in c.collective_counts.items():
            counts[k] = counts.get(k, 0) + v * mult
        for cal in c.calls:
            visit(cal, mult)
        for cond, body in c.whiles:
            num_whiles += 1
            trip = max(comps.get(cond, Comp(cond)).max_constant, 1)
            visit(body, mult * trip)
            visit(cond, mult)  # cond cost ~ trip times, negligible: once
        seen_stack.pop()

    visit(entry, 1.0)
    counts["total"] = sum(v for k, v in counts.items())
    return ModuleCosts(
        dot_flops=flops,
        traffic_bytes=traffic,
        collective_bytes=coll,
        collective_counts=counts,
        num_whiles=num_whiles,
    )
