"""repro.roofline — roofline-term derivation from compiled artifacts."""

from repro.roofline.analysis import (
    HW,
    Roofline,
    TPU_V5E_HW,
    parse_collectives,
    roofline_terms,
)

__all__ = ["HW", "Roofline", "TPU_V5E_HW", "parse_collectives", "roofline_terms"]
