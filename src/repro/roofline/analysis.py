"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the post-SPMD HLO text and sum the result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (a ring all-X moves ≈ result bytes per participating
device, so result bytes is the per-device wire estimate — documented
approximation).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

__all__ = ["HW", "TPU_V5E_HW", "parse_collectives", "roofline_terms", "Roofline"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `  %x = bf16[8,128]{1,0} all-gather(...)` or tuple types.
_OP_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[\w\[\],{}\s]*?)\s*"
    r"(?P<op>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)
_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result bytes per collective kind from (post-SPMD) HLO text."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES
    }
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op").replace("-start", "")
        b = _type_bytes(m.group("type"))
        out[op]["count"] += 1
        out[op]["bytes"] += b
    out["total"] = {
        "count": sum(v["count"] for k, v in out.items() if k in _COLLECTIVES),
        "bytes": sum(v["bytes"] for k, v in out.items() if k in _COLLECTIVES),
    }
    return out


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float      # per chip
    hbm_bw: float          # per chip
    link_bw: float         # per link


TPU_V5E_HW = HW("tpu-v5e", 197.0e12, 819.0e9, 50.0e9)


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self, model_flops: float) -> float:
        """Useful-FLOPs time at peak / modeled step time (≤1)."""
        ideal = model_flops / (self.chips * TPU_V5E_HW.peak_flops)
        return ideal / self.bound_s if self.bound_s > 0 else 0.0


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    collective_bytes: float,
    chips: int,
    hw: HW = TPU_V5E_HW,
) -> Roofline:
    """All inputs are *global* (whole-mesh) quantities; cost_analysis of an
    SPMD module reports per-partition numbers × we pass them through as the
    per-chip workload (see dryrun.py for which convention each field uses).
    """
    return Roofline(
        compute_s=flops / (chips * hw.peak_flops),
        memory_s=bytes_accessed / (chips * hw.hbm_bw),
        collective_s=collective_bytes / (chips * hw.link_bw),
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=collective_bytes,
        chips=chips,
    )
