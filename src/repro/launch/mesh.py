"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state — the dry-run must set XLA_FLAGS before any
device initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the scale-out 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model) mesh (model=1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
