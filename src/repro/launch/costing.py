"""Costed serving-step helpers shared by the batch and streaming paths.

``launch/serve.py`` (lock-step batch drain) and ``launch/streaming.py``
(continuous batching) place the same two units of work on the cluster — a
prefill over prompt tokens and decode over generated tokens — and both
collapse the model stack to one GEMM-shaped :class:`~repro.core.cost_model
.OpCost` the scheduler can weigh: every token runs the stack's GEMMs, so
``tokens x d_model x d_model`` batched over ``num_layers`` is the workload
shape.  That shape math used to live twice (``_prefill_cost`` /
``_decode_cost`` in serve.py); this module is its single home.

The streaming engine additionally needs *per-step* decode costs (one token
per active slot per step, weights re-streamed from device memory every
step) and byte estimates for KV handles — all derived from the same config
fields, never from live arrays, so the whole streaming simulation runs
without building a model.
"""

from __future__ import annotations

import dataclasses

from repro.core import cost_model as cm

__all__ = [
    "ITEMSIZE",
    "decode_cost",
    "decode_step_cost",
    "kv_bytes_per_token",
    "prefill_cost",
    "stack_gemm_cost",
    "weight_bytes",
    "weight_resident_fraction",
]

# Serving activations/weights are modeled bf16 — matches the original
# serve.py shape math (gemm_cost itemsize=2), so refactored call sites
# produce bit-identical breakdowns.
ITEMSIZE = 2


def stack_gemm_cost(tokens: int, cfg, *, op: str) -> cm.OpCost:
    """The serving workload unit: ``tokens`` through the stack's GEMMs.

    One ``tokens x d_model x d_model`` GEMM batched over ``num_layers`` —
    the collapse both serve paths score placement with.  ``staged_bytes``
    includes the per-layer weight panels (a cold lane pays them; resident
    weights are credited via ``resident_fraction`` at issue time)."""
    d = cfg.d_model
    return cm.gemm_cost(
        max(int(tokens), 1), d, d, ITEMSIZE,
        batch=max(cfg.num_layers, 1), op=op,
    )


def prefill_cost(prompt_tokens: int, cfg, *, op: str = "serve_prefill") -> cm.OpCost:
    """Modeled prefill workload: every prompt token runs the stack's GEMMs."""
    return stack_gemm_cost(prompt_tokens, cfg, op=op)


def decode_cost(
    tokens: int, cache_bytes: float, cfg, *, op: str = "serve_decode"
) -> cm.OpCost:
    """Modeled lock-step decode workload — *including the KV cache in staged
    bytes*.

    Decode streams the whole cache every step, so a device already holding
    it (pinned handle) skips that share of the copy region.  This is the
    asymmetry the ``cost-aware`` scheduler keys on to route decode batches
    to the cache-holding device."""
    base = stack_gemm_cost(tokens, cfg, op=op)
    return dataclasses.replace(
        base,
        staged_bytes=base.staged_bytes + cache_bytes,
        touched_bytes=base.touched_bytes + cache_bytes,
    )


def decode_step_cost(
    batch: int, cfg, *, cache_bytes: float = 0.0, op: str = "serve_decode_step"
) -> cm.OpCost:
    """One continuous-batching decode step: ``batch`` live tokens through
    the stack.

    Weights and every active request's KV cache are device-resident on the
    decode lane (the slot-refill path migrated the handle there), so they
    ride ``touched_bytes`` — the per-step weight re-stream is what makes a
    step memory-bound and batch width nearly free — while only the step's
    token activations (in) and logits row (out) cross the host link as
    ``staged_bytes``."""
    base = stack_gemm_cost(batch, cfg, op=op)
    act_bytes = 2.0 * max(int(batch), 1) * cfg.d_model * ITEMSIZE
    return dataclasses.replace(
        base,
        staged_bytes=act_bytes,
        touched_bytes=base.touched_bytes + float(cache_bytes),
    )


def kv_bytes_per_token(cfg) -> float:
    """Modeled KV/state bytes one cached token occupies (K + V per layer)."""
    return 2.0 * max(cfg.num_layers, 1) * cfg.d_model * ITEMSIZE


def weight_bytes(cfg) -> float:
    """Bytes of the modeled stack weights (one d x d panel per layer)."""
    return float(max(cfg.num_layers, 1)) * cfg.d_model * cfg.d_model * ITEMSIZE


def weight_resident_fraction(cost: cm.OpCost, cfg) -> float:
    """Share of ``cost.staged_bytes`` that is resident stack weights.

    The streaming engine pins the weights on every lane at server start, so
    a prefill/decode launch only stages its activations; this is the exact
    per-call residency credit threaded through ``assign_at``."""
    if cost.staged_bytes <= 0:
        return 0.0
    return min(weight_bytes(cfg) / cost.staged_bytes, 1.0)
