"""Pipeline parallelism (GPipe schedule) over a mesh axis.

The assigned shapes never *need* PP at 256–512 chips (DESIGN.md §4), but a
1000+-node deployment of the deeper archs would pipeline across pods; this
module provides the schedule as a first-class, tested feature.

Design: stages live on the ``model`` (or any) mesh axis; stage parameters
are stacked on a leading (S, …) axis sharded ``P(axis, …)``.  Under a
``shard_map``, a ``lax.scan`` runs the classic GPipe wavefront — at tick
``t`` stage ``k`` processes microbatch ``t−k`` — with activations handed to
the next stage by ``lax.ppermute``.  Backward is pure autodiff: the
transpose of ``ppermute`` is the reverse permute, so the gradient wavefront
flows backward through the pipeline automatically (no hand-written bwd
schedule).

Bubble fraction = (S−1)/(M+S−1) — pick microbatches M ≫ S.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(
    stage_params,
    x: jax.Array,
    stage_fn: Callable,
    mesh,
    *,
    axis: str = "model",
    num_microbatches: int | None = None,
):
    """Run ``stage_fn`` S times as a pipeline over mesh axis ``axis``.

    stage_params: pytree with leading stage dim (S, …) on every leaf.
    x: (B, …) global batch (replicated across the pipeline axis).
    stage_fn(params_slice, x_mb) -> y_mb with y_mb.shape == x_mb.shape.
    Returns (B, …) outputs equivalent to sequentially applying all stages.
    """
    s = mesh.shape[axis]
    b = x.shape[0]
    m = num_microbatches or s
    if b % m:
        raise ValueError(f"batch {b} not divisible by {m} microbatches")
    mb = b // m
    xmb = x.reshape(m, mb, *x.shape[1:])

    def local(params_loc, xmb_):
        idx = jax.lax.axis_index(axis)
        p_slice = jax.tree_util.tree_map(lambda a: a[0], params_loc)
        zero = jnp.zeros_like(xmb_[0])

        def tick(buf, t):
            # stage 0 ingests microbatch t (clamped; masked at the end),
            # stages k>0 consume the activation handed over last tick.
            x_in = jnp.where(idx == 0, xmb_[jnp.clip(t, 0, m - 1)], buf)
            y = stage_fn(p_slice, x_in)
            y_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(s - 1)]
            )
            return y_next, y

        _, ys = jax.lax.scan(tick, zero, jnp.arange(m + s - 1))
        # microbatch j completes on the LAST stage at tick j + s - 1
        outs = ys[s - 1 :]                                # (M, mb, …)
        outs = jnp.where(idx == s - 1, outs, 0.0)
        return jax.lax.psum(outs, axis)                   # broadcast result

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            jax.tree_util.tree_map(lambda _: P(axis), stage_params),
            P(*((None,) * xmb.ndim)),
        ),
        out_specs=P(*((None,) * xmb.ndim)),
        check_vma=False,
    )
    out = fn(stage_params, xmb)
    return out.reshape(b, *x.shape[1:])
