"""Batched serving driver: prefill + decode with a KV/state cache.

A deliberately small but real serving loop: requests arrive with prompts,
are padded into a batch, prefilled (full forward building the cache via
teacher-forced decode), then decoded token-by-token with greedy/temperature
sampling.  The same ``serve_step`` is what the decode dry-run cells lower.

``serve_cluster`` scales the loop to the multi-PMCA engine with placement
as a first-class concept: each batch's prefill is placed by the active
scheduler, and the KV cache it builds is **pinned** there as a
:class:`~repro.core.hero.DeviceHandle` (a device-residency token).  Decode
placement then goes through ``cluster.assign(..., handle=...)`` — the
``cost-aware`` scheduler sees the residency credit and routes the decode
batch to the device holding its cache (skipping the modeled copy region);
placement-oblivious schedulers (``round-robin``) do not, and pay a modeled
``d2d_copy`` migration when decode lands elsewhere.  The un-pinned baseline
(``pin_caches=False``) models today's common deployment: the cache drains
to host DRAM after prefill and decode pays a full host re-stage.  Cluster
throughput is the modeled-parallel makespan — the max device lane, not the
sum.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import cost_model as cm
from repro.core.hero import DeviceHandle, engine
from repro.launch import costing
from repro.launch.steps import make_serve_step
from repro.models import build_model


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray          # (B, max_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


def _run_prefill(serve_step, params, cache, prompts: List[List[int]]):
    """Prefill token-by-token through the decode path (correct for rolling
    caches and hybrid state; a fused prefill kernel is a perf option)."""
    bsz = len(prompts)
    max_prompt = max(len(p) for p in prompts)
    t0 = time.time()
    tok = np.zeros((bsz, 1), np.int32)
    logits = None
    for t in range(max_prompt):
        for b, p in enumerate(prompts):
            tok[b, 0] = p[t] if t < len(p) else 0
        logits, cache = serve_step(
            params, cache, jnp.asarray(tok), jnp.int32(t)
        )
    return logits, cache, time.time() - t0


def _run_decode(
    serve_step, params, cache, logits, *, start_pos: int,
    max_new_tokens: int, temperature: float, seed: int,
):
    """Greedy/temperature sampling loop from a prefilled cache."""
    bsz = logits.shape[0]
    rng = np.random.default_rng(seed)
    out = np.zeros((bsz, max_new_tokens), np.int32)
    t0 = time.time()
    for i in range(max_new_tokens):
        lf = np.asarray(logits, np.float32)
        if temperature > 0:
            p = np.exp((lf - lf.max(-1, keepdims=True)) / temperature)
            p /= p.sum(-1, keepdims=True)
            nxt = np.array(
                [rng.choice(lf.shape[-1], p=p[b]) for b in range(bsz)], np.int32
            )
        else:
            nxt = lf.argmax(-1).astype(np.int32)
        out[:, i] = nxt
        logits, cache = serve_step(
            params, cache, jnp.asarray(nxt[:, None]), jnp.int32(start_pos + i)
        )
    return out, cache, time.time() - t0


def serve_batch(
    arch: str,
    prompts: List[List[int]],
    *,
    smoke: bool = True,
    max_new_tokens: int = 16,
    cache_len: int = 128,
    temperature: float = 0.0,
    seed: int = 0,
    params=None,
) -> ServeResult:
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    if not cfg.embed_inputs:
        raise ValueError("serving driver targets token-input archs")
    if cfg.is_encoder:
        raise ValueError("encoder-only arch has no decode step")
    model = build_model(cfg)
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))

    bsz = len(prompts)
    max_prompt = max(len(p) for p in prompts)
    cache = model.init_decode_cache(bsz, cache_len)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    logits, cache, prefill_s = _run_prefill(serve_step, params, cache, prompts)
    out, cache, decode_s = _run_decode(
        serve_step, params, cache, logits, start_pos=max_prompt,
        max_new_tokens=max_new_tokens, temperature=temperature, seed=seed,
    )
    return ServeResult(
        tokens=out,
        prefill_s=prefill_s,
        decode_s=decode_s,
        tokens_per_s=bsz * max_new_tokens / max(decode_s, 1e-9),
    )


@dataclasses.dataclass
class ClusterServeResult:
    """One multi-device serving round."""

    results: List[ServeResult]            # one per request batch
    placements: List[int]                 # batch index -> decode device id
    prefill_placements: List[int]         # batch index -> prefill device id
    # Device holding each cache when its decode batch was *placed*
    # (-1 = unstaged to host); differs from `placements` exactly when the
    # scheduler strayed from the cache and a move was paid.
    cache_devices: List[int]
    per_device_s: Dict[int, float]        # modeled busy seconds per device
    makespan_s: float                     # modeled wall-clock (max lane)
    total_tokens: int
    tokens_per_s: float                   # modeled cluster throughput
    d2d_s: float = 0.0                    # modeled cache-migration seconds
    restage_s: float = 0.0                # modeled host re-stage seconds


def _cache_nbytes(cache) -> float:
    """Total bytes of the KV/state cache pytree (the pinned buffer size)."""
    return float(sum(
        leaf.size * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(cache)
        if hasattr(leaf, "size") and hasattr(leaf, "dtype")
    ))


def _prefill_cost(prompts: List[List[int]], cfg) -> cm.OpCost:
    """Batch-path adapter over the shared costed-step helper
    (:mod:`repro.launch.costing`): every prompt token runs the stack's
    GEMMs, collapsed to one cost the scheduler can weigh."""
    return costing.prefill_cost(sum(len(p) for p in prompts), cfg)


def _decode_cost(
    bsz: int, max_new_tokens: int, cache_bytes: float, cfg
) -> cm.OpCost:
    """Batch-path adapter over :func:`repro.launch.costing.decode_cost` —
    the whole decode phase's tokens with the KV cache riding staged bytes,
    the asymmetry the ``cost-aware`` scheduler keys on to route decode
    batches to the cache-holding device."""
    return costing.decode_cost(bsz * max_new_tokens, cache_bytes, cfg)


def serve_cluster(
    arch: str,
    request_batches: List[List[List[int]]],
    *,
    smoke: bool = True,
    max_new_tokens: int = 16,
    cache_len: int = 128,
    temperature: float = 0.0,
    seed: int = 0,
    pin_caches: bool = True,
    forward_mode: Optional[str] = None,
) -> ClusterServeResult:
    """Serve concurrent request batches across the HeroCluster's devices.

    Two placement rounds per batch, both through the active scheduler:

    1. **Prefill** is placed by workload (prompt tokens x stack GEMMs) and
       executed with the cluster pinned to its lane; the KV cache it builds
       is pinned there as a :class:`DeviceHandle` (``pin_caches=True``) or
       drained back to host DRAM (``pin_caches=False``).
    2. **Decode** is placed with ``assign(..., handle=...)``: a
       placement-affine scheduler routes it to the cache holder (no cache
       movement); landing elsewhere costs a modeled ``d2d_copy`` migration,
       and an unstaged cache costs a full host re-stage — both recorded on
       the decode lane's trace.

    All request batches are modeled as in flight concurrently — every KV
    cache stays live from its prefill to its decode, as on a real server
    holding resident caches per device (chunk ``request_batches`` if host
    memory can't hold them all at once at full model scale).

    Devices run batches sequentially within a lane; lanes run in parallel
    — the modeled makespan is the longest lane.  Lane seconds are model
    units throughout (batch-level cost-model breakdowns plus explicit cache
    moves, never wall clock): the jit cache means fine-grained launches
    only trace once per shape, so per-batch execution traces are not a
    coherent lane measure — the batch cost the scheduler placed is.
    """
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    if forward_mode is not None:
        # "graph": the decode steps run the graph-captured forward — each
        # block's dense FFN is lowered as an hnp expression graph (residual
        # fused into the FFN launch, per-launch residency threaded), through
        # the exact same registered descriptors as the eager path.
        cfg = dataclasses.replace(cfg, forward_mode=forward_mode)
    cluster = engine()
    # one set of weights serves every batch (and one jit cache warms up)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    per_device_s: Dict[int, float] = {}
    prefill_placements: List[int] = []
    handles: List[DeviceHandle] = []
    sessions = []  # (logits, cache, prefill_s, max_prompt)

    results: List[ServeResult] = []
    placements: List[int] = []
    cache_devices: List[int] = []
    total_tokens = 0
    d2d_s = 0.0
    restage_s = 0.0
    try:
        # ---- round 1: prefill placement + execution, caches pinned ------
        for i, prompts in enumerate(request_batches):
            cache = model.init_decode_cache(len(prompts), cache_len)
            p_dev, p_bd = cluster.assign(
                _prefill_cost(prompts, cfg), shape_key=f"serve-prefill-{i}"
            )
            prefill_placements.append(p_dev)
            with cluster.pin_device(p_dev):
                logits, cache, prefill_s = _run_prefill(
                    serve_step, params, cache, prompts
                )
            per_device_s[p_dev] = per_device_s.get(p_dev, 0.0) + p_bd.offload_s
            handle = cluster.pin_handle(
                f"kv-cache-{i}", _cache_nbytes(cache), device_id=p_dev
            )
            if not pin_caches:
                # baseline: the cache drains to host DRAM between phases
                cluster.unstage_handle(handle)
            handles.append(handle)
            sessions.append(
                (logits, cache, prefill_s, max(len(p) for p in prompts))
            )

        cluster.sync()  # prefill barrier: decode starts after prefills retire

        # ---- round 2: handle-affine decode placement + execution --------
        for i, prompts in enumerate(request_batches):
            logits, cache, prefill_s, max_prompt = sessions[i]
            handle = handles[i]
            d_cost = _decode_cost(
                len(prompts), max_new_tokens, handle.nbytes, cfg
            )
            d_dev, _ = cluster.assign(
                d_cost,
                shape_key=f"serve-decode-{i}",
                handle=handle if pin_caches else None,
            )
            placements.append(d_dev)
            cache_devices.append(handle.device_id if handle.valid else -1)
            # Bring the cache to the decode lane first, paying the move
            # visibly (recorded on the active trace, charged to the lane):
            move_s = 0.0
            if not handle.valid:
                # unstaged cache: full host->device copy on this lane
                move_s = cluster.restage_handle(
                    handle, device_id=d_dev
                ).offload_s
                restage_s += move_s
            elif handle.device_id != d_dev:
                # pinned elsewhere: migrate over the d2d link
                move_s = cluster.migrate_handle(handle, d_dev).offload_s
                d2d_s += move_s
            with cluster.pin_device(d_dev):
                out, cache, decode_s = _run_decode(
                    serve_step, params, cache, logits, start_pos=max_prompt,
                    max_new_tokens=max_new_tokens, temperature=temperature,
                    seed=seed,
                )
            # Not assign()'s breakdown: that one was scored before the move,
            # so a strayed/unstaged cache still counted in its copy region.
            # Now the cache is resident on the lane — the decode breakdown
            # takes the credit and the movement cost was added explicitly.
            lane_s = move_s + cluster.device(d_dev).breakdown_for(
                d_cost, cluster.policy, handle.name
            ).offload_s
            per_device_s[d_dev] = per_device_s.get(d_dev, 0.0) + lane_s
            results.append(ServeResult(
                tokens=out,
                prefill_s=prefill_s,
                decode_s=decode_s,
                tokens_per_s=(
                    len(prompts) * max_new_tokens / max(decode_s, 1e-9)
                ),
            ))
            total_tokens += len(prompts) * max_new_tokens

        cluster.sync()  # retire the batch tickets (modeled barrier)
    finally:
        # never leak handles into the singleton engine, even on failure
        for h in handles:
            cluster.release_handle(h)
    makespan_s = max(per_device_s.values(), default=0.0)
    return ClusterServeResult(
        results=results,
        placements=placements,
        prefill_placements=prefill_placements,
        cache_devices=cache_devices,
        per_device_s=per_device_s,
        makespan_s=makespan_s,
        total_tokens=total_tokens,
        tokens_per_s=total_tokens / max(makespan_s, 1e-9),
        d2d_s=d2d_s,
        restage_s=restage_s,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--scheduler", default="least-loaded",
                    choices=["round-robin", "least-loaded", "cost-aware"])
    ap.add_argument("--policy-mode", default="device",
                    choices=["host", "device", "auto"],
                    help="offload routing policy for the cluster run")
    ap.add_argument("--forward-mode", default=None,
                    choices=["eager", "graph"],
                    help="decode step forward path (graph = hnp capture)")
    ap.add_argument("--num-batches", type=int, default=1)
    ap.add_argument("--no-pin-caches", action="store_true",
                    help="baseline: caches drain to host between phases")
    # Streaming mode: the continuous-batching engine over a live arrival
    # process (fully modeled — no model build, so it runs anywhere fast).
    ap.add_argument("--stream", action="store_true",
                    help="run the streaming engine on a bursty trace")
    ap.add_argument("--qps", type=float, default=100.0,
                    help="offered load for --stream (requests/s)")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="trace duration for --stream (modeled seconds)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.stream:
        from repro.launch.streaming import (
            StreamConfig, bursty_trace, serve_stream,
        )

        # 1 prefill lane + >=1 decode lanes; default to the bench's
        # 4-device split unless the user asked for a bigger cluster.
        cfg = StreamConfig(
            num_devices=max(args.devices, 4), scheduler=args.scheduler
        )
        trace = bursty_trace(args.qps, args.duration, seed=args.seed)
        rep = serve_stream(args.arch, trace, config=cfg)
        o = rep.slo.overall
        print(f"streaming {args.arch}: offered {rep.offered_qps:.4g} qps "
              f"-> sustained {rep.sustained_qps:.4g} qps "
              f"(reject {rep.reject_rate:.1%}, "
              f"ttft p99 {o.ttft.p99_s * 1e3:.1f}ms, "
              f"per-token p99 {o.per_token.p99_s * 1e3:.2f}ms, "
              f"meets SLO: {rep.slo.meets_slo})")
        return
    rng = np.random.default_rng(args.seed)
    if args.devices > 1 or args.num_batches > 1:
        from repro.core.hero import offload_policy

        batches = [
            [list(rng.integers(1, 200, size=args.prompt_len))
             for _ in range(args.batch)]
            for _ in range(args.num_batches)
        ]
        with offload_policy(mode=args.policy_mode, num_devices=args.devices,
                            scheduler=args.scheduler):
            res = serve_cluster(
                args.arch, batches, max_new_tokens=args.max_new,
                temperature=args.temperature,
                pin_caches=not args.no_pin_caches,
                forward_mode=args.forward_mode,
            )
        print(f"{len(batches)} batches over {args.devices} devices "
              f"({args.scheduler}): prefill={res.prefill_placements} "
              f"decode={res.placements} "
              f"makespan={res.makespan_s:.6g}s "
              f"d2d={res.d2d_s:.3g}s restage={res.restage_s:.3g}s "
              f"{res.tokens_per_s:.4g} tok/s (modeled)")
        return
    prompts = [list(rng.integers(1, 200, size=args.prompt_len)) for _ in range(args.batch)]
    res = serve_batch(
        args.arch, prompts, max_new_tokens=args.max_new,
        temperature=args.temperature,
    )
    print(f"prefill {res.prefill_s:.2f}s decode {res.decode_s:.2f}s "
          f"{res.tokens_per_s:.1f} tok/s")
    print(res.tokens)


if __name__ == "__main__":
    main()
