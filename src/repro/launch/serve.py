"""Batched serving driver: prefill + decode with a KV/state cache.

A deliberately small but real serving loop: requests arrive with prompts,
are padded into a batch, prefilled (full forward building the cache via
teacher-forced decode), then decoded token-by-token with greedy/temperature
sampling.  The same ``serve_step`` is what the decode dry-run cells lower.

``serve_cluster`` scales the loop to the multi-PMCA engine: concurrent
request batches are placed on the :class:`~repro.core.hero.HeroCluster`'s
virtual devices through the active scheduler (tokens-weighted cost), each
batch's offload trace is tagged with its device, and cluster throughput is
the modeled-parallel makespan — the max device lane, not the sum.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import accounting
from repro.core import cost_model as cm
from repro.core.hero import engine
from repro.launch.steps import make_serve_step
from repro.models import build_model


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray          # (B, max_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


def serve_batch(
    arch: str,
    prompts: List[List[int]],
    *,
    smoke: bool = True,
    max_new_tokens: int = 16,
    cache_len: int = 128,
    temperature: float = 0.0,
    seed: int = 0,
    params=None,
) -> ServeResult:
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    if not cfg.embed_inputs:
        raise ValueError("serving driver targets token-input archs")
    if cfg.is_encoder:
        raise ValueError("encoder-only arch has no decode step")
    model = build_model(cfg)
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))

    bsz = len(prompts)
    max_prompt = max(len(p) for p in prompts)
    cache = model.init_decode_cache(bsz, cache_len)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    # Prefill token-by-token through the decode path (correct for rolling
    # caches and hybrid state; a fused prefill kernel is a perf option).
    t0 = time.time()
    tok = np.zeros((bsz, 1), np.int32)
    logits = None
    for t in range(max_prompt):
        for b, p in enumerate(prompts):
            tok[b, 0] = p[t] if t < len(p) else 0
        logits, cache = serve_step(
            params, cache, jnp.asarray(tok), jnp.int32(t)
        )
    prefill_s = time.time() - t0

    rng = np.random.default_rng(seed)
    out = np.zeros((bsz, max_new_tokens), np.int32)
    t0 = time.time()
    for i in range(max_new_tokens):
        lf = np.asarray(logits, np.float32)
        if temperature > 0:
            p = np.exp((lf - lf.max(-1, keepdims=True)) / temperature)
            p /= p.sum(-1, keepdims=True)
            nxt = np.array(
                [rng.choice(lf.shape[-1], p=p[b]) for b in range(bsz)], np.int32
            )
        else:
            nxt = lf.argmax(-1).astype(np.int32)
        out[:, i] = nxt
        logits, cache = serve_step(
            params, cache, jnp.asarray(nxt[:, None]), jnp.int32(max_prompt + i)
        )
    decode_s = time.time() - t0
    return ServeResult(
        tokens=out,
        prefill_s=prefill_s,
        decode_s=decode_s,
        tokens_per_s=bsz * max_new_tokens / max(decode_s, 1e-9),
    )


@dataclasses.dataclass
class ClusterServeResult:
    """One multi-device serving round."""

    results: List[ServeResult]            # one per request batch
    placements: List[int]                 # batch index -> device id
    per_device_s: Dict[int, float]        # modeled busy seconds per device
    makespan_s: float                     # modeled wall-clock (max lane)
    total_tokens: int
    tokens_per_s: float                   # modeled cluster throughput


def _batch_cost(prompts: List[List[int]], max_new_tokens: int, cfg) -> "cm.OpCost":
    """Modeled workload of one serving batch: every decode step runs the
    stack's GEMMs over the batch — collapse to one gemm_cost the scheduler
    can weigh (tokens × d_model² work, tokens × d_model staged)."""
    tokens = sum(len(p) for p in prompts) + len(prompts) * max_new_tokens
    d = cfg.d_model
    return cm.gemm_cost(tokens, d, d, 2, batch=max(cfg.num_layers, 1),
                        op="serve_batch")


def serve_cluster(
    arch: str,
    request_batches: List[List[List[int]]],
    *,
    smoke: bool = True,
    max_new_tokens: int = 16,
    cache_len: int = 128,
    temperature: float = 0.0,
    seed: int = 0,
) -> ClusterServeResult:
    """Serve concurrent request batches across the HeroCluster's devices.

    Each batch is placed by the cluster scheduler (cost-weighted by its
    token count), then executed with the cluster *pinned* to its assigned
    device, so every launch the batch issues is traced against that lane.
    Devices run batches sequentially within a lane; lanes run in parallel
    — the modeled makespan is the longest lane.
    """
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    cluster = engine()
    # one set of weights serves every batch (and one jit cache warms up)
    params = build_model(cfg).init_params(jax.random.PRNGKey(seed))

    placements: List[int] = []
    for i, prompts in enumerate(request_batches):
        cost = _batch_cost(prompts, max_new_tokens, cfg)
        placements.append(cluster.assign(cost, shape_key=f"serve-batch-{i}"))

    results: List[ServeResult] = []
    per_device_s: Dict[int, float] = {}
    total_tokens = 0
    for i, prompts in enumerate(request_batches):
        with cluster.pin_device(placements[i]):
            with accounting.offload_trace() as trace:
                res = serve_batch(
                    arch, prompts, smoke=smoke, max_new_tokens=max_new_tokens,
                    cache_len=cache_len, temperature=temperature, seed=seed,
                    params=params,
                )
        results.append(res)
        total_tokens += len(prompts) * max_new_tokens
        # Modeled lane time, in model units throughout (never wall clock —
        # mixing the two makes lanes incommensurable): device work is the
        # pinned lane's overlap makespan, host-routed calls add their
        # modeled host seconds serially.
        host_s = sum(
            r.regions.host_s * r.count for r in trace.host_only()
        )
        lane_s = trace.cluster_makespan_s() + host_s
        if lane_s <= 0:  # nothing traced at all: degrade to wall time
            lane_s = res.prefill_s + res.decode_s
        dev = placements[i]
        per_device_s[dev] = per_device_s.get(dev, 0.0) + lane_s

    cluster.sync()  # retire the batch tickets (modeled barrier)
    makespan_s = max(per_device_s.values(), default=0.0)
    return ClusterServeResult(
        results=results,
        placements=placements,
        per_device_s=per_device_s,
        makespan_s=makespan_s,
        total_tokens=total_tokens,
        tokens_per_s=total_tokens / max(makespan_s, 1e-9),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--scheduler", default="least-loaded")
    ap.add_argument("--num-batches", type=int, default=1)
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    if args.devices > 1 or args.num_batches > 1:
        from repro.core.hero import offload_policy

        batches = [
            [list(rng.integers(1, 200, size=args.prompt_len))
             for _ in range(args.batch)]
            for _ in range(args.num_batches)
        ]
        with offload_policy(num_devices=args.devices, scheduler=args.scheduler):
            res = serve_cluster(
                args.arch, batches, max_new_tokens=args.max_new,
                temperature=args.temperature,
            )
        print(f"{len(batches)} batches over {args.devices} devices "
              f"({args.scheduler}): placements={res.placements} "
              f"makespan={res.makespan_s:.6g}s "
              f"{res.tokens_per_s:.4g} tok/s (modeled)")
        return
    prompts = [list(rng.integers(1, 200, size=args.prompt_len)) for _ in range(args.batch)]
    res = serve_batch(
        args.arch, prompts, max_new_tokens=args.max_new,
        temperature=args.temperature,
    )
    print(f"prefill {res.prefill_s:.2f}s decode {res.decode_s:.2f}s "
          f"{res.tokens_per_s:.1f} tok/s")
    print(res.tokens)


if __name__ == "__main__":
    main()
