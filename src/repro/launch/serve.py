"""Batched serving driver: prefill + decode with a KV/state cache.

A deliberately small but real serving loop: requests arrive with prompts,
are padded into a batch, prefilled (full forward building the cache via
teacher-forced decode), then decoded token-by-token with greedy/temperature
sampling.  The same ``serve_step`` is what the decode dry-run cells lower.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.steps import make_serve_step
from repro.models import build_model


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray          # (B, max_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


def serve_batch(
    arch: str,
    prompts: List[List[int]],
    *,
    smoke: bool = True,
    max_new_tokens: int = 16,
    cache_len: int = 128,
    temperature: float = 0.0,
    seed: int = 0,
    params=None,
) -> ServeResult:
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    if not cfg.embed_inputs:
        raise ValueError("serving driver targets token-input archs")
    if cfg.is_encoder:
        raise ValueError("encoder-only arch has no decode step")
    model = build_model(cfg)
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))

    bsz = len(prompts)
    max_prompt = max(len(p) for p in prompts)
    cache = model.init_decode_cache(bsz, cache_len)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    # Prefill token-by-token through the decode path (correct for rolling
    # caches and hybrid state; a fused prefill kernel is a perf option).
    t0 = time.time()
    tok = np.zeros((bsz, 1), np.int32)
    logits = None
    for t in range(max_prompt):
        for b, p in enumerate(prompts):
            tok[b, 0] = p[t] if t < len(p) else 0
        logits, cache = serve_step(
            params, cache, jnp.asarray(tok), jnp.int32(t)
        )
    prefill_s = time.time() - t0

    rng = np.random.default_rng(seed)
    out = np.zeros((bsz, max_new_tokens), np.int32)
    t0 = time.time()
    for i in range(max_new_tokens):
        lf = np.asarray(logits, np.float32)
        if temperature > 0:
            p = np.exp((lf - lf.max(-1, keepdims=True)) / temperature)
            p /= p.sum(-1, keepdims=True)
            nxt = np.array(
                [rng.choice(lf.shape[-1], p=p[b]) for b in range(bsz)], np.int32
            )
        else:
            nxt = lf.argmax(-1).astype(np.int32)
        out[:, i] = nxt
        logits, cache = serve_step(
            params, cache, jnp.asarray(nxt[:, None]), jnp.int32(max_prompt + i)
        )
    decode_s = time.time() - t0
    return ServeResult(
        tokens=out,
        prefill_s=prefill_s,
        decode_s=decode_s,
        tokens_per_s=bsz * max_new_tokens / max(decode_s, 1e-9),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, 200, size=args.prompt_len)) for _ in range(args.batch)]
    res = serve_batch(
        args.arch, prompts, max_new_tokens=args.max_new,
        temperature=args.temperature,
    )
    print(f"prefill {res.prefill_s:.2f}s decode {res.decode_s:.2f}s "
          f"{res.tokens_per_s:.1f} tok/s")
    print(res.tokens)


if __name__ == "__main__":
    main()
