"""Arch/cell inspector: params, active params, shape applicability, memory.

Usage:
  PYTHONPATH=src python -m repro.launch.info            # all archs
  PYTHONPATH=src python -m repro.launch.info --arch yi-6b
"""

from __future__ import annotations

import argparse

from repro.configs import ALL_SHAPES, get_arch, list_archs, shape_applicable


def arch_row(name: str) -> str:
    cfg = get_arch(name)
    n = cfg.param_count()
    na = cfg.active_param_count()
    shapes = []
    for s in ALL_SHAPES:
        ok, _ = shape_applicable(cfg, s)
        shapes.append(s.name if ok else f"~~{s.name}~~")
    memo = []
    if cfg.fsdp:
        memo.append("fsdp")
    if cfg.zero1:
        memo.append("zero1")
    if cfg.optimizer != "adamw":
        memo.append(cfg.optimizer)
    return (
        f"| {name} | {cfg.family} | {cfg.num_layers} | {cfg.d_model} "
        f"| {n/1e9:.1f}B | {na/1e9:.2f}B | {' '.join(shapes)} "
        f"| {','.join(memo) or '—'} |"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else [
        a for a in list_archs() if a != "paper-gemm"
    ]
    print("| arch | family | L | d_model | params | active | shapes (~~skip~~) | memory opts |")
    print("|---|---|---|---|---|---|---|---|")
    for a in archs:
        print(arch_row(a))


if __name__ == "__main__":
    main()
