"""Streaming serving engine: continuous batching over the HeroCluster.

``serve_cluster`` drains a *fixed list* of batches and reports one
makespan.  Production serving is the opposite shape: requests arrive on a
stochastic clock, each carries its own prompt/output lengths and deadline,
and the number that matters is the **max offered load the cluster sustains
while the p99 TTFT / per-token tails stay inside SLO**.  This module is
that engine, built entirely on modeled time:

* **Arrival processes** — seeded Poisson, bursty (on/off modulated
  Poisson) and trace-replay generators producing :class:`Request` streams
  with per-class prompt/output-length distributions and deadlines.  Every
  generator takes an explicit seed; nothing in this file reads a wall
  clock (``make lint`` enforces it via the ``serve-no-wallclock`` rule).

* **Continuous batching** — each decode lane owns a slot pool; every step
  decodes one token for every active slot, and slots refill *per step* as
  requests finish, instead of lock-step batch drain.  The per-step issue
  path is :meth:`HeroCluster.assign_at`: the lane's stream clocks advance
  to the step's ready time and the stamped :class:`LaunchTicket` supplies
  the modeled completion event each emitted token is timed with.

* **Prefill/decode disaggregation** — prefill lanes run prompt passes and
  pin the KV cache they build as a :class:`DeviceHandle`; at slot
  assignment the handle migrates to the decode lane over the modeled d2d
  link, exactly the ``serve_cluster`` placement machinery driven per
  request instead of per batch.

* **Admission control with backpressure** — reject/queue decisions read
  modeled in-flight completion times off the prefill lanes' ticket
  streams (``stream_makespan_s`` is the frontier of stamped
  ``complete_s`` events) plus the decode-side backlog; an AIMD slot-target
  controller (xpra's per-source batch-delay heuristic, transplanted)
  shrinks the decode width multiplicatively when step latency blows the
  per-token budget and grows it back additively.

The lock-step baseline (:func:`serve_lockstep`) runs the *same trace* on
the same lanes with ``serve_cluster`` semantics modeled per step — batches
form at full width, pad to the longest output, and never refill mid-drain
— so the continuous-vs-lockstep headline in ``BENCH_offload.json`` is an
apples-to-apples modeled comparison.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs import get_arch
from repro.core import accounting
from repro.core.hero import DeviceHandle, HeroCluster, LaunchTicket
from repro.core.placement import (
    ExpertPlacementPolicy,
    PlacementConfig,
    zipf_histogram,
)
from repro.core.platform import TPU_V5E, Platform
from repro.launch import costing
from repro.obs import metrics as _obs_metrics
from repro.obs import spans as _obs_spans

__all__ = [
    "SLO",
    "ArrivalTrace",
    "Request",
    "SlotRefill",
    "StreamConfig",
    "StreamReport",
    "bursty_trace",
    "estimate_capacity",
    "offered_load_sweep",
    "poisson_trace",
    "replay_trace",
    "scale_trace",
    "serve_lockstep",
    "serve_stream",
]


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request on the modeled arrival clock."""

    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int
    req_class: str = "default"
    # Absolute first-token deadline (admission rejects requests whose
    # estimated TTFT already misses it).  0 = no deadline.
    deadline_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """A seeded, replayable request stream (sorted by arrival time)."""

    requests: Tuple[Request, ...]
    seed: int
    kind: str                   # "poisson" | "bursty" | "replay" | "scaled"
    duration_s: float

    @property
    def offered_qps(self) -> float:
        return len(self.requests) / max(self.duration_s, 1e-9)


# Request classes: (weight, prompt range, output range, TTFT deadline budget).
# "interactive" models chat turns; "batch" models long-document jobs that
# tolerate a slower first token.  Percentile rollups key on the class name.
DEFAULT_CLASSES: Tuple[Tuple[str, float, Tuple[int, int], Tuple[int, int], float], ...] = (
    ("interactive", 0.8, (16, 128), (16, 96), 0.5),
    ("batch", 0.2, (128, 512), (32, 96), 2.0),
)


def _sample_request(
    rng: random.Random, rid: int, arrival_s: float, classes
) -> Request:
    r = rng.random()
    acc = 0.0
    name, _, prange, orange, budget = classes[-1]
    for cname, weight, cp, co, cb in classes:
        acc += weight
        if r <= acc:
            name, prange, orange, budget = cname, cp, co, cb
            break
    return Request(
        rid=rid,
        arrival_s=arrival_s,
        prompt_len=rng.randint(*prange),
        output_len=rng.randint(*orange),
        req_class=name,
        deadline_s=arrival_s + budget if budget > 0 else 0.0,
    )


def poisson_trace(
    qps: float,
    duration_s: float,
    *,
    seed: int,
    classes=DEFAULT_CLASSES,
) -> ArrivalTrace:
    """Memoryless arrivals at rate ``qps`` (seeded; no wall clock)."""
    rng = random.Random(seed)
    t = 0.0
    reqs: List[Request] = []
    while True:
        t += rng.expovariate(max(qps, 1e-9))
        if t >= duration_s:
            break
        reqs.append(_sample_request(rng, len(reqs), t, classes))
    return ArrivalTrace(tuple(reqs), seed, "poisson", duration_s)


def bursty_trace(
    qps: float,
    duration_s: float,
    *,
    seed: int,
    burst_factor: float = 3.0,
    burst_fraction: float = 0.3,
    period_s: float = 0.25,
    classes=DEFAULT_CLASSES,
) -> ArrivalTrace:
    """On/off modulated Poisson: bursts at ``burst_factor`` x the base rate.

    ``burst_fraction`` of each ``period_s`` window runs hot; the quiet
    remainder is rate-scaled so the *average* offered load is ``qps`` —
    bursty and plain traces at the same ``qps`` are comparable.  Sampled
    by Lewis-Shedler thinning (candidates at the hot rate, accepted with
    probability ``rate(t) / hot``) so the modulation is exact even when
    the quiet rate's mean step would jump clean over a burst window."""
    rng = random.Random(seed)
    hot = max(qps * burst_factor, 1e-9)
    denom = 1.0 - burst_fraction * burst_factor
    cold = qps * max(denom, 0.0) / max(1.0 - burst_fraction, 1e-9)
    t = 0.0
    reqs: List[Request] = []
    while True:
        t += rng.expovariate(hot)
        if t >= duration_s:
            break
        phase = (t % period_s) / period_s
        rate = hot if phase < burst_fraction else cold
        if rng.random() * hot <= rate:
            reqs.append(_sample_request(rng, len(reqs), t, classes))
    return ArrivalTrace(tuple(reqs), seed, "bursty", duration_s)


def replay_trace(
    arrivals: Iterable[Tuple[float, int, int]],
    *,
    seed: int = 0,
    req_class: str = "replay",
    deadline_budget_s: float = 0.0,
) -> ArrivalTrace:
    """Replay explicit ``(arrival_s, prompt_len, output_len)`` rows."""
    reqs = tuple(
        Request(
            rid=i, arrival_s=float(t), prompt_len=int(p), output_len=int(o),
            req_class=req_class,
            deadline_s=float(t) + deadline_budget_s if deadline_budget_s > 0 else 0.0,
        )
        for i, (t, p, o) in enumerate(sorted(arrivals))
    )
    dur = reqs[-1].arrival_s if reqs else 0.0
    return ArrivalTrace(reqs, seed, "replay", dur)


def scale_trace(trace: ArrivalTrace, factor: float) -> ArrivalTrace:
    """Rescale offered load by compressing arrival times (``factor`` > 1 =
    more load).  The request *population* — lengths, classes, order — is
    untouched, so a load sweep built from one base trace compares identical
    work at every point; deadlines keep their relative budget."""
    f = 1.0 / max(float(factor), 1e-9)
    reqs = tuple(
        dataclasses.replace(
            r,
            arrival_s=r.arrival_s * f,
            deadline_s=(
                r.arrival_s * f + (r.deadline_s - r.arrival_s)
                if r.deadline_s > 0 else 0.0
            ),
        )
        for r in trace.requests
    )
    return ArrivalTrace(reqs, trace.seed, "scaled", trace.duration_s * f)


# ---------------------------------------------------------------------------
# Engine configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLO:
    """The serving contract the sweep searches against (p99 budgets)."""

    ttft_s: float = 0.25
    per_token_s: float = 0.008


@dataclasses.dataclass
class StreamConfig:
    """Knobs for one streaming run (all placement is modeled)."""

    num_devices: int = 4
    prefill_lanes: int = 1          # devices [0, prefill_lanes) run prefill
    decode_slots: int = 8           # slot pool size per decode lane
    scheduler: str = "least-loaded"
    platform: Platform = TPU_V5E
    slo: SLO = dataclasses.field(default_factory=SLO)
    # Admission: "none" admits everything, "queue" bounds the backlog,
    # "slo" additionally rejects when the modeled TTFT estimate misses the
    # request's deadline / the SLO budget (backpressure).
    admission: str = "slo"
    max_queue: int = 64
    headroom: float = 0.8           # admit while est. TTFT <= headroom * SLO
    # AIMD slot-target controller (xpra-style congestion response).
    adaptive: bool = True
    aimd_decrease: float = 0.7
    aimd_increase: int = 1
    # Dynamic expert placement: a PlacementConfig here makes every decode
    # step feed its routed-token histogram (seeded Zipf skew over the
    # step's active slots) to an ExpertPlacementPolicy homed on the decode
    # lanes, so live decode traffic drives expert migration/replication.
    # None (the default) leaves serve runs byte-identical to before.
    expert_placement: Optional[PlacementConfig] = None
    expert_zipf_s: float = 1.2

    def __post_init__(self) -> None:
        if self.admission not in ("none", "queue", "slo"):
            raise ValueError(f"bad admission mode {self.admission!r}")
        if not (0 < self.prefill_lanes < self.num_devices):
            raise ValueError(
                "need at least one prefill lane and one decode lane"
            )


@dataclasses.dataclass(frozen=True)
class SlotRefill:
    """One slot-refill edge on a decode lane (the race-rule witness).

    ``refill_issue_s`` is the DMA-stream issue event of the lane's first
    launch after ``freed_rids`` finished; the happens-before invariant
    (``race/slot-refill-before-complete``) is ``refill_issue_s >=
    freed_complete_s`` — a freed slot's successor cannot be issued before
    the finishing request's completion event."""

    device_id: int
    freed_rids: Tuple[int, ...]
    freed_complete_s: float
    next_rids: Tuple[int, ...]
    refill_issue_s: float


@dataclasses.dataclass
class StreamReport:
    """Everything one streaming (or lock-step) run produced."""

    arch: str
    seed: int
    engine: str                     # "continuous" | "lockstep"
    offered_qps: float
    admitted: int
    rejected: int
    completed: int
    sustained_qps: float
    makespan_s: float
    max_active_slots: int
    min_slot_target: int
    slo: accounting.SLOReport
    metrics: List[accounting.RequestMetrics]
    slot_refills: List[SlotRefill]
    # Every ticket this run issued, per device — the full event streams
    # (unlike VirtualDevice.inflight, which is a bounded window), so race
    # checks and rejected-never-launched assertions see the whole run.
    ticket_log: Dict[int, List[LaunchTicket]]
    # Deterministic event trail: (event, modeled_s, id).  Two runs with the
    # same seed must produce identical trails (regression-tested).
    events: List[Tuple[str, float, int]]
    # Flat obs-metrics rollup scoped to this run (admission counts by
    # reason, AIMD decisions, ticket kinds...) — rides into point_dict.
    metrics_rollup: Dict[str, object] = dataclasses.field(
        default_factory=dict)
    # Expert-placement decision identities from this run's decode traffic
    # ((step, kind, expert, src, dst) keys); empty unless
    # StreamConfig.expert_placement was set.
    placement_decisions: List[tuple] = dataclasses.field(
        default_factory=list)

    @property
    def reject_rate(self) -> float:
        total = self.admitted + self.rejected
        return self.rejected / total if total else 0.0

    def point_dict(self) -> dict:
        """The offered-load-sweep row for BENCH_offload.json."""
        o = self.slo.overall
        return {
            "offered_qps": round(self.offered_qps, 3),
            "sustained_qps": round(self.sustained_qps, 3),
            "admitted": self.admitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "reject_rate": round(self.reject_rate, 4),
            "ttft_p50_ms": round(o.ttft.p50_s * 1e3, 3),
            "ttft_p95_ms": round(o.ttft.p95_s * 1e3, 3),
            "ttft_p99_ms": round(o.ttft.p99_s * 1e3, 3),
            "per_token_p50_ms": round(o.per_token.p50_s * 1e3, 4),
            "per_token_p95_ms": round(o.per_token.p95_s * 1e3, 4),
            "per_token_p99_ms": round(o.per_token.p99_s * 1e3, 4),
            "meets_slo": self.slo.meets_slo,
            "metrics": dict(self.metrics_rollup),
        }


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

class _Lane:
    """Decode-lane state: the slot pool and its in-flight step."""

    def __init__(self, device_id: int, slots: int) -> None:
        self.device_id = device_id
        self.slots = slots
        self.slot_target = slots        # AIMD-controlled (<= slots)
        self.active: List[int] = []     # rids in slots, step order
        self.stepping = False
        self.step_issue_s = 0.0
        self.steps = 0
        # Pending refill witness: set when slots free, consumed by the next
        # issued step on this lane (even across an idle gap).
        self.last_freed: Optional[Tuple[Tuple[int, ...], float]] = None


class _StreamSim:
    """Discrete-event simulation of the streaming server (modeled time)."""

    def __init__(self, arch: str, trace: ArrivalTrace, cfg: StreamConfig,
                 cluster: Optional[HeroCluster] = None) -> None:
        self.arch_cfg = get_arch(arch)
        self.arch = arch
        self.trace = trace
        self.cfg = cfg
        self.cluster = cluster or HeroCluster(
            num_devices=cfg.num_devices, platform=cfg.platform,
            scheduler=cfg.scheduler,
        )
        self.prefill_ids = list(range(cfg.prefill_lanes))
        self.lanes = [
            _Lane(d, cfg.decode_slots)
            for d in range(cfg.prefill_lanes, cfg.num_devices)
        ]
        self.kv_per_token = costing.kv_bytes_per_token(self.arch_cfg)
        self.metrics: Dict[int, accounting.RequestMetrics] = {}
        self.requests: Dict[int, Request] = {r.rid: r for r in trace.requests}
        self.kv_handles: Dict[int, DeviceHandle] = {}
        self.kv_bytes: Dict[int, float] = {}
        self.last_token_s: Dict[int, float] = {}
        self.ready: deque = deque()     # rids with prefill done, no slot yet
        self.inflight_prefills = 0
        self.slot_refills: List[SlotRefill] = []
        self.ticket_log: Dict[int, List[LaunchTicket]] = {}
        self.events: List[Tuple[str, float, int]] = []
        self.max_active = 0
        self.min_slot_target = cfg.decode_slots
        self._weight_handles: List[DeviceHandle] = []
        self._heap: List[Tuple[float, int, str, int]] = []
        self._seq = 0
        # Observability: tracer captured once (a sim is single-use); the
        # request-lifecycle asyncs still open at drain time get closed at
        # the final makespan so exported traces always pair begin/end.
        self._tr = _obs_spans.current_tracer()
        self._open_reqs: List[int] = []
        # Optional dynamic expert placement fed by decode traffic: expert
        # weights home on the decode lanes; each issued decode step routes
        # its active-slot tokens through a seeded Zipf histogram.
        self.placement: Optional[ExpertPlacementPolicy] = None
        self._moe_rng: Optional[random.Random] = None
        if cfg.expert_placement is not None:
            self.placement = ExpertPlacementPolicy(
                cfg.expert_placement, self.cluster)
            self.placement.attach([lane.device_id for lane in self.lanes])
            self._moe_rng = random.Random(trace.seed)

    # -- plumbing -----------------------------------------------------------

    def _push(self, t: float, kind: str, ident: int) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, ident))

    def _log_ticket(self, ticket: LaunchTicket) -> None:
        self.ticket_log.setdefault(ticket.device_id, []).append(ticket)

    def _pin_weights(self) -> None:
        """Model the stack weights resident on every lane (pinned once at
        server start); per-launch residency credit is threaded through
        ``assign_at`` as an explicit resident fraction."""
        wb = costing.weight_bytes(self.arch_cfg)
        for d in range(self.cfg.num_devices):
            self._weight_handles.append(
                self.cluster.pin_handle(f"stack-weights-d{d}", wb, device_id=d)
            )

    def _release_all(self) -> None:
        for h in list(self.kv_handles.values()) + self._weight_handles:
            self.cluster.release_handle(h)
        self.kv_handles.clear()

    # -- admission ----------------------------------------------------------

    def _avg_output_len(self) -> float:
        pool = [self.requests[r].output_len for r in self.ready]
        for lane in self.lanes:
            pool.extend(self.requests[r].output_len for r in lane.active)
        return sum(pool) / len(pool) if pool else 64.0

    def _estimate_ttft(self, req: Request, now: float) -> float:
        """Modeled TTFT if admitted now, read off the in-flight window:
        prefill-lane frontier (the max stamped ``complete_s``) + prefill
        time + decode-queue drain ahead of this request + one step."""
        lane = min(
            (self.cluster.devices[d] for d in self.prefill_ids),
            key=lambda dev: dev.stream_makespan_s,
        )
        queue_wait = max(0.0, lane.stream_makespan_s - now)
        pcost = costing.prefill_cost(req.prompt_len, self.arch_cfg)
        prefill_s = self.cluster.policy.score(
            pcost, self.cfg.platform,
            resident_fraction=costing.weight_resident_fraction(
                pcost, self.arch_cfg),
        ).offload_s
        step_s = self._step_estimate_s()
        backlog = len(self.ready) + self.inflight_prefills
        free = sum(
            max(0, lane.slot_target - len(lane.active)) for lane in self.lanes
        )
        waves = max(0, backlog - free) / max(
            sum(lane.slot_target for lane in self.lanes), 1
        )
        queue_delay = waves * self._avg_output_len() * step_s
        return queue_wait + prefill_s + queue_delay + step_s

    def _step_estimate_s(self) -> float:
        width = max(sum(len(lane.active) for lane in self.lanes), 1)
        width = min(width, self.cfg.decode_slots)
        cache = width * 128 * self.kv_per_token
        cost = costing.decode_step_cost(width, self.arch_cfg, cache_bytes=cache)
        return self.cluster.policy.score(
            cost, self.cfg.platform, resident_fraction=0.0
        ).offload_s

    def _admit(self, req: Request, now: float) -> Tuple[bool, str]:
        """Admission decision plus the reject reason ("" on admit)."""
        if self.cfg.admission == "none":
            return True, ""
        backlog = len(self.ready) + self.inflight_prefills
        if backlog >= self.cfg.max_queue:
            return False, "queue-full"
        if self.cfg.admission == "queue":
            return True, ""
        est = now + self._estimate_ttft(req, now)
        budget = self.cfg.headroom * self.cfg.slo.ttft_s
        if self.cfg.slo.ttft_s > 0 and est > now + budget:
            return False, "ttft-budget"
        if req.deadline_s > 0 and est > req.deadline_s:
            return False, "deadline"
        return True, ""

    # -- event handlers -----------------------------------------------------

    def _on_arrival(self, req: Request) -> None:
        now = req.arrival_s
        m = accounting.RequestMetrics(
            rid=req.rid, req_class=req.req_class, arrival_s=now,
            prompt_len=req.prompt_len, output_len=req.output_len,
        )
        self.metrics[req.rid] = m
        ok, reason = self._admit(req, now)
        if not ok:
            m.admitted = False
            self.events.append(("reject", now, req.rid))
            _obs_metrics.counter("serve.rejected", reason=reason).inc()
            if self._tr is not None:
                self._tr.instant(f"reject:{reason}", cat="serve",
                                 lane="requests", t=now,
                                 attrs={"rid": req.rid,
                                        "class": req.req_class})
            return
        self.events.append(("admit", now, req.rid))
        _obs_metrics.counter("serve.admitted").inc()
        if self._tr is not None:
            self._tr.async_begin(f"req{req.rid}", cat="serve",
                                 lane="requests", t=now, pair_id=req.rid,
                                 attrs={"class": req.req_class,
                                        "prompt_len": req.prompt_len,
                                        "output_len": req.output_len})
            self._open_reqs.append(req.rid)
        # Prefill on the least-backlogged prefill lane; the request cannot
        # issue before it arrives (assign_at advances the lane clocks).
        lane_id = min(
            self.prefill_ids,
            key=lambda d: self.cluster.devices[d].stream_makespan_s,
        )
        pcost = costing.prefill_cost(req.prompt_len, self.arch_cfg)
        _, _, ticket = self.cluster.assign_at(
            pcost, f"prefill-{req.rid}", ready_s=now, device_id=lane_id,
            resident_fraction=costing.weight_resident_fraction(
                pcost, self.arch_cfg),
        )
        self._log_ticket(ticket)
        self.inflight_prefills += 1
        # The prefill builds this request's KV cache on its lane.
        kv = req.prompt_len * self.kv_per_token
        self.kv_bytes[req.rid] = kv
        self.kv_handles[req.rid] = self.cluster.pin_handle(
            f"kv-{req.rid}", kv, device_id=lane_id
        )
        m.prefill_done_s = ticket.complete_s
        self._push(ticket.complete_s, "prefill_done", req.rid)

    def _on_prefill_done(self, rid: int, now: float) -> None:
        self.inflight_prefills -= 1
        self.ready.append(rid)
        self.events.append(("ready", now, rid))
        if self._tr is not None:
            self._tr.async_instant("prefill-done", cat="serve",
                                   lane="requests", t=now, pair_id=rid)
            self._tr.counter("ready_queue", now, float(len(self.ready)))
        # Wake any idle lane (one with no step in flight).
        for lane in sorted(self.lanes, key=lambda L: len(L.active)):
            if not lane.stepping:
                self._refill_and_step(lane, now)

    def _refill_and_step(self, lane: _Lane, now: float) -> None:
        """Refill free slots from the ready queue, then issue one step."""
        refilled: List[int] = []
        while self.ready and len(lane.active) < lane.slot_target:
            rid = self.ready.popleft()
            handle = self.kv_handles[rid]
            if handle.device_id != lane.device_id:
                # KV migrates from its prefill lane at-or-after `now`
                # (slots it fills were freed at `now` at the earliest).
                self.cluster.devices[lane.device_id].advance_clocks(now)
                src_dev = handle.device_id
                self.cluster.migrate_handle(handle, lane.device_id)
                self._log_ticket(
                    self.cluster.devices[lane.device_id].inflight[-1]
                )
                if self._tr is not None:
                    self._tr.async_instant(
                        "kv-migrate", cat="serve", lane="requests", t=now,
                        pair_id=rid,
                        attrs={"src": src_dev, "dst": lane.device_id})
            lane.active.append(rid)
            refilled.append(rid)
        if not lane.active:
            return
        self.max_active = max(
            self.max_active, sum(len(L.active) for L in self.lanes)
        )
        cache = sum(
            self.kv_bytes[r]
            + self.metrics[r].tokens_out * self.kv_per_token
            for r in lane.active
        )
        cost = costing.decode_step_cost(
            len(lane.active), self.arch_cfg, cache_bytes=cache
        )
        # Weights + KV ride touched bytes (device-resident); staged bytes
        # are this step's activations only, so no residency credit applies.
        _, _, ticket = self.cluster.assign_at(
            cost, f"decode-step-d{lane.device_id}-{lane.steps}",
            ready_s=now, device_id=lane.device_id, resident_fraction=0.0,
        )
        self._log_ticket(ticket)
        if lane.last_freed is not None:
            freed_rids, freed_t = lane.last_freed
            self.slot_refills.append(SlotRefill(
                device_id=lane.device_id,
                freed_rids=freed_rids,
                freed_complete_s=freed_t,
                next_rids=tuple(refilled),
                refill_issue_s=ticket.issue_s,
            ))
            if self._tr is not None:
                # Arrow from the freeing completion to the refilled step.
                self._tr.flow(
                    "slot-refill", cat="serve",
                    src_lane=f"dev{lane.device_id}/compute", src_t=freed_t,
                    dst_lane=f"dev{lane.device_id}/compute",
                    dst_t=ticket.issue_s,
                    attrs={"freed": list(freed_rids),
                           "next": list(refilled)})
            lane.last_freed = None
        if self._tr is not None:
            self._tr.counter(
                "decode_slots_active", ticket.issue_s,
                float(sum(len(L.active) for L in self.lanes)))
        lane.stepping = True
        lane.step_issue_s = ticket.issue_s
        lane.steps += 1
        if self.placement is not None:
            # This step's tokens (one per active slot) hit the router; the
            # policy sees the histogram at the step's modeled issue time so
            # any migrate/replicate d2d lands on the lane clocks after it.
            hist = zipf_histogram(
                self._moe_rng, self.placement.cfg.num_experts,
                self.cfg.expert_zipf_s, len(lane.active),
            )
            for d in self.placement.step(hist, now_s=ticket.issue_s):
                if d.ticket is not None:
                    self._log_ticket(d.ticket)
                t_dec = (d.ticket.issue_s if d.ticket is not None
                         else ticket.issue_s)
                self.events.append((f"placement-{d.kind}", t_dec, d.expert))
        self._push(ticket.complete_s, "step_done", lane.device_id)

    def _on_step_done(self, lane: _Lane, now: float) -> None:
        lane.stepping = False
        finished: List[int] = []
        for rid in lane.active:
            m = self.metrics[rid]
            m.tokens_out += 1
            if m.tokens_out == 1:
                m.first_token_s = now
                self.events.append(("first_token", now, rid))
                if self._tr is not None:
                    self._tr.async_instant("first-token", cat="serve",
                                           lane="requests", t=now,
                                           pair_id=rid)
            else:
                m.token_latencies_s.append(now - self.last_token_s[rid])
            self.last_token_s[rid] = now
            if m.tokens_out >= m.output_len:
                finished.append(rid)
        for rid in finished:
            m = self.metrics[rid]
            m.finish_s = now
            lane.active.remove(rid)
            self.cluster.release_handle(self.kv_handles.pop(rid))
            self.events.append(("finish", now, rid))
            if self._tr is not None:
                self._tr.async_end(f"req{rid}", cat="serve",
                                   lane="requests", t=now, pair_id=rid,
                                   attrs={"tokens": m.tokens_out})
                self._open_reqs.remove(rid)
        if finished:
            lane.last_freed = (tuple(finished), now)
        if self.cfg.adaptive:
            # AIMD: the step's modeled latency *is* the per-token latency
            # when steps are back to back — shrink the width target hard
            # when it exceeds the budget, regrow it additively.
            step_s = now - lane.step_issue_s
            before = lane.slot_target
            if step_s > self.cfg.slo.per_token_s > 0:
                lane.slot_target = max(
                    1, int(lane.slot_target * self.cfg.aimd_decrease)
                )
                _obs_metrics.counter("serve.aimd",
                                     decision="decrease").inc()
            else:
                lane.slot_target = min(
                    lane.slots, lane.slot_target + self.cfg.aimd_increase
                )
            if self._tr is not None and lane.slot_target != before:
                decision = ("aimd-decrease" if lane.slot_target < before
                            else "aimd-increase")
                self._tr.instant(
                    decision, cat="serve", lane="aimd", t=now,
                    attrs={"device": lane.device_id, "step_s": step_s,
                           "slot_target": lane.slot_target,
                           "was": before})
            self.min_slot_target = min(self.min_slot_target, lane.slot_target)
        self._refill_and_step(lane, now)

    # -- driver -------------------------------------------------------------

    def run(self) -> StreamReport:
        self._pin_weights()
        lane_by_id = {lane.device_id: lane for lane in self.lanes}
        with _obs_metrics.collect() as reg:
            try:
                for req in self.trace.requests:
                    self._push(req.arrival_s, "arrival", req.rid)
                while self._heap:
                    t, _, kind, ident = heapq.heappop(self._heap)
                    if kind == "arrival":
                        self._on_arrival(self.requests[ident])
                    elif kind == "prefill_done":
                        self._on_prefill_done(ident, t)
                    else:
                        self._on_step_done(lane_by_id[ident], t)
                self.cluster.sync()
            finally:
                self._release_all()
        if self._tr is not None and self._open_reqs:
            # Requests still mid-decode when the trace drained: close their
            # lifecycle tracks at the run's modeled frontier so exported
            # traces always pair async begin/end.
            end_t = max((d.stream_makespan_s for d in self.cluster.devices),
                        default=0.0)
            for rid in self._open_reqs:
                self._tr.async_end(f"req{rid}", cat="serve",
                                   lane="requests", t=end_t, pair_id=rid,
                                   attrs={"drained": True})
            self._open_reqs.clear()
        rep = self._report()
        rep.metrics_rollup = reg.rollup()
        return rep

    def _report(self) -> StreamReport:
        ms = [self.metrics[r.rid] for r in self.trace.requests
              if r.rid in self.metrics]
        admitted = sum(1 for m in ms if m.admitted)
        completed = sum(1 for m in ms if m.completed)
        finishes = [m.finish_s for m in ms if m.completed]
        arrivals = [m.arrival_s for m in ms]
        span = (max(finishes) - min(arrivals)) if finishes else 0.0
        return StreamReport(
            arch=self.arch,
            seed=self.trace.seed,
            engine="continuous",
            offered_qps=self.trace.offered_qps,
            admitted=admitted,
            rejected=len(ms) - admitted,
            completed=completed,
            sustained_qps=completed / max(span, 1e-9),
            makespan_s=span,
            max_active_slots=self.max_active,
            min_slot_target=self.min_slot_target,
            slo=accounting.slo_report(
                ms, ttft_slo_s=self.cfg.slo.ttft_s,
                per_token_slo_s=self.cfg.slo.per_token_s,
            ),
            metrics=ms,
            slot_refills=self.slot_refills,
            ticket_log=self.ticket_log,
            events=self.events,
            placement_decisions=(
                list(self.placement.decision_log)
                if self.placement is not None else []
            ),
        )


def serve_stream(
    arch: str,
    trace: ArrivalTrace,
    *,
    config: Optional[StreamConfig] = None,
    cluster: Optional[HeroCluster] = None,
) -> StreamReport:
    """Run the continuous-batching streaming server over one trace.

    Fully modeled and deterministic: same ``trace`` (same seed) and same
    ``config`` produce an identical :attr:`StreamReport.events` trail."""
    cfg = config or StreamConfig()
    return _StreamSim(arch, trace, cfg, cluster=cluster).run()


# ---------------------------------------------------------------------------
# Lock-step baseline (serve_cluster semantics, modeled per step)
# ---------------------------------------------------------------------------

def serve_lockstep(
    arch: str,
    trace: ArrivalTrace,
    *,
    config: Optional[StreamConfig] = None,
) -> StreamReport:
    """The ``serve_cluster`` drain discipline on a live arrival stream.

    Requests batch in arrival order at the full slot width; a batch's
    prefill cannot start until its *last* member arrives (batch-forming
    wait), decode runs every step at full width padded to the longest
    output (finished slots keep burning), and no slot refills until the
    whole batch drains.  Same lanes, same cost model, same trace as
    :func:`serve_stream` — the delta is purely the batching discipline,
    which is what the ``continuous_vs_lockstep`` headline isolates."""
    cfg = config or StreamConfig()
    arch_cfg = get_arch(arch)
    cluster = HeroCluster(
        num_devices=cfg.num_devices, platform=cfg.platform,
        scheduler=cfg.scheduler,
    )
    kv_tok = costing.kv_bytes_per_token(arch_cfg)
    wb = costing.weight_bytes(arch_cfg)
    weight_handles = [
        cluster.pin_handle(f"stack-weights-d{d}", wb, device_id=d)
        for d in range(cfg.num_devices)
    ]
    decode_ids = list(range(cfg.prefill_lanes, cfg.num_devices))
    prefill_ids = list(range(cfg.prefill_lanes))
    metrics: List[accounting.RequestMetrics] = []
    ticket_log: Dict[int, List[LaunchTicket]] = {}
    events: List[Tuple[str, float, int]] = []

    def log(t: LaunchTicket) -> None:
        ticket_log.setdefault(t.device_id, []).append(t)

    reqs = list(trace.requests)
    batches = [
        reqs[i:i + cfg.decode_slots]
        for i in range(0, len(reqs), cfg.decode_slots)
    ]
    try:
        for bi, batch in enumerate(batches):
            ready_t = max(r.arrival_s for r in batch)  # batch-forming wait
            ms = [
                accounting.RequestMetrics(
                    rid=r.rid, req_class=r.req_class, arrival_s=r.arrival_s,
                    prompt_len=r.prompt_len, output_len=r.output_len,
                )
                for r in batch
            ]
            metrics.extend(ms)
            p_lane = min(
                prefill_ids,
                key=lambda d: cluster.devices[d].stream_makespan_s,
            )
            pcost = costing.prefill_cost(
                sum(r.prompt_len for r in batch), arch_cfg
            )
            _, _, pt = cluster.assign_at(
                pcost, f"lockstep-prefill-{bi}", ready_s=ready_t,
                device_id=p_lane,
                resident_fraction=costing.weight_resident_fraction(
                    pcost, arch_cfg),
            )
            log(pt)
            for m in ms:
                m.prefill_done_s = pt.complete_s
            kv0 = sum(r.prompt_len for r in batch) * kv_tok
            handle = cluster.pin_handle(f"kv-batch-{bi}", kv0, device_id=p_lane)
            d_lane = min(
                decode_ids,
                key=lambda d: cluster.devices[d].stream_makespan_s,
            )
            cluster.devices[d_lane].advance_clocks(pt.complete_s)
            cluster.migrate_handle(handle, d_lane)
            log(cluster.devices[d_lane].inflight[-1])
            width = len(batch)
            max_out = max(r.output_len for r in batch)
            last_tok = {r.rid: 0.0 for r in batch}
            step_ready = pt.complete_s
            for step in range(max_out):
                # padded: every slot charges compute + KV whether or not
                # its request already finished (the lock-step tax)
                cache = kv0 + width * step * kv_tok
                cost = costing.decode_step_cost(
                    width, arch_cfg, cache_bytes=cache
                )
                _, _, st = cluster.assign_at(
                    cost, f"lockstep-decode-{bi}-{step}", ready_s=step_ready,
                    device_id=d_lane, resident_fraction=0.0,
                )
                log(st)
                step_ready = 0.0  # subsequent steps queue on the lane clock
                now = st.complete_s
                for r, m in zip(batch, ms):
                    if m.tokens_out >= m.output_len:
                        continue
                    m.tokens_out += 1
                    if m.tokens_out == 1:
                        m.first_token_s = now
                        events.append(("first_token", now, r.rid))
                    else:
                        m.token_latencies_s.append(now - last_tok[r.rid])
                    last_tok[r.rid] = now
                    if m.tokens_out >= m.output_len:
                        m.finish_s = now
                        events.append(("finish", now, r.rid))
            cluster.release_handle(handle)
        cluster.sync()
    finally:
        for h in weight_handles:
            cluster.release_handle(h)
    completed = sum(1 for m in metrics if m.completed)
    finishes = [m.finish_s for m in metrics if m.completed]
    arrivals = [m.arrival_s for m in metrics]
    span = (max(finishes) - min(arrivals)) if finishes else 0.0
    return StreamReport(
        arch=arch,
        seed=trace.seed,
        engine="lockstep",
        offered_qps=trace.offered_qps,
        admitted=len(metrics),
        rejected=0,
        completed=completed,
        sustained_qps=completed / max(span, 1e-9),
        makespan_s=span,
        max_active_slots=cfg.decode_slots * len(decode_ids),
        min_slot_target=cfg.decode_slots,
        slo=accounting.slo_report(
            metrics, ttft_slo_s=cfg.slo.ttft_s,
            per_token_slo_s=cfg.slo.per_token_s,
        ),
        metrics=metrics,
        slot_refills=[],
        ticket_log=ticket_log,
        events=events,
    )


# ---------------------------------------------------------------------------
# Offered-load sweep (the headline producer)
# ---------------------------------------------------------------------------

def estimate_capacity(arch: str, config: Optional[StreamConfig] = None) -> float:
    """Back-of-envelope sustainable QPS from the cost model (sweep anchor).

    Decode bound: each lane completes ``slots`` requests every
    ``avg_output x step_time`` seconds at full width; prefill bound: one
    prompt pass per request per prefill lane.  The knee lives near the
    smaller of the two — load points are placed as fractions of it."""
    cfg = config or StreamConfig()
    arch_cfg = get_arch(arch)
    score = OffloadPolicyScore(cfg)
    avg_prompt, avg_out = 96, 56    # midpoints of DEFAULT_CLASSES mixture
    kv = (
        cfg.decode_slots * (avg_prompt + avg_out)
        * costing.kv_bytes_per_token(arch_cfg)
    )
    step_s = score(
        costing.decode_step_cost(cfg.decode_slots, arch_cfg, cache_bytes=kv)
    )
    decode_lanes = cfg.num_devices - cfg.prefill_lanes
    decode_qps = decode_lanes * cfg.decode_slots / (avg_out * step_s)
    pcost = costing.prefill_cost(avg_prompt, arch_cfg)
    prefill_s = score(
        pcost, rf=costing.weight_resident_fraction(pcost, arch_cfg)
    )
    prefill_qps = cfg.prefill_lanes / prefill_s
    return min(decode_qps, prefill_qps)


class OffloadPolicyScore:
    """Tiny adapter: score a cost on a config's platform (no cluster)."""

    def __init__(self, cfg: StreamConfig) -> None:
        from repro.core.hero import OffloadPolicy

        self.policy = OffloadPolicy()
        self.platform = cfg.platform

    def __call__(self, cost, rf: float = 0.0) -> float:
        return self.policy.score(
            cost, self.platform, resident_fraction=rf
        ).offload_s


def offered_load_sweep(
    arch: str = "yi-6b",
    *,
    utils: Sequence[float] = (0.5, 1.0, 2.0),
    seed: int = 0,
    duration_s: float = 1.5,
    config: Optional[StreamConfig] = None,
) -> dict:
    """Sweep offered load over one bursty trace; produce the bench section.

    One base bursty trace at the highest load point is time-scaled down to
    the lower points (:func:`scale_trace`), so every point — and the
    lock-step baseline — serves the *identical request population*.  The
    headline is ``max_qps_at_slo``: the largest sustained QPS among points
    whose p99 TTFT / per-token tails meet the SLO."""
    cfg = config or StreamConfig()
    capacity = estimate_capacity(arch, cfg)
    top = max(utils)
    base = bursty_trace(capacity * top, duration_s, seed=seed)
    points: List[dict] = []
    lockstep_points: List[dict] = []
    runs: List[Tuple[ArrivalTrace, StreamReport, StreamReport]] = []
    best: Optional[int] = None
    for u in utils:
        trace = scale_trace(base, u / top)
        rep = serve_stream(arch, trace, config=cfg)
        lock = serve_lockstep(arch, trace, config=cfg)
        runs.append((trace, rep, lock))
        points.append(rep.point_dict())
        lockstep_points.append(lock.point_dict())
        if rep.slo.meets_slo and (
            best is None or rep.sustained_qps > runs[best][1].sustained_qps
        ):
            best = len(runs) - 1
    max_qps = runs[best][1].sustained_qps if best is not None else 0.0
    lock_max = max(
        (p["sustained_qps"] for p in lockstep_points if p["meets_slo"]),
        default=0.0,
    )
    # Continuous vs lock-step on the SAME trace at the knee: the batching
    # discipline is the only delta.
    knee, cont_at_knee, lock_at_knee = runs[best if best is not None else 0]
    speedup = cont_at_knee.sustained_qps / max(
        lock_at_knee.sustained_qps, 1e-9
    )
    return {
        "arch": arch,
        "seed": seed,
        "trace": "bursty",
        "duration_s": duration_s,
        "estimated_capacity_qps": round(capacity, 3),
        "slo": {
            "ttft_ms": cfg.slo.ttft_s * 1e3,
            "per_token_ms": cfg.slo.per_token_s * 1e3,
        },
        "config": {
            "num_devices": cfg.num_devices,
            "prefill_lanes": cfg.prefill_lanes,
            "decode_slots": cfg.decode_slots,
            "admission": cfg.admission,
            "adaptive": cfg.adaptive,
        },
        "points": points,
        "lockstep_points": lockstep_points,
        "max_qps_at_slo": round(max_qps, 3),
        "lockstep_max_qps_at_slo": round(lock_max, 3),
        "continuous_vs_lockstep": {
            "knee_offered_qps": round(knee.offered_qps, 3),
            "continuous_qps": round(cont_at_knee.sustained_qps, 3),
            "lockstep_qps": round(lock_at_knee.sustained_qps, 3),
            "speedup": round(speedup, 3),
        },
    }
