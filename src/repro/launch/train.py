"""End-to-end training driver with checkpoint/restart fault tolerance.

Usage (CPU-scale example — see examples/train_lm.py for the ~100M run):

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --global-batch 8 --seq-len 128
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.data import SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import TrainOptions, init_train_state, make_train_step
from repro.models import build_model
from repro.sharding import batch_pspecs, named, opt_pspecs, param_pspecs


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 50,
    global_batch: int = 8,
    seq_len: int = 128,
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 20,
    peak_lr: float | None = None,
    compress_grads: bool = False,
    resume: bool = True,
    log_every: int = 10,
    num_microbatches: int | None = None,
):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    if num_microbatches is not None:
        cfg = dataclasses.replace(cfg, num_microbatches=num_microbatches)
    model = build_model(cfg)
    mesh = make_local_mesh()
    if peak_lr is None:
        # The reduced smoke models are a few hundred K params; at the
        # full-size default (3e-4) they move less per step than the
        # batch-to-batch loss noise of the synthetic stream, so short smoke
        # runs can't show descent.  Tiny models take a bigger step.
        peak_lr = 3e-3 if smoke else 3e-4
    opts = TrainOptions(
        peak_lr=peak_lr, warmup_steps=max(steps // 10, 1), total_steps=steps,
        compress_grads=compress_grads,
    )

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state, err = init_train_state(model, params, opts)

    ckpt = Checkpointer(Path(ckpt_dir))
    start_step = 0
    if resume and ckpt.latest_step() is not None:
        (params, opt_state), start_step = ckpt.restore((params, opt_state))
        print(f"resumed from step {start_step}")

    data = SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed=17)
    p_shard = named(mesh, param_pspecs(jax.eval_shape(lambda: params), mesh))
    step_fn = jax.jit(
        make_train_step(model, opts), donate_argnums=(0, 1),
    )

    losses = []
    t_start = time.time()
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, err, metrics = step_fn(params, opt_state, err, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t_start
            print(f"step {step:5d}  loss {losses[-1]:.4f}  ({dt:.1f}s)")
        if (step + 1) % ckpt_every == 0 or step == steps - 1:
            # async: snapshot now, write in background (one in flight)
            ckpt.save_async(step + 1, (params, opt_state))
    ckpt.wait()
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=None)  # None: auto by scale
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-resume", dest="resume", action="store_false")
    args = ap.parse_args()
    train(
        args.arch,
        smoke=args.smoke,
        steps=args.steps,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        peak_lr=args.lr,
        compress_grads=args.compress_grads,
        resume=args.resume,
    )


if __name__ == "__main__":
    main()
