import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, record memory/cost/collective analysis for §Roofline.

MUST be run as a script / module entry — the XLA_FLAGS line above executes
before any jax import, forcing 512 host devices (this process only).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ALL_SHAPES, get_arch, list_archs, shape_applicable
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    TrainOptions,
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models import build_model
from repro.roofline.analysis import parse_collectives
from repro.roofline.hlo_parse import analyze_module
from repro.sharding import (
    batch_pspecs,
    cache_pspecs,
    named,
    opt_pspecs,
    param_pspecs,
)

from jax.sharding import PartitionSpec as P


def seam_costs(arch_name: str, shape: ShapeConfig):
    """Kernel-ideal workload from the BLAS seam (trace-time accounting).

    Forward ops are recorded with structural (scan trip) multipliers; for
    training the backward+remat factor is applied analytically: matmul
    backward = 2 extra GEMMs per forward GEMM, remat re-runs forward
    (factor 4 with remat, 3 without).  ``touched_bytes`` assumes each op
    streams operands/results exactly once — the VMEM/SPM-tiled execution
    the paper's device kernels implement (kernel-ideal HBM traffic)."""
    from repro.core import accounting

    cfg = get_arch(arch_name)
    model = build_model(cfg)
    specs = model.input_specs(shape)
    with accounting.offload_trace() as trace:
        if shape.kind in ("train", "prefill"):
            jax.eval_shape(
                lambda p, b: model.forward(p, b), params_abstract(model), specs
            )
        else:
            jax.eval_shape(
                lambda p, c, t, i: model.decode_step(p, c, t, i),
                params_abstract(model),
                specs["cache"], specs["tokens"], specs["cache_index"],
            )
    fwd_flops = trace.total_flops()
    fwd_bytes = trace.total_touched_bytes()
    if shape.kind == "train":
        factor = 4.0 if cfg.remat else 3.0
        return fwd_flops * factor, fwd_bytes * factor
    return fwd_flops, fwd_bytes


_PARAMS_ABSTRACT_CACHE = {}


def params_abstract(model):
    key = model.cfg.name
    if key not in _PARAMS_ABSTRACT_CACHE:
        _PARAMS_ABSTRACT_CACHE[key] = jax.eval_shape(
            lambda: model.init_params(jax.random.PRNGKey(0))
        )
    return _PARAMS_ABSTRACT_CACHE[key]


def lower_cell(arch_name: str, shape: ShapeConfig, mesh, *, donate: bool = True):
    """Build + lower + compile one cell. Returns (compiled, lowered, meta)."""
    cfg = get_arch(arch_name)
    model = build_model(cfg)
    specs = model.input_specs(shape)

    param_shapes = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0))
    )
    p_specs = param_pspecs(param_shapes, mesh, fsdp=cfg.fsdp)
    p_shard = named(mesh, p_specs)

    if shape.kind == "train":
        opts = TrainOptions()
        opt_shapes = jax.eval_shape(
            lambda p: init_train_state(model, p, opts)[0], param_shapes
        )
        o_shard = named(
            mesh, opt_pspecs(opt_shapes, mesh, fsdp=cfg.fsdp or cfg.zero1)
        )
        b_shard = named(mesh, batch_pspecs(specs, mesh))
        step = make_train_step(model, opts)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, None, b_shard),
            out_shardings=(p_shard, o_shard, None, None),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = fn.lower(param_shapes, opt_shapes, None, specs)
    elif shape.kind == "prefill":
        b_shard = named(mesh, batch_pspecs(specs, mesh))
        step = make_prefill_step(model)
        fn = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = fn.lower(param_shapes, specs)
    else:  # decode
        cache_shapes = specs["cache"]
        c_shard = named(mesh, cache_pspecs(cache_shapes, mesh))
        tok_shard = named(
            mesh, batch_pspecs({"tokens": specs["tokens"]}, mesh)
        )["tokens"]
        step = make_serve_step(model)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, tok_shard, None),
            out_shardings=(None, c_shard),
            donate_argnums=(1,) if donate else (),
        )
        lowered = fn.lower(
            param_shapes, cache_shapes, specs["tokens"], specs["cache_index"]
        )

    compiled = lowered.compile()
    return compiled, lowered, {"cfg": cfg, "model": model}


def run_cell(arch_name: str, shape: ShapeConfig, mesh, mesh_name: str, out_dir: Path):
    out_path = out_dir / mesh_name / f"{arch_name}__{shape.name}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    if out_path.exists():
        print(f"[skip-done] {arch_name} x {shape.name} ({mesh_name})")
        return json.loads(out_path.read_text())

    cfg = get_arch(arch_name)
    ok, reason = shape_applicable(cfg, shape)
    rec = {
        "arch": arch_name,
        "shape": shape.name,
        "mesh": mesh_name,
        "chips": mesh.devices.size,
    }
    if not ok:
        rec.update({"status": "skipped", "reason": reason})
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[skip-n/a ] {arch_name} x {shape.name}: {reason}")
        return rec

    t0 = time.time()
    try:
        seam = seam_costs(arch_name, shape)
        with mesh:
            compiled, lowered, meta = lower_cell(arch_name, shape, mesh)
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)          # scan-once (raw) view
        rolled = analyze_module(hlo)           # trip-count-aware rollup
        rec.update(
            {
                "status": "ok",
                "compile_s": round(time.time() - t0, 1),
                # raw cost_analysis (counts scan bodies once — kept for
                # reference / the MODEL/HLO waste ratio discussion)
                "flops_per_device_raw": float(cost.get("flops", -1.0)),
                "bytes_per_device_raw": float(cost.get("bytes accessed", -1.0)),
                # trip-count-aware per-device totals (used for §Roofline)
                "dot_flops_per_device": rolled.dot_flops,
                "traffic_bytes_per_device": rolled.traffic_bytes,
                "collective_bytes_per_device": rolled.collective_bytes,
                "collective_counts": rolled.collective_counts,
                "collectives_raw": coll,
                "memory_analysis": {
                    k: int(getattr(mem, k))
                    for k in (
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "generated_code_size_in_bytes",
                    )
                    if hasattr(mem, k)
                },
                "params": meta["cfg"].param_count(),
                "active_params": meta["cfg"].active_param_count(),
                "seam_flops_global": seam[0],
                "seam_bytes_global": seam[1],
                "tokens_per_step": shape.global_batch * shape.seq_len
                if shape.kind != "decode"
                else shape.global_batch,
            }
        )
        print(
            f"[ok {rec['compile_s']:7.1f}s] {arch_name} x {shape.name} ({mesh_name}) "
            f"dotflops/dev={rolled.dot_flops:.3e} "
            f"coll/dev={rolled.collective_bytes:.3e}B "
            f"temp={rec['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.1f}GiB"
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(
            {
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
                "compile_s": round(time.time() - t0, 1),
            }
        )
        print(f"[FAIL {rec['compile_s']:6.1f}s] {arch_name} x {shape.name}: {rec['error'][:200]}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = []
    if args.both_meshes:
        meshes = [(make_production_mesh(), "pod16x16"),
                  (make_production_mesh(multi_pod=True), "multipod2x16x16")]
    elif args.multi_pod:
        meshes = [(make_production_mesh(multi_pod=True), "multipod2x16x16")]
    else:
        meshes = [(make_production_mesh(), "pod16x16")]

    archs = [a for a in list_archs() if a != "paper-gemm"]
    if args.arch:
        archs = [args.arch]
    shapes = list(ALL_SHAPES)
    if args.shape:
        shapes = [s for s in ALL_SHAPES if s.name == args.shape]

    failures = 0
    for mesh, mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, mesh_name, out_dir)
                failures += rec.get("status") == "error"
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
