"""repro.launch — mesh, dry-run, train, serve entry points."""
