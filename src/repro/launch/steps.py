"""jit-able step functions: train (grad-accum microbatching), prefill, serve.

``make_train_step`` returns a pure ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` with:
  * sequential gradient accumulation over ``cfg.num_microbatches`` (memory:
    activations live for one microbatch only — how the 72-80L × 1M-token
    train cells fit a 16 GB/chip pod),
  * optional int8 error-feedback gradient compression before the DP
    all-reduce (``TrainOptions.compress_grads``),
  * AdamW or blockwise-int8 AdamW keyed by the arch config.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model
from repro.optim import compression as C
from repro.optim.adamw import make_optimizer
from repro.optim.schedules import warmup_cosine

__all__ = ["TrainOptions", "make_train_step", "make_prefill_step", "make_serve_step", "init_train_state"]


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False


def _split_microbatches(batch: Dict[str, jax.Array], nmb: int):
    """(B, …) -> (nmb, B/nmb, …); 'positions' is (3, B, S) -> (nmb, 3, ·, S).

    The split keeps the *batch* factor major — ``(B,) -> (B/nmb, nmb) ->
    moveaxis`` — so a data-sharded batch dim stays sharded on the per-step
    batch and the scan (microbatch) axis is replicated.  The naive
    ``reshape(nmb, B/nmb)`` puts the sharded major factor on the scan axis
    and GSPMD then replicates every microbatch's compute across the DP
    groups (measured: 8x dot-flops inflation at nmb=8)."""

    def leaf(key, x):
        if key == "positions":
            b = x.shape[1]
            assert b % nmb == 0, f"batch {b} % microbatches {nmb}"
            y = x.reshape(x.shape[0], b // nmb, nmb, *x.shape[2:])
            return jnp.moveaxis(y, 2, 0)
        b = x.shape[0]
        assert b % nmb == 0, f"batch {b} % microbatches {nmb}"
        y = x.reshape(b // nmb, nmb, *x.shape[1:])
        return jnp.moveaxis(y, 1, 0)

    return {k: leaf(k, v) for k, v in batch.items()}


def init_train_state(model: Model, params, opts: TrainOptions):
    """(opt_state, error_feedback_buffers_or_None)."""
    opt_init, _ = make_optimizer(
        model.cfg, warmup_cosine(opts.peak_lr, opts.warmup_steps, opts.total_steps)
    )
    opt_state = opt_init(params)
    err = C.init_error_buffer(params) if opts.compress_grads else None
    return opt_state, err


def make_train_step(model: Model, opts: TrainOptions = TrainOptions()):
    cfg = model.cfg
    _, opt_update = make_optimizer(
        cfg, warmup_cosine(opts.peak_lr, opts.warmup_steps, opts.total_steps)
    )
    nmb = cfg.num_microbatches
    accum_dtype = jnp.dtype(cfg.accum_dtype)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(params, opt_state, err, batch):
        if nmb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_microbatches(batch, nmb)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )

            def body(acc, mb):
                from repro.core import accounting

                with accounting.scaled(nmb):  # mb scan body runs nmb times
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(accum_dtype), acc, g
                )
                return acc, l

            gsum, losses = jax.lax.scan(body, g0, mbs)
            grads = jax.tree_util.tree_map(lambda g: (g / nmb), gsum)
            loss = jnp.mean(losses)

        if err is not None:
            grads, err = C.compress_decompress(grads, err)

        new_params, new_opt = opt_update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32)}
        return new_params, new_opt, err, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, tokens, cache_index):
        return model.decode_step(params, cache, tokens, cache_index)

    return serve_step
