"""Version-compat shims for JAX API drift, resolved once in one place.

The repo is written against the newest JAX surface; older installed
versions spell the same features differently.  Policy (see ROADMAP.md
"Open items"): every cross-version API difference is absorbed *here* —
kernel and model code imports from ``repro.compat`` and never probes
``jax.*`` attributes itself, so the next drift is a one-file fix.

Currently shimmed:

* ``tpu_compiler_params(**kw)`` — ``jax.experimental.pallas.tpu`` renamed
  ``TPUCompilerParams`` to ``CompilerParams``; resolve whichever exists.
* ``shard_map(...)`` — ``jax.shard_map`` (new spelling, ``check_vma=``)
  vs ``jax.experimental.shard_map.shard_map`` (old spelling,
  ``check_rep=``).  The wrapper accepts either keyword and translates.
"""

from __future__ import annotations

from typing import Any

import jax

__all__ = [
    "axis_size",
    "shard_map",
    "tpu_compiler_params",
]


def axis_size(axis_name) -> Any:
    """``jax.lax.axis_size`` on new JAX; ``psum(1, axis)`` on old."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


# --- Pallas TPU CompilerParams -------------------------------------------

# Resolved lazily: repro.compat is imported by non-Pallas consumers
# (models, launch) for shard_map/axis_size, and an eager pallas.tpu probe
# would turn a Pallas-only drift into a whole-suite import failure.
_COMPILER_PARAMS_CLS = None


def _resolve_compiler_params_cls():
    global _COMPILER_PARAMS_CLS
    if _COMPILER_PARAMS_CLS is None:
        from jax.experimental.pallas import tpu as pltpu

        for name in ("CompilerParams", "TPUCompilerParams"):
            cls = getattr(pltpu, name, None)
            if cls is not None:
                _COMPILER_PARAMS_CLS = cls
                break
        else:
            raise AttributeError(
                "jax.experimental.pallas.tpu exposes neither CompilerParams "
                "nor TPUCompilerParams; unsupported JAX version"
            )
    return _COMPILER_PARAMS_CLS


def tpu_compiler_params(**kwargs: Any):
    """Build the Pallas-TPU compiler-params object under either name."""
    return _resolve_compiler_params_cls()(**kwargs)


# --- shard_map ------------------------------------------------------------

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _OLD_SHARD_MAP
else:
    _OLD_SHARD_MAP = None


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs: Any):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    Accepts both replication-check spellings (``check_vma=`` new,
    ``check_rep=`` old) and forwards whichever the resolved function
    understands.
    """
    check = None
    if "check_vma" in kwargs:
        check = kwargs.pop("check_vma")
    if "check_rep" in kwargs:
        check = kwargs.pop("check_rep")
    if _NEW_SHARD_MAP is not None:
        if check is not None:
            kwargs["check_vma"] = check
        return _NEW_SHARD_MAP(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    if check is not None:
        kwargs["check_rep"] = check
    return _OLD_SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
