"""Lazy expression graph behind ``repro.hnp``.

The paper's value proposition is *transparency*: a plain NumPy program gets
accelerated because the library underneath makes the offload decisions.
This module is the capture half of that story — array operations build an
expression graph of :class:`Node` s instead of executing, so the scheduler
(:mod:`repro.frontend.schedule`) can lower the *whole* computation onto the
offload registry: fuse elementwise epilogues into their producer, batch
independent GEMMs, and keep device-resident intermediates on device.

Import-light by contract: this module imports only the standard library and
numpy at module scope (jax and the offload seam load lazily at graph-build /
evaluation time).  ``make collect`` gates every ``repro.frontend`` module
import under one second.

Node kinds:

* ``leaf``             — a concrete array (or Python scalar) fed into the
                         graph by :func:`repro.hnp.array` / operator lifting;
* ``registry:<op>``    — a *heavy* node lowered through the declarative op
                         registry (``core/dispatch.py``); any registered
                         ``OffloadOp`` appears in ``hnp`` for free;
* elementwise / reduction / shape nodes — light ops executed with ``jnp``
                         during evaluation; single-consumer elementwise
                         chains are fused into their producer's lowering.
"""

from __future__ import annotations

import itertools
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ELEMENTWISE",
    "ELEMENTWISE_BINARY",
    "ELEMENTWISE_UNARY",
    "LazyArray",
    "Node",
    "REDUCTIONS",
    "SHAPE_OPS",
    "is_heavy",
    "leaf",
    "lift",
    "registry_node",
]

_IDS = itertools.count()

ELEMENTWISE_UNARY = frozenset({
    "tanh", "exp", "sqrt", "abs", "neg", "relu", "silu", "gelu", "sigmoid",
})
ELEMENTWISE_BINARY = frozenset({
    "add", "sub", "mul", "div", "maximum", "minimum", "pow",
})
ELEMENTWISE = ELEMENTWISE_UNARY | ELEMENTWISE_BINARY
REDUCTIONS = frozenset({"sum", "mean", "max", "min"})
SHAPE_OPS = frozenset({"reshape", "transpose", "astype"})

_UNSET = object()


def is_heavy(op: str) -> bool:
    """Heavy nodes lower through the offload registry (one dispatch each)."""
    return op.startswith("registry:")


def _result_dtype(*dtypes):
    """Promotion over the array operands (Python scalars are weak: they
    never widen an array dtype, loosely matching JAX's weak typing)."""
    dts = [d for d in dtypes if d is not None]
    if not dts:
        return np.dtype(np.float32)
    if all(d == dts[0] for d in dts):
        return dts[0]
    try:
        return np.result_type(*dts)
    except TypeError:
        # bf16 et al. only promote through jnp's lattice
        import jax.numpy as jnp

        return jnp.result_type(*dts)


def _broadcast_shapes(*shapes):
    return np.broadcast_shapes(*shapes)


class Node:
    """One vertex of the expression graph.

    ``attrs`` holds the static (non-array) part of the call.  For registry
    nodes it carries a call template so the scheduler can rebuild the exact
    positional/keyword signature around the evaluated inputs.  ``value``
    caches the evaluated result so shared subgraphs execute once.
    """

    __slots__ = ("id", "op", "inputs", "attrs", "shape", "dtype", "_value")

    def __init__(
        self,
        op: str,
        inputs: Sequence["Node"],
        attrs: Optional[Dict[str, Any]],
        shape: Tuple[int, ...],
        dtype,
    ) -> None:
        self.id = next(_IDS)
        self.op = op
        self.inputs = tuple(inputs)
        self.attrs = attrs or {}
        self.shape = tuple(int(d) for d in shape)
        self.dtype = dtype
        self._value = _UNSET

    # ---- cached evaluation ------------------------------------------------
    @property
    def evaluated(self) -> bool:
        return self._value is not _UNSET

    @property
    def value(self):
        if self._value is _UNSET:
            raise RuntimeError(f"node {self.id} ({self.op}) not evaluated")
        return self._value

    def set_value(self, v) -> None:
        self._value = v

    # ---- derived ----------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    @property
    def nbytes(self) -> float:
        if self.dtype is None:
            return 0.0
        return float(self.size) * np.dtype(self.dtype).itemsize

    def __repr__(self) -> str:
        return (
            f"Node(id={self.id}, op={self.op!r}, shape={self.shape}, "
            f"dtype={self.dtype})"
        )


def leaf(x, dtype=None) -> Node:
    """Wrap a concrete array (or Python scalar) as a graph input."""
    if isinstance(x, LazyArray):
        return x.node
    if isinstance(x, Node):
        return x
    if isinstance(x, (bool, int, float, complex)):
        n = Node("leaf", (), {"weak": True}, (), None)
        n.set_value(x)
        return n
    if dtype is not None and getattr(x, "dtype", None) != dtype:
        import jax.numpy as jnp

        x = jnp.asarray(x, dtype)
    shape = tuple(getattr(x, "shape", np.shape(x)))
    dt = getattr(x, "dtype", None)
    if dt is None:
        x = np.asarray(x)
        dt = x.dtype
        shape = x.shape
    n = Node("leaf", (), {}, shape, dt)
    n.set_value(x)
    return n


def lift(x) -> Node:
    return leaf(x)


# ---------------------------------------------------------------------------
# Node constructors
# ---------------------------------------------------------------------------

def _elementwise_unary(op: str, x: Node) -> Node:
    return Node(op, (x,), {}, x.shape, x.dtype)


def _elementwise_binary(op: str, a: Node, b: Node) -> Node:
    shape = _broadcast_shapes(a.shape, b.shape)
    dtype = _result_dtype(a.dtype, b.dtype)
    return Node(op, (a, b), {}, shape, dtype)


def _reduction(op: str, x: Node, axis=None, keepdims: bool = False) -> Node:
    if axis is None:
        axes = tuple(range(x.ndim))
    else:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        axes = tuple(a % x.ndim for a in axes)
    if keepdims:
        shape = tuple(1 if i in axes else d for i, d in enumerate(x.shape))
    else:
        shape = tuple(d for i, d in enumerate(x.shape) if i not in axes)
    return Node(op, (x,), {"axis": axis, "keepdims": keepdims}, shape, x.dtype)


def _spec(node: Node):
    """ShapeDtypeStruct for abstract evaluation of a registry lowering."""
    import jax

    return jax.ShapeDtypeStruct(node.shape, node.dtype)


def registry_node(opname: str, args: Sequence[Any], kwargs: Dict[str, Any]) -> Node:
    """Build a heavy node for one registered ``OffloadOp``.

    Array-like operands (lazy or concrete) become graph inputs; everything
    else stays static in the call template.  Shape/dtype are inferred by
    abstract evaluation (``jax.eval_shape``) of the op's host lowering, so
    every registered op — present and future — gets graph capture without
    frontend changes: *register once, appear in ``hnp`` for free*.
    """
    from repro.core.dispatch import get_op

    op = get_op(opname)  # raises KeyError for unknown ops, eagerly

    inputs = []
    template = []  # per positional slot: ("in", input_index) | ("static", v)
    kw_inputs = {}  # kw name -> input index
    static_kwargs = {}
    for a in args:
        if isinstance(a, (LazyArray, Node)) or (
            hasattr(a, "shape") and hasattr(a, "dtype")
        ):
            n = lift(a)
            template.append(("in", len(inputs)))
            inputs.append(n)
        else:
            template.append(("static", a))
    for k, v in kwargs.items():
        if isinstance(v, (LazyArray, Node)) or (
            hasattr(v, "shape") and hasattr(v, "dtype")
        ):
            n = lift(v)
            kw_inputs[k] = len(inputs)
            inputs.append(n)
        else:
            static_kwargs[k] = v

    # Abstract shape/dtype inference through the host lowering.
    import jax

    def _rebuild(vals):
        pos = [
            vals[idx] if kind == "in" else idx
            for kind, idx in template
        ]
        kw = dict(static_kwargs)
        for k, idx in kw_inputs.items():
            kw[k] = vals[idx]
        return pos, kw

    def _abstract(*vals):
        pos, kw = _rebuild(list(vals))
        return op.host(*pos, **kw)

    specs = [_spec(n) if n.dtype is not None else n.value for n in inputs]
    out = jax.eval_shape(_abstract, *specs)
    if not hasattr(out, "shape"):
        raise TypeError(
            f"registry op {opname!r} host lowering returned a non-array; "
            "cannot capture it in an hnp graph"
        )
    attrs = {
        "name": opname,
        "template": tuple(template),
        "kw_inputs": dict(kw_inputs),
        "kwargs": dict(static_kwargs),
    }
    return Node(f"registry:{opname}", inputs, attrs, out.shape, out.dtype)


def rebuild_call(node: Node, values: Sequence[Any]):
    """Reconstruct (args, kwargs) of a registry node around input values."""
    pos = [
        values[idx] if kind == "in" else idx
        for kind, idx in node.attrs["template"]
    ]
    kw = dict(node.attrs["kwargs"])
    for k, idx in node.attrs["kw_inputs"].items():
        kw[k] = values[idx]
    return pos, kw


# ---------------------------------------------------------------------------
# LazyArray — the user-facing ndarray stand-in
# ---------------------------------------------------------------------------

class LazyArray:
    """NumPy-like array whose operations build an expression graph.

    Nothing executes until the array is forced — ``hnp.asnumpy(x)``,
    ``x.block()``, ``np.asarray(x)`` or ``float(x)`` — at which point the
    scheduler lowers the whole captured graph onto the offload registry.
    """

    __slots__ = ("node",)

    # win over np.ndarray in mixed binary ops (ndarray op LazyArray)
    __array_priority__ = 1000

    def __init__(self, node: Node) -> None:
        self.node = node

    # ---- metadata ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.node.shape

    @property
    def dtype(self):
        return self.node.dtype

    @property
    def ndim(self) -> int:
        return self.node.ndim

    @property
    def size(self) -> int:
        return self.node.size

    @property
    def nbytes(self) -> float:
        return self.node.nbytes

    def __len__(self) -> int:
        if not self.shape:
            raise TypeError("len() of unsized LazyArray")
        return self.shape[0]

    def __repr__(self) -> str:
        state = "evaluated" if self.node.evaluated else "lazy"
        return (
            f"LazyArray(shape={self.shape}, dtype={self.dtype}, "
            f"op={self.node.op!r}, {state})"
        )

    # ---- forcing ----------------------------------------------------------
    def block(self) -> "LazyArray":
        """Force evaluation of the captured graph (result cached)."""
        from repro.frontend import schedule

        schedule.evaluate(self.node)
        return self

    def __array__(self, dtype=None):
        out = np.asarray(self.block().node.value)
        return out.astype(dtype) if dtype is not None else out

    def __float__(self) -> float:
        return float(np.asarray(self))

    def __int__(self) -> int:
        return int(np.asarray(self))

    def __bool__(self) -> bool:
        return bool(np.asarray(self))

    # ---- graph-building operators -----------------------------------------
    def _binary(self, op: str, other, reflected: bool = False) -> "LazyArray":
        a, b = lift(self), lift(other)
        if reflected:
            a, b = b, a
        return LazyArray(_elementwise_binary(op, a, b))

    def __add__(self, o):
        return self._binary("add", o)

    def __radd__(self, o):
        return self._binary("add", o, reflected=True)

    def __sub__(self, o):
        return self._binary("sub", o)

    def __rsub__(self, o):
        return self._binary("sub", o, reflected=True)

    def __mul__(self, o):
        return self._binary("mul", o)

    def __rmul__(self, o):
        return self._binary("mul", o, reflected=True)

    def __truediv__(self, o):
        return self._binary("div", o)

    def __rtruediv__(self, o):
        return self._binary("div", o, reflected=True)

    def __pow__(self, o):
        return self._binary("pow", o)

    def __neg__(self):
        return LazyArray(_elementwise_unary("neg", lift(self)))

    def __abs__(self):
        return LazyArray(_elementwise_unary("abs", lift(self)))

    def __matmul__(self, other) -> "LazyArray":
        return LazyArray(registry_node("matmul", (self, other), {}))

    def __rmatmul__(self, other) -> "LazyArray":
        return LazyArray(registry_node("matmul", (other, self), {}))

    # ---- shape ops ---------------------------------------------------------
    def reshape(self, *shape) -> "LazyArray":
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        n = self.size
        shape = tuple(int(d) for d in shape)
        if -1 in shape:
            rest = 1
            for d in shape:
                if d != -1:
                    rest *= d
            shape = tuple(n // rest if d == -1 else d for d in shape)
        node = Node(
            "reshape", (self.node,), {"shape": shape}, shape, self.dtype
        )
        return LazyArray(node)

    def transpose(self, *axes) -> "LazyArray":
        if len(axes) == 1 and isinstance(axes[0], (tuple, list)):
            axes = tuple(axes[0])
        if not axes:
            axes = tuple(reversed(range(self.ndim)))
        shape = tuple(self.shape[a] for a in axes)
        node = Node(
            "transpose", (self.node,), {"axes": axes}, shape, self.dtype
        )
        return LazyArray(node)

    @property
    def T(self) -> "LazyArray":
        return self.transpose()

    def astype(self, dtype) -> "LazyArray":
        node = Node(
            "astype", (self.node,), {"dtype": dtype}, self.shape, dtype
        )
        return LazyArray(node)

    # ---- reductions --------------------------------------------------------
    def sum(self, axis=None, keepdims: bool = False) -> "LazyArray":
        return LazyArray(_reduction("sum", self.node, axis, keepdims))

    def mean(self, axis=None, keepdims: bool = False) -> "LazyArray":
        return LazyArray(_reduction("mean", self.node, axis, keepdims))

    def max(self, axis=None, keepdims: bool = False) -> "LazyArray":
        return LazyArray(_reduction("max", self.node, axis, keepdims))

    def min(self, axis=None, keepdims: bool = False) -> "LazyArray":
        return LazyArray(_reduction("min", self.node, axis, keepdims))
