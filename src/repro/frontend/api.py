"""``repro.hnp`` — a lazy NumPy-like namespace over the offload registry.

The paper's user story, reproduced at graph granularity: write plain
array code, and the library underneath decides what runs where ::

    import repro.hnp as hnp

    x = hnp.array(x_np)
    h = hnp.tanh(x @ w1)          # nothing executes yet
    y = hnp.linear(h, w2, bias)   # ... the graph just grows
    out = hnp.asnumpy(y)          # whole graph lowers onto the cluster

Operations build a lazy expression graph (:mod:`repro.frontend.lazy`); the
scheduler (:mod:`repro.frontend.schedule`) lowers it onto ``dispatch()`` —
fusing elementwise epilogues, batching independent GEMMs and keeping
device-resident intermediates on device.

**Seam contract**: any op registered in :mod:`repro.core.dispatch` appears
here for free — ``hnp.gemm``, ``hnp.attention``, ``hnp.syrk`` ... are
generated from the registry via module ``__getattr__``, with shape/dtype
inferred abstractly from the op's host lowering.  Registering a new
``OffloadOp`` is the *only* step to make it graph-capturable.

Import-light by contract (see ``make collect``'s import-time gate): jax and
the offload seam are loaded lazily on first use, not at import.
"""

from __future__ import annotations

import builtins
from typing import Optional

import numpy as np

from repro.frontend.lazy import LazyArray, leaf, lift, registry_node
from repro.frontend.lazy import _elementwise_binary, _elementwise_unary
from repro.frontend.schedule import (
    GraphRegion,
    GraphReport,
    NodeReport,
    current_region,
    evaluate,
    evaluate_many,
    offload_region,
)

__all__ = [
    "GraphRegion",
    "GraphReport",
    "LazyArray",
    "NodeReport",
    "abs",
    "add",
    "array",
    "asarray",
    "asnumpy",
    "block",
    "block_all",
    "current_region",
    "divide",
    "exp",
    "gelu",
    "linear",
    "matmul",
    "max",
    "maximum",
    "mean",
    "min",
    "minimum",
    "multiply",
    "offload_region",
    "power",
    "relu",
    "sigmoid",
    "silu",
    "sqrt",
    "subtract",
    "sum",
    "tanh",
]


# ---------------------------------------------------------------------------
# Array construction / forcing
# ---------------------------------------------------------------------------

def array(obj, dtype=None, *, pin: bool = False) -> LazyArray:
    """Wrap a concrete array as a graph leaf.

    ``pin=True`` homes the buffer on a device up front (weights that many
    graph nodes will consume): the scheduler credits it as resident in every
    launch that touches it and placement-affine scheduling is drawn to it.
    """
    if isinstance(obj, LazyArray):
        return obj.astype(dtype) if dtype is not None else obj
    node = leaf(obj, dtype=dtype)
    if pin and node.dtype is not None:
        from repro.core.hero import engine

        node.attrs["handle"] = engine().pin_handle(
            f"hnp-leaf-{node.id}", node.nbytes
        )
    return LazyArray(node)


def asarray(obj, dtype=None) -> LazyArray:
    return array(obj, dtype=dtype)


def asnumpy(x) -> np.ndarray:
    """Force evaluation (lower the captured graph) and return a numpy array.

    ``LazyArray.__array__`` does the forcing, so plain ``np.asarray`` covers
    lazy and concrete inputs alike."""
    return np.asarray(x)


def block(x: LazyArray) -> LazyArray:
    """Force evaluation of a lazy array in place (returns it)."""
    if isinstance(x, LazyArray):
        return x.block()
    return x


def block_all(*arrays):
    """Force several lazy arrays in ONE scheduling pass (returns them).

    Independent expressions surface in the same topological waves, so
    same-shape GEMMs *across* the forced roots batch into a single
    ``gemm_batched`` launch and CSE-shared subgraphs run once — this is how
    the graph model forward forces a block's independent projections
    together (``models/forward.py``)."""
    evaluate_many([a.node for a in arrays if isinstance(a, LazyArray)])
    return arrays


# ---------------------------------------------------------------------------
# Linear algebra sugar (everything heavy goes through the registry)
# ---------------------------------------------------------------------------

def matmul(a, b, *, out_dtype=None) -> LazyArray:
    return LazyArray(registry_node("matmul", (a, b), {"out_dtype": out_dtype}))


def linear(x, w, b=None, *, out_dtype=None) -> LazyArray:
    """y = x @ w (+ b).  The bias add is an elementwise consumer of the
    matmul, so the scheduler fuses it into the GEMM launch."""
    y = matmul(x, w, out_dtype=out_dtype)
    if b is not None:
        y = y + (b if isinstance(b, LazyArray) else LazyArray(lift(b)))
    return y


# ---------------------------------------------------------------------------
# Elementwise / reductions
# ---------------------------------------------------------------------------

def _unary(op):
    def fn(x) -> LazyArray:
        return LazyArray(_elementwise_unary(op, lift(x)))

    fn.__name__ = op
    fn.__doc__ = f"Lazy elementwise {op} (fusible into its producer)."
    return fn


def _binary(op):
    def fn(a, b) -> LazyArray:
        return LazyArray(_elementwise_binary(op, lift(a), lift(b)))

    fn.__name__ = op
    fn.__doc__ = f"Lazy elementwise {op} (fusible into its producer)."
    return fn


tanh = _unary("tanh")
exp = _unary("exp")
sqrt = _unary("sqrt")
abs = _unary("abs")  # noqa: A001 — numpy-style namespace shadows builtins
relu = _unary("relu")
silu = _unary("silu")
gelu = _unary("gelu")
sigmoid = _unary("sigmoid")

add = _binary("add")
subtract = _binary("sub")
multiply = _binary("mul")
divide = _binary("div")
maximum = _binary("maximum")
minimum = _binary("minimum")
power = _binary("pow")


def _reduction(op):
    def fn(x, axis=None, keepdims: bool = False) -> LazyArray:
        return getattr(array(x), op)(axis=axis, keepdims=keepdims)

    fn.__name__ = op
    fn.__doc__ = f"Lazy {op} reduction."
    return fn


sum = _reduction("sum")  # noqa: A001 — numpy-style namespace shadows builtins
mean = _reduction("mean")
max = _reduction("max")  # noqa: A001
min = _reduction("min")  # noqa: A001


# ---------------------------------------------------------------------------
# Registry passthrough: every registered OffloadOp appears in hnp for free
# ---------------------------------------------------------------------------

def registry_ops() -> tuple:
    """Names of the registered ops reachable through this namespace."""
    import repro.core.blas  # noqa: F401 — populate the registry
    from repro.core.dispatch import registered_ops

    return registered_ops()


def __getattr__(name: str):
    """PEP-562 fallback: resolve unknown attributes against the op registry.

    ``hnp.<op>(*args, **kwargs)`` builds a heavy graph node for any
    registered :class:`~repro.core.dispatch.OffloadOp` — new descriptors
    appear here with zero frontend changes.
    """
    if name.startswith("_"):
        raise AttributeError(name)
    try:
        ops = registry_ops()
    except Exception as e:  # pragma: no cover — registry import failure
        raise AttributeError(f"{name} (registry unavailable: {e})") from None
    if name not in ops:
        raise AttributeError(
            f"module 'repro.hnp' has no attribute {name!r} "
            f"(registered ops: {', '.join(builtins.sorted(ops))})"
        )

    def op_fn(*args, **kwargs) -> LazyArray:
        return LazyArray(registry_node(name, args, kwargs))

    op_fn.__name__ = name
    op_fn.__qualname__ = name
    op_fn.__doc__ = (
        f"Lazy graph capture of registered offload op {name!r} "
        "(see repro.core.dispatch)."
    )
    globals()[name] = op_fn  # cache for subsequent lookups
    return op_fn
