"""Graph scheduler — lowers a lazy ``hnp`` expression graph onto the
offload registry.

Where eager ``repro.core.blas`` calls pay host<->device staging per op and
the cluster scheduler never sees more than one call ahead, this module sees
the *shape of the whole computation* (Pirova et al.) and exploits it:

* **topological waves** — independent ops surface together, so the cluster
  scheduler can spread them across lanes;
* **elementwise fusion** — a single-consumer elementwise chain (bias add,
  ``tanh``, ``silu`` ...) folds into its producer's lowering: no extra
  dispatch record, no staging for the chain's intermediates;
* **GEMM batching** — same-shape independent 2-D GEMMs in one wave stack
  into a single ``gemm_batched`` launch (one fork/join instead of N);
* **residency threading** — the key win: an intermediate produced on a
  device *stays* device-resident for its consumers instead of round-tripping
  through host DRAM.  Each heavy node dispatches with the exact fraction of
  its operand/result bytes already (or staying) on device, and cross-device
  consumption is charged over the d2d link (``migrate_handle``), riding the
  DMA stream in the overlap timeline.

Import-light by contract: jax and the offload seam are imported inside
functions (``make collect`` gates frontend import time).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.frontend.lazy import (
    ELEMENTWISE,
    Node,
    is_heavy,
    rebuild_call,
)
from repro.obs import spans as _obs

__all__ = [
    "GraphReport",
    "GraphRegion",
    "NodeReport",
    "current_region",
    "evaluate",
    "evaluate_many",
    "offload_region",
]

_REGION_IDS = itertools.count()

# Registry ops whose independent same-shape 2-D instances can stack into one
# gemm_batched launch.
_BATCHABLE = frozenset({"registry:matmul", "registry:gemm"})


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeReport:
    """Accounting view of one heavy (registry-dispatched) graph node."""

    node_id: int
    op: str
    backend: str
    device_id: int
    resident_fraction: float
    staged_in_bytes: float      # host->device bytes paid for operands
    readback_bytes: float       # device->host bytes paid for the result
    fused: Tuple[str, ...] = ()  # elementwise ops folded into this launch
    batched: bool = False        # member of a stacked gemm_batched launch


@dataclasses.dataclass
class GraphReport:
    """Rollup of every dispatch the scheduler issued for one graph scope."""

    name: str
    launches: List[NodeReport] = dataclasses.field(default_factory=list)
    # Nodes removed before scheduling: duplicate subtrees collapsed by
    # common-subexpression elimination plus the dead nodes only they fed.
    nodes_eliminated: int = 0
    # Bytes staged ahead of their consumer by cross-wave prefetch: wave k+1
    # operand copies issued while wave k computes, riding the DMA stream
    # under compute.  Not part of ``staged_bytes`` — the consumer's launch
    # takes the residency credit instead of paying the copy region.
    prefetched_bytes: float = 0.0

    @property
    def staged_in_bytes(self) -> float:
        return sum(r.staged_in_bytes for r in self.launches)

    @property
    def readback_bytes(self) -> float:
        return sum(r.readback_bytes for r in self.launches)

    @property
    def staged_bytes(self) -> float:
        return self.staged_in_bytes + self.readback_bytes

    @property
    def fused_ops(self) -> int:
        return sum(len(r.fused) for r in self.launches)

    @property
    def batched_launches(self) -> int:
        return sum(1 for r in self.launches if r.batched)

    def summary(self) -> str:
        s = (
            f"graph {self.name!r}: {len(self.launches)} launches, "
            f"{self.fused_ops} fused elementwise ops, "
            f"{self.batched_launches} batched GEMMs, "
            f"{self.nodes_eliminated} nodes CSE/DCE-eliminated, "
            f"staged_in={self.staged_in_bytes:.0f}B "
            f"readback={self.readback_bytes:.0f}B"
        )
        if self.prefetched_bytes > 0:
            s += f" prefetched={self.prefetched_bytes:.0f}B"
        return s


# ---------------------------------------------------------------------------
# Graph regions — scope residency + handle lifetimes over many evaluations
# ---------------------------------------------------------------------------

class GraphRegion:
    """Scope for one logical graph: shared residency map, owned handles,
    accumulated report.

    Used directly as the ``hnp.offload_region()`` context manager.  All
    evaluations inside share intermediate residency (an intermediate forced
    by one ``asnumpy`` stays device-resident for the next expression), and
    every handle the scheduler pinned is released when the region closes —
    the multi-op handle-lifetime contract on :class:`HeroCluster`.
    """

    def __init__(
        self, name: Optional[str] = None, *, validate: bool = False
    ) -> None:
        self.name = name or f"hnp-graph-{next(_REGION_IDS)}"
        self.residency: Dict[int, Any] = {}   # node id -> DeviceHandle
        self.owned: set = set()               # handle names we pinned
        self.report = GraphReport(self.name)
        # validate=True runs repro.analysis.graph over every graph forced
        # inside this region before anything dispatches
        self.validate = bool(validate)

    # -- residency ----------------------------------------------------------
    def handle_for(self, node: Node):
        """Valid residency handle for a node's value, if any (scheduler-owned
        intermediates, or user-pinned leaves via ``hnp.array(pin=True)``)."""
        h = self.residency.get(node.id)
        if h is None:
            h = node.attrs.get("handle")
        if h is not None and getattr(h, "valid", False):
            return h
        return None

    def pin(self, node: Node, device_id: int) -> None:
        from repro.core.hero import engine

        h = engine().pin_handle(
            f"{self.name}:n{node.id}", node.nbytes, device_id=device_id
        )
        self.residency[node.id] = h
        self.owned.add(h.name)

    def prefetch(self, node: Node, device_id: int) -> None:
        """Stage an evaluated operand onto ``device_id`` ahead of its
        consumer (cross-wave DMA prefetch).  The copy is charged now — on
        the lane's DMA stream, under the current wave's compute — and the
        owned handle carries the residency credit the consumer's launch
        picks up."""
        from repro.core.hero import engine

        h = engine().prefetch_stage(
            f"{self.name}:n{node.id}", node.nbytes, device_id=device_id
        )
        self.residency[node.id] = h
        self.owned.add(h.name)
        self.report.prefetched_bytes += node.nbytes

    def release(self) -> None:
        from repro.core.hero import engine

        eng = engine()
        for name in sorted(self.owned):
            h = eng.handle(name)
            if h is not None:
                eng.release_handle(h)
        self.owned.clear()
        self.residency.clear()

    # -- context manager ------------------------------------------------------
    def __enter__(self) -> "GraphRegion":
        _REGION_STACK.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _REGION_STACK.pop()
        self.release()


_REGION_STACK: List[GraphRegion] = []


def current_region() -> Optional[GraphRegion]:
    return _REGION_STACK[-1] if _REGION_STACK else None


# Public alias: ``with hnp.offload_region("step") as region: ...``
offload_region = GraphRegion


# ---------------------------------------------------------------------------
# Light-op lowering (elementwise / reductions / shape ops via jnp)
# ---------------------------------------------------------------------------

def _lower_light(op: str, attrs: Dict[str, Any], vals: Sequence[Any]):
    import jax
    import jax.numpy as jnp

    if op == "add":
        return vals[0] + vals[1]
    if op == "sub":
        return vals[0] - vals[1]
    if op == "mul":
        return vals[0] * vals[1]
    if op == "div":
        return vals[0] / vals[1]
    if op == "pow":
        return vals[0] ** vals[1]
    if op == "maximum":
        return jnp.maximum(vals[0], vals[1])
    if op == "minimum":
        return jnp.minimum(vals[0], vals[1])
    if op == "neg":
        return -vals[0]
    if op == "abs":
        return jnp.abs(vals[0])
    if op == "tanh":
        return jnp.tanh(vals[0])
    if op == "exp":
        return jnp.exp(vals[0])
    if op == "sqrt":
        return jnp.sqrt(vals[0])
    if op == "relu":
        return jax.nn.relu(vals[0])
    if op == "silu":
        return jax.nn.silu(vals[0])
    if op == "gelu":
        return jax.nn.gelu(vals[0])
    if op == "sigmoid":
        return jax.nn.sigmoid(vals[0])
    if op in ("sum", "mean", "max", "min"):
        fn = {"sum": jnp.sum, "mean": jnp.mean, "max": jnp.max,
              "min": jnp.min}[op]
        return fn(
            vals[0], axis=attrs.get("axis"),
            keepdims=bool(attrs.get("keepdims", False)),
        )
    if op == "reshape":
        return jnp.reshape(vals[0], attrs["shape"])
    if op == "transpose":
        return jnp.transpose(vals[0], attrs["axes"])
    if op == "astype":
        return jnp.asarray(vals[0]).astype(attrs["dtype"])
    raise NotImplementedError(f"no lowering for light op {op!r}")


# ---------------------------------------------------------------------------
# Fusion analysis
# ---------------------------------------------------------------------------

def _fusion_chains(
    order: List[Node],
    consumers: Dict[int, List[Node]],
) -> Tuple[Dict[int, List[Node]], Dict[int, int]]:
    """Maximal single-consumer elementwise chains hanging off heavy nodes.

    A node fuses into its producer's launch when it is elementwise, it is the
    producer's only consumer in the forced subgraph, and every *other*
    operand is already available (a leaf or previously-evaluated node — the
    bias-add case).  Returns ``(chains, fused_into)``: per-head fused chain
    in application order, and a membership map.
    """
    chains: Dict[int, List[Node]] = {}
    fused_into: Dict[int, int] = {}
    for head in order:
        if not is_heavy(head.op):
            continue
        chain: List[Node] = []
        tail = head
        while True:
            cs = consumers.get(tail.id, [])
            if len(cs) != 1:
                break
            e = cs[0]
            if e.op not in ELEMENTWISE or e.id in fused_into:
                break
            side = [i for i in e.inputs if i is not tail]
            if any(not s.evaluated for s in side):
                break
            chain.append(e)
            fused_into[e.id] = head.id
            tail = e
        if chain:
            chains[head.id] = chain
    return chains, fused_into


def _apply_chain(head_value, chain: List[Node], prev: Node):
    """Run a fused elementwise chain on the producer's value, caching each
    link's value (shared-subgraph coherence)."""
    value = head_value
    tail = prev
    for e in chain:
        vals = [value if i is tail else i.value for i in e.inputs]
        value = _lower_light(e.op, e.attrs, vals)
        e.set_value(value)
        tail = e
    return value


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------

def _collect(roots: Sequence[Node]) -> List[Node]:
    """Postorder over the unevaluated subgraph reachable from ``roots``."""
    order: List[Node] = []
    seen = set()
    stack: List[Tuple[Node, bool]] = [(r, False) for r in reversed(roots)]
    while stack:
        node, expanded = stack.pop()
        if node.id in seen:
            continue
        if node.evaluated:
            seen.add(node.id)
            continue
        if expanded:
            seen.add(node.id)
            order.append(node)
            continue
        stack.append((node, True))
        for inp in node.inputs:
            if not inp.evaluated and inp.id not in seen:
                stack.append((inp, False))
    return order


def _freeze(v):
    """Hashable view of a node-attrs value (best effort: repr fallback)."""
    if isinstance(v, (tuple, list)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    try:
        hash(v)
        return v
    except TypeError:
        return repr(v)


def _eliminate(
    order: List[Node], roots: Sequence[Node]
) -> Tuple[List[Node], List[Tuple[Node, Node]], int]:
    """Common-subexpression + dead-node elimination before scheduling.

    Structurally identical nodes — same op, same (representative) inputs,
    same static params — collapse onto their first occurrence; consumers
    are rewired to the representative.  Nodes made unreachable from the
    forced roots by the collapse (the duplicate subtrees) are dropped from
    the schedule entirely.  Returns ``(live_order, aliases, eliminated)``;
    each alias ``(dup, rep)`` has its value copied from ``rep`` after the
    schedule runs, so outside references to the duplicate stay valid.
    Leaves are identity-keyed (two equal-shaped arrays are not assumed
    equal); evaluated nodes are already values and never collapse.
    """
    rep: Dict[int, Node] = {}
    by_val: Dict[int, Node] = {}   # evaluated-node unification by buffer id
    seen: Dict[Any, Node] = {}
    aliases: List[Tuple[Node, Node]] = []

    def rep_of(i: Node) -> Node:
        r = rep.get(i.id)
        if r is not None:
            return r
        if i.evaluated:
            # Leaves (and pre-forced nodes) unify on the underlying buffer:
            # the same array lifted twice is the same graph input.
            return by_val.setdefault(id(i.value), i)
        return i

    for n in order:
        key = (
            n.op,
            tuple(rep_of(i).id for i in n.inputs),
            _freeze(n.attrs),
        )
        r = seen.get(key)
        if r is None:
            seen[key] = n
            rep[n.id] = n
        else:
            rep[n.id] = r
            aliases.append((n, r))
    if not aliases:
        return order, [], 0
    for n in order:
        n.inputs = tuple(rep_of(i) for i in n.inputs)
    # Dead-node elimination: only what the rewired roots still reach runs.
    live: set = set()
    stack = [rep.get(r.id, r) for r in roots]
    while stack:
        n = stack.pop()
        if n.id in live or n.evaluated:
            continue
        live.add(n.id)
        stack.extend(n.inputs)
    kept = [n for n in order if n.id in live]
    return kept, aliases, len(order) - len(kept)


def _array_inputs(node: Node) -> List[Node]:
    return [i for i in node.inputs if i.dtype is not None]


def _residency_split(node: Node, region: GraphRegion):
    """(resident_bytes, total_in_bytes, best_handle) over a node's operands."""
    resident = 0.0
    total = 0.0
    best = None
    best_bytes = -1.0
    for inp in _array_inputs(node):
        total += inp.nbytes
        h = region.handle_for(inp)
        if h is not None:
            resident += inp.nbytes
            if inp.nbytes > best_bytes:
                best, best_bytes = h, inp.nbytes
    return resident, total, best


def _migrate_inputs(node: Node, device_id: int, region: GraphRegion) -> None:
    """Bring scheduler-owned resident inputs to the consuming device.

    Charged as ``d2d_copy`` records on the destination's DMA stream — the
    modeled price of consuming an intermediate on a different lane than the
    one that produced it.  User-pinned leaves are never moved (their home is
    the user's contract); affinity scheduling is what keeps work near them.
    """
    from repro.core.hero import engine

    for inp in _array_inputs(node):
        h = region.handle_for(inp)
        if (
            h is not None
            and h.device_id != device_id
            and h.name in region.owned
        ):
            engine().migrate_handle(h, device_id)


def _run_heavy(
    node: Node,
    chains: Dict[int, List[Node]],
    roots: set,
    region: GraphRegion,
) -> None:
    """Dispatch one heavy node (plus its fused chain) through the registry."""
    from repro.core.dispatch import dispatch_placed

    chain = chains.get(node.id, [])
    tail = chain[-1] if chain else node
    vals = [i.value for i in node.inputs]
    resident_in, in_total, aff = _residency_split(node, region)
    out_nbytes = tail.nbytes
    keep_out = tail.id not in roots
    total = in_total + out_nbytes
    rf = ((resident_in + (out_nbytes if keep_out else 0.0)) / total
          if total > 0 else 0.0)

    args, kwargs = rebuild_call(node, vals)
    opname = node.op.split(":", 1)[1]
    value, launch = dispatch_placed(
        opname, *args, resident_fraction=rf, handle=aff, **kwargs
    )
    node.set_value(value)
    offloaded = launch.backend.startswith("device")
    if offloaded:
        _migrate_inputs(node, launch.device_id, region)
    final = _apply_chain(value, chain, node) if chain else value
    tail.set_value(final)
    if offloaded:
        # Forcing a root reads a *copy* back to host — the device buffer
        # stays valid for later consumers in the same region, so pin
        # unconditionally (rf already excluded the root's readback bytes).
        region.pin(tail, launch.device_id)
    region.report.launches.append(NodeReport(
        node_id=node.id,
        op=opname,
        backend=launch.backend,
        device_id=launch.device_id,
        resident_fraction=rf,
        staged_in_bytes=(in_total - resident_in) if offloaded else 0.0,
        readback_bytes=out_nbytes if (offloaded and not keep_out) else 0.0,
        fused=tuple(e.op for e in chain),
        batched=False,
    ))


def _batch_key(node: Node):
    """Stacking key for independent same-shape 2-D GEMMs (None = unbatchable)."""
    if node.op not in _BATCHABLE or len(node.inputs) != 2:
        return None
    if node.attrs["kw_inputs"]:
        return None
    if any(kind != "in" for kind, _ in node.attrs["template"]):
        return None
    if any(bool(v) for v in node.attrs["kwargs"].values()):
        return None  # transposes / tp_mode / explicit out_dtype opt out
    a, b = node.inputs
    if a.ndim != 2 or b.ndim != 2:
        return None
    return (a.shape, b.shape, str(a.dtype), str(b.dtype))


def _run_batched(
    members: List[Node],
    chains: Dict[int, List[Node]],
    roots: set,
    region: GraphRegion,
) -> None:
    """Stack N independent same-shape GEMMs into one gemm_batched launch."""
    import jax.numpy as jnp

    from repro.core.dispatch import dispatch_placed

    resident_in = in_total = out_total = keep_bytes = 0.0
    aff = None
    aff_bytes = -1.0
    tails = []
    splits = []
    for n in members:
        chain = chains.get(n.id, [])
        tail = chain[-1] if chain else n
        tails.append(tail)
        r, t, h = _residency_split(n, region)
        splits.append((r, t))
        resident_in += r
        in_total += t
        out_total += tail.nbytes
        if tail.id not in roots:
            keep_bytes += tail.nbytes
        if h is not None and h.nbytes > aff_bytes:
            aff, aff_bytes = h, h.nbytes
    total = in_total + out_total
    rf = (resident_in + keep_bytes) / total if total > 0 else 0.0

    a_stack = jnp.stack([jnp.asarray(n.inputs[0].value) for n in members])
    b_stack = jnp.stack([jnp.asarray(n.inputs[1].value) for n in members])
    out, launch = dispatch_placed(
        "gemm_batched", a_stack, b_stack, resident_fraction=rf, handle=aff
    )
    offloaded = launch.backend.startswith("device")
    for i, (n, tail) in enumerate(zip(members, tails)):
        chain = chains.get(n.id, [])
        value = out[i]
        n.set_value(value)
        if offloaded:
            _migrate_inputs(n, launch.device_id, region)
        final = _apply_chain(value, chain, n) if chain else value
        tail.set_value(final)
        keep = tail.id not in roots
        if offloaded:
            region.pin(tail, launch.device_id)
        r, t = splits[i]
        region.report.launches.append(NodeReport(
            node_id=n.id,
            op=n.op.split(":", 1)[1],
            backend=launch.backend,
            device_id=launch.device_id,
            resident_fraction=rf,
            staged_in_bytes=(t - r) if offloaded else 0.0,
            readback_bytes=tail.nbytes if (offloaded and not keep) else 0.0,
            fused=tuple(e.op for e in chain),
            batched=True,
        ))


def _run_light_node(node: Node, region: GraphRegion) -> None:
    """Evaluate a light node; inherit device residency when all its array
    operands already live on one device (the elementwise runs there, so its
    result does too — a free pin, no staging charged either way, matching
    the unmodeled ``jnp`` elementwise ops of the eager path)."""
    vals = [i.value for i in node.inputs]
    value = _lower_light(node.op, node.attrs, vals)
    node.set_value(value)
    arrays = _array_inputs(node)
    if not arrays:
        return
    handles = [region.handle_for(i) for i in arrays]
    devs = {h.device_id for h in handles if h is not None}
    if len(devs) == 1 and all(h is not None for h in handles):
        region.pin(node, devs.pop())


def evaluate(root: Node):
    """Force one graph root: lower the whole captured subgraph onto the
    offload registry and return the root's value.

    Runs inside the ambient :class:`GraphRegion` if one is open (sharing
    residency and handle lifetimes with sibling evaluations), else under an
    ephemeral region whose intermediate handles are released on return.
    """
    return evaluate_many([root])[0]


def evaluate_many(roots: Sequence[Node]):
    """Force several graph roots in ONE scheduling pass.

    Independent roots surface in the same topological waves, so same-shape
    GEMMs *across* roots batch into one ``gemm_batched`` launch and shared
    subgraphs (post-CSE) run once — the multi-output form of
    :func:`evaluate` (``hnp.block_all``).
    """
    pending = [r for r in roots if not r.evaluated]
    if pending:
        from repro.core import accounting

        region = current_region()
        ephemeral = region is None
        if ephemeral:
            region = GraphRegion()
        try:
            with accounting.graph_region(region.name):
                _schedule(pending, region)
        finally:
            if ephemeral:
                region.release()
    return [r.value for r in roots]


def _prefetch_next_wave(
    next_ids: List[int], by_id: Dict[int, Node], region: GraphRegion
) -> None:
    """Issue wave k+1's staging while wave k's compute is still in flight.

    For each heavy node in the upcoming wave that already has a device
    affinity (some operand resident on a lane), stage its *other* evaluated,
    unresident array operands onto that lane now.  The copies land on the
    DMA stream behind the current wave's launches — i.e. under compute —
    and the consumer's ``resident_fraction`` then credits them.  Opt-in via
    ``OffloadPolicy.prefetch_staging``.
    """
    from repro.core.hero import engine

    eng = engine()
    pol = eng.policy
    if not pol.prefetch_staging or pol.mode == "host":
        return
    for nid in sorted(next_ids):
        n = by_id.get(nid)
        if n is None or n.evaluated or not is_heavy(n.op):
            continue
        dev = None
        for inp in _array_inputs(n):
            h = region.handle_for(inp)
            if h is not None:
                dev = h.device_id
                break
        if dev is None:
            continue  # no affinity yet — placement unknown, don't guess
        for inp in _array_inputs(n):
            if not inp.evaluated or inp.nbytes <= 0:
                continue  # in-flight intermediates ride residency threading
            if region.handle_for(inp) is not None:
                continue  # already device-resident
            region.prefetch(inp, dev)


def _schedule(roots: Sequence[Node], region: GraphRegion) -> None:
    if getattr(region, "validate", False):
        from repro.analysis.graph import assert_valid

        assert_valid(roots, region)
    order = _collect(roots)
    if not order:
        return
    order, aliases, eliminated = _eliminate(order, roots)
    region.report.nodes_eliminated += eliminated
    in_graph = {n.id for n in order}
    consumers: Dict[int, List[Node]] = {}
    deps: Dict[int, int] = {}
    for n in order:
        cnt = 0
        for i in n.inputs:
            if i.id in in_graph and not i.evaluated:
                consumers.setdefault(i.id, []).append(n)
                cnt += 1
        deps[n.id] = cnt
    chains, fused_into = _fusion_chains(order, consumers)
    alias_of = {d.id: r for d, r in aliases}
    root_ids = {alias_of.get(r.id, r).id for r in roots}

    by_id = {n.id: n for n in order}
    ready = sorted(
        (nid for nid, c in deps.items() if c == 0), key=lambda i: i
    )
    done = set()

    def complete(n: Node, frontier: List[int]) -> None:
        done.add(n.id)
        for c in consumers.get(n.id, []):
            deps[c.id] -= 1
            if deps[c.id] == 0:
                frontier.append(c.id)

    tr = _obs.current_tracer()
    graph_span = None
    if tr is not None:
        graph_span = tr.begin(
            f"graph:{region.name}", cat="graph", lane="host",
            t0=_obs.modeled_now(),
            attrs={"nodes": len(order), "eliminated": eliminated,
                   "fused_chains": len(chains)},
        )
    wave_idx = 0
    while ready:
        wave = [by_id[i] for i in sorted(ready)]
        wave_span = None
        if tr is not None:
            wave_span = tr.begin(
                f"wave{wave_idx}", cat="graph", lane="host",
                t0=_obs.modeled_now(), attrs={"nodes": len(wave)},
            )
            wave_idx += 1
        ready = []
        # nodes fused into an earlier head arrive here already evaluated
        pending_heavy: List[Node] = []
        for n in wave:
            if n.evaluated:
                complete(n, ready)
            elif is_heavy(n.op):
                pending_heavy.append(n)
            else:
                _run_light_node(n, region)
                complete(n, ready)
        # batch same-shape independent GEMMs; dispatch the rest singly
        groups: Dict[Any, List[Node]] = {}
        singles: List[Node] = []
        for n in pending_heavy:
            key = _batch_key(n)
            if key is None:
                singles.append(n)
            else:
                groups.setdefault(key, []).append(n)
        for key, members in groups.items():
            if len(members) < 2:
                singles.extend(members)
        for n in sorted(singles, key=lambda n: n.id):
            if tr is not None and chains.get(n.id):
                tr.instant("fuse", cat="graph", lane="host",
                           t=_obs.modeled_now(),
                           attrs={"head": n.op,
                                  "fused": len(chains[n.id]) + 1})
            _run_heavy(n, chains, root_ids, region)
            complete(n, ready)
        for key, members in groups.items():
            if len(members) >= 2:
                members = sorted(members, key=lambda n: n.id)
                if tr is not None:
                    tr.instant("gemm-batch", cat="graph", lane="host",
                               t=_obs.modeled_now(),
                               attrs={"members": len(members)})
                _run_batched(members, chains, root_ids, region)
                for n in members:
                    complete(n, ready)
        # wave k just dispatched; `ready` is wave k+1 — issue its staging
        # now so the copies shingle under wave k's modeled compute
        if ready:
            _prefetch_next_wave(ready, by_id, region)
        if tr is not None:
            tr.end(wave_span, _obs.modeled_now())
    if tr is not None:
        tr.end(graph_span, _obs.modeled_now())

    leftover = [n for n in order if n.id not in done and not n.evaluated]
    if leftover:  # cycles cannot happen by construction; guard anyway
        raise RuntimeError(f"scheduler failed to evaluate nodes: {leftover}")
    # CSE aliases: outside references to a collapsed duplicate stay valid —
    # it carries its representative's value without ever launching.
    for dup, rep in aliases:
        if not dup.evaluated and rep.evaluated:
            dup.set_value(rep.value)
