"""repro.frontend — graph capture layer between user code and the cluster.

``repro.hnp`` (the public face of this package) is a lazy NumPy-like
namespace: operations build an expression graph instead of executing, and a
graph scheduler lowers whole graphs onto the declarative offload registry —
fusing elementwise chains, batching independent GEMMs across cluster lanes,
and keeping device-resident intermediates on device.

Modules (all import-light; jax loads lazily at first use):
  lazy      — LazyArray + expression-graph nodes
  schedule  — the graph scheduler / registry lowering + GraphRegion scoping
  api       — the hnp namespace (re-exported as ``repro.hnp``)
"""

from repro.frontend.lazy import LazyArray, Node  # noqa: F401
from repro.frontend.schedule import (  # noqa: F401
    GraphRegion,
    GraphReport,
    NodeReport,
    evaluate,
    offload_region,
)

__all__ = [
    "GraphRegion",
    "GraphReport",
    "LazyArray",
    "Node",
    "NodeReport",
    "evaluate",
    "offload_region",
]
