"""repro — heterogeneous BLAS-offload substrate for JAX/TPU.

Reproduction + framework-scale extension of:
  "Work-In-Progress: Accelerating Numpy With OpenBLAS For Open-Source
   RISC-V Chips" (ETH Zurich / UniBo, 2025).

Public surface:
  repro.core      — BLAS seam, offload engine, cost model, accounting
  repro.kernels   — Pallas TPU device kernels (+ jnp oracles)
  repro.models    — composable model zoo (all matmuls through the seam)
  repro.configs   — assigned architecture configs
  repro.sharding  — logical-axis partitioning rules
  repro.launch    — mesh / dryrun / train / serve entry points
"""

__version__ = "0.1.0"
