"""Jamba 1.5 Large (398B, hybrid Mamba+attention 1:7, MoE 16e top-2).

[arXiv:2403.19887 / 2408.12570; hf:ai21labs/AI21-Jamba-1.5-Large]
72 layers = 9 super-blocks of 8; attention at in-block offset 4 (1:7 ratio);
MoE FFN every 2nd layer (16 experts, top-2).  GQA 64 q heads / 8 kv heads.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        num_experts=16,
        experts_per_token=2,
        moe_d_ff=24576,
        moe_layer_period=2,
        attn_layer_period=8,
        attn_layer_offset=4,
        ssm_state_dim=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        ssm_num_groups=8,
        rope_theta=1.0e6,  # attn layers are NoPE in Jamba; RoPE kept for zoo uniformity
        fsdp=True,
        num_microbatches=8,
        optimizer="adamw8bit",
    )
)
