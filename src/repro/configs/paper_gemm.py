"""The paper's own 'architecture': a bare float64 GEMM workload.

Used by the paper-reproduction benchmarks (Fig. 3) and the quickstart —
not part of the assigned 10-arch pool, so it is registered under
``paper-gemm`` for the offload benchmarks only.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

# Problem sizes the paper sweeps in Figure 3.
PAPER_SIZES = (16, 32, 64, 128)
PAPER_DTYPE = "float64"

CONFIG = register(
    ArchConfig(
        name="paper-gemm",
        family="dense",
        num_layers=1,
        d_model=128,
        num_heads=1,
        num_kv_heads=1,
        head_dim=128,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        num_microbatches=1,
    )
)
