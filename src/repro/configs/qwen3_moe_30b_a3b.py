"""Qwen3-30B-A3B (MoE: 128 experts, top-8, 3B active). [hf:Qwen/Qwen3-30B-A3B]

48 layers, d_model 2048, GQA 32/4, expert FFN width 768, vocab 151936.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,  # qwen3 uses head_dim 128 (not d_model/heads)
        d_ff=768,
        moe_d_ff=768,
        vocab_size=151936,
        num_experts=128,
        experts_per_token=8,
        moe_layer_period=1,
        rope_theta=1.0e6,
        num_microbatches=4,
    )
)
