"""Architecture config schema + input-shape definitions for all assigned cells."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

GLOBAL_WINDOW = 1 << 30  # "window" value meaning full attention


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture. All fields static; models are built purely from this."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attn-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0              # expert hidden width (0 -> d_ff)
    moe_layer_period: int = 1      # every k-th layer is MoE (jamba: 2)
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # "auto": shard_map explicit-collective dispatch when a compatible mesh
    # is ambient (minimal EP all-to-all volume), falling back to "grouped".
    # "grouped": group-local GSPMD dispatch. "global": mesh-wide sort (the
    # naive baseline, kept for §Perf comparison).
    moe_dispatch: str = "auto"
    dispatch_groups: int = 16      # = data-axis size on the production mesh

    # --- attention ---------------------------------------------------------
    causal: bool = True            # False for encoder-only (hubert)
    sliding_window: int = 0        # uniform SWA window (danube); 0 = none
    local_global_period: int = 0   # gemma3: 6 -> 5 local + 1 global per period
    local_window: int = 0          # gemma3 local window
    qkv_bias: bool = False         # qwen2 / qwen2-vl
    rope_theta: float = 1.0e6
    local_rope_theta: float = 0.0  # gemma3 local layers use a different theta
    mrope: bool = False            # qwen2-vl M-RoPE (3 position streams)

    # --- hybrid (jamba) ----------------------------------------------------
    attn_layer_period: int = 0     # jamba: 8
    attn_layer_offset: int = 0     # jamba: attn at layer i % period == offset

    # --- SSM (mamba2 / jamba mamba layers) ----------------------------------
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 64
    ssm_num_groups: int = 1

    # --- misc ---------------------------------------------------------------
    # "eager": every block op dispatches through the seam one call at a
    # time.  "graph": block forwards are captured as lazy `hnp` expression
    # graphs (models/forward.py) — elementwise epilogues fuse into their
    # producer launches, independent same-shape projections batch into one
    # gemm_batched, and intermediates stay device-resident across the block.
    forward_mode: str = "eager"    # eager | graph
    mlp_kind: str = "swiglu"       # swiglu | gelu
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1.0e-6
    tie_embeddings: bool = False
    embed_inputs: bool = True      # False: input_specs provides embeddings (audio/vlm stub frontend)
    dtype: str = "bfloat16"
    # training memory knobs (used by launch/steps)
    num_microbatches: int = 4
    accum_dtype: str = "float32"   # gradient-accumulation dtype
    optimizer: str = "adamw"       # adamw | adamw8bit (blockwise int8 moments)
    remat: bool = True
    fsdp: bool = False             # ZeRO-3: shard params/moments over 'data' too
    zero1: bool = False            # ZeRO-1: shard only optimizer moments over 'data'

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # --- derived ------------------------------------------------------------
    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def attn_free(self) -> bool:
        return self.num_heads == 0

    @property
    def subquadratic(self) -> bool:
        """Supports the 524k long-context decode cell (see DESIGN.md §5)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0 and self.local_global_period == 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state_dim else 0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'mamba' for layer i."""
        if self.family == "ssm":
            return "mamba"
        if self.attn_layer_period:
            return "attn" if i % self.attn_layer_period == self.attn_layer_offset else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        return bool(self.num_experts) and (i % self.moe_layer_period == self.moe_layer_period - 1)

    def layer_window(self, i: int, seq_len: int) -> int:
        """Effective attention window for layer i (GLOBAL_WINDOW = full)."""
        if self.local_global_period:
            return self.local_window if (i % self.local_global_period) < (self.local_global_period - 1) else GLOBAL_WINDOW
        if self.sliding_window:
            return self.sliding_window
        return GLOBAL_WINDOW

    def layer_rope_theta(self, i: int) -> float:
        if self.local_global_period and self.local_rope_theta:
            is_local = (i % self.local_global_period) < (self.local_global_period - 1)
            return self.local_rope_theta if is_local else self.rope_theta
        return self.rope_theta

    @property
    def uniform_stack(self) -> bool:
        """True if every layer has the same pytree structure (scan over L)."""
        if self.family == "hybrid":
            return False
        if self.num_experts and self.moe_layer_period != 1:
            return False
        return True

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        n = 0
        if self.embed_inputs:
            n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for i in range(self.num_layers):
            if self.layer_kind(i) == "attn":
                hd = self.head_dim
                n += d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads
                n += hd * self.num_heads * d
            else:
                di, g, ns = self.d_inner, self.ssm_num_groups, self.ssm_state_dim
                n += d * (2 * di + 2 * g * ns + self.ssm_num_heads) + di * d
            if self.family == "ssm":
                continue  # mamba2: mixer only
            if self.layer_is_moe(i):
                mult = 3 if self.mlp_kind == "swiglu" else 2
                n += self.num_experts * mult * d * self.moe_d_ff + d * self.num_experts
                if self.dense_residual:
                    n += (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
            else:
                n += (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only) — for 6ND."""
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2) if self.embed_inputs else 0
        for i in range(self.num_layers):
            if self.layer_kind(i) == "attn":
                hd = self.head_dim
                n += d * hd * (self.num_heads + 2 * self.num_kv_heads) + hd * self.num_heads * d
            else:
                di, g, ns = self.d_inner, self.ssm_num_groups, self.ssm_state_dim
                n += d * (2 * di + 2 * g * ns + self.ssm_num_heads) + di * d
            if self.family == "ssm":
                continue
            mult = 3 if self.mlp_kind == "swiglu" else 2
            if self.layer_is_moe(i):
                n += self.experts_per_token * mult * d * self.moe_d_ff + d * self.num_experts
                if self.dense_residual:
                    n += mult * d * self.d_ff
            else:
                n += mult * d * self.d_ff
        return n

    # --- reduced config for CPU smoke tests ---------------------------------
    def reduced(self) -> "ArchConfig":
        """Same family/topology, tiny dims — one forward/train step on CPU."""
        period = max(self.attn_layer_period, self.local_global_period,
                     self.moe_layer_period, 1)
        layers = max(2, min(2 * period, 8 if period == 1 else 2 * period))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_heads else 0,
            head_dim=16 if self.num_heads else 0,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=96 if self.num_experts else 0,
            dispatch_groups=2,
            ssm_state_dim=16 if self.ssm_state_dim else 0,
            ssm_head_dim=16,
            ssm_chunk=8,
            sliding_window=8 if self.sliding_window else 0,
            local_window=8 if self.local_window else 0,
            num_microbatches=1,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) — the DESIGN.md §5 applicability matrix."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "524k decode needs sub-quadratic attention (full-attention arch)"
    if shape.name == "long_500k" and cfg.local_global_period:
        return False, "global layers are full attention; arch context capped at 128k"
    return True, ""
