"""H2O-Danube 1.8B (llama+mistral mix, sliding-window attention).

[arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base]
24 layers, d_model 2560, GQA 32/8, SWA window 4096 — the rolling KV cache
makes the 524k long-context decode cell runnable (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="h2o-danube-1.8b",
        family="dense",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        vocab_size=32000,
        sliding_window=4096,
        rope_theta=1.0e4,
        num_microbatches=2,
    )
)
