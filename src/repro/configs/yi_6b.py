"""Yi-6B (llama-arch dense, GQA 32/4). [arXiv:2403.04652; hf:01-ai/Yi-6B]"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="yi-6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5.0e6,
        num_microbatches=2,
    )
)
