"""Qwen2-VL 72B (VLM: qwen2-72b backbone + M-RoPE). [arXiv:2409.12191]

Backbone identical to qwen2-72b; positions arrive as 3 streams (temporal /
height / width) for multimodal RoPE.  The ViT frontend is a stub:
``input_specs`` provides precomputed patch+text embeddings (B, S, 8192)
plus the (3, B, S) position tensor.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        mrope=True,
        embed_inputs=False,
        rope_theta=1.0e6,
        zero1=True,
        num_microbatches=8,
    )
)
