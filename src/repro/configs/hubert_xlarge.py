"""HuBERT X-Large (encoder-only audio transformer). [arXiv:2106.07447]

48 layers, d_model 1280, 16 heads (MHA), d_ff 5120, GELU + LayerNorm,
bidirectional.  The conv waveform frontend is a stub: ``input_specs``
provides precomputed frame embeddings (B, S, 1280); the 504-unit head
predicts masked-frame cluster ids.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        mlp_kind="gelu",
        norm_kind="layernorm",
        embed_inputs=False,
        rope_theta=1.0e4,
        num_microbatches=2,
    )
)
