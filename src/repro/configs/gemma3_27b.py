"""Gemma-3 27B (dense, 5:1 local:global attention, 128k context).

[hf:google/gemma-3-27b-it — per-layer pattern from the gemma3 family card]
62 layers, d_model 5376, GQA 32/16, local window 1024, local RoPE theta 10k
vs global 1M.  Layer i is local iff i % 6 < 5 — expressed as per-layer
window/theta *data* scanned with the (uniform) stack.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="gemma3-27b",
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,  # gemma3 uses 128 irrespective of d_model/heads
        d_ff=21504,
        vocab_size=262144,
        local_global_period=6,
        local_window=1024,
        rope_theta=1.0e6,
        local_rope_theta=1.0e4,
        tie_embeddings=True,
        num_microbatches=4,
    )
)
