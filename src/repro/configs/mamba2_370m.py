"""Mamba-2 370M (attention-free SSM, SSD form). [arXiv:2405.21060]

48 layers, d_model 1024, state dim 128, head dim 64 (32 heads at expand=2),
vocab 50280.  SSD = chunked matmuls — the best GEMM-offload fit in the pool.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state_dim=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        ssm_num_groups=1,
        tie_embeddings=True,
        num_microbatches=1,
    )
)
