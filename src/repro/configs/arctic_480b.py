"""Snowflake Arctic (480B, dense-MoE hybrid: 128 experts top-2 + dense residual).

[hf:Snowflake/snowflake-arctic-base]
35 layers, d_model 7168, GQA 56/8, expert FFN 4864, dense residual FFN in
parallel with the MoE path every layer.
"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        moe_d_ff=4864,
        vocab_size=32000,
        num_experts=128,
        experts_per_token=2,
        moe_layer_period=1,
        dense_residual=True,
        rope_theta=1.0e6,
        fsdp=True,
        num_microbatches=8,
        optimizer="adamw8bit",
    )
)
