"""repro.configs — assigned architecture configs + registry."""

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ArchConfig,
    ShapeConfig,
    shape_applicable,
)
from repro.configs.registry import get_arch, list_archs

__all__ = [
    "ALL_SHAPES",
    "DECODE_32K",
    "LONG_500K",
    "PREFILL_32K",
    "TRAIN_4K",
    "ArchConfig",
    "ShapeConfig",
    "shape_applicable",
    "get_arch",
    "list_archs",
]
