"""Qwen2-72B (dense, GQA 64/8, QKV bias). [arXiv:2407.10671; hf:Qwen/Qwen2-72B]"""

from repro.configs.base import ArchConfig
from repro.configs.registry import register

CONFIG = register(
    ArchConfig(
        name="qwen2-72b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1.0e6,
        zero1=True,
        num_microbatches=8,
    )
)
