"""Architecture registry: --arch <id> resolution for every launch entry point."""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    # import for side effect of register()
    from repro.configs import (  # noqa: F401
        arctic_480b,
        gemma3_27b,
        h2o_danube_1_8b,
        hubert_xlarge,
        jamba_1_5_large_398b,
        mamba2_370m,
        paper_gemm,
        qwen2_72b,
        qwen2_vl_72b,
        qwen3_moe_30b_a3b,
        yi_6b,
    )

    _LOADED = True
