"""Graph-captured block forwards — the model zoo on lazy ``hnp`` graphs.

``cfg.forward_mode = "graph"`` routes every transformer block through this
module instead of the eager per-op seam calls.  Each block's forward is
built as one ``repro.hnp`` expression graph inside an
``hnp.offload_region()``, so the graph scheduler — not the call order —
decides the launches:

* independent same-shape projections in one wave **batch** into a single
  ``gemm_batched`` launch (Mamba's z/x and B/C projection pairs);
* elementwise epilogues (RMSNorm scale, SiLU/gate, residual adds) **fuse**
  into their producer's launch — no extra dispatch record, no staging for
  the chain's intermediates;
* attention/SSM intermediates **stay device-resident** across the block:
  each launch carries its exact ``resident_fraction``, so a qkv projection
  consumed by the attention launch on the same device never pays the
  host<->device staging region.

Everything heavy dispatches through the same registered ``OffloadOp``
descriptors as the eager path (``qkv_project``, ``attention``, ``ssd_scan``,
``mlp_block``, ``moe_expert_ffn``, ``matmul``, ``rmsnorm_scale``), so eager
and graph forwards are numerically identical per backend — the parity
switch is exercised across host / device / pallas-interpret in
``tests/test_models.py``.  Light glue the lazy frontend cannot express
(RoPE trig, the depthwise conv, MoE sort/scatter routing) runs eagerly
between forces; an ``offload_region`` shares residency across those forces.

Works inside ``jax.jit``/``lax.scan`` tracing: forcing uses ``.block()``
(never a host ``np.asarray``), so graph values may be tracers — dispatch
and accounting happen at trace time exactly as for eager seam calls.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = [
    "LAST_REPORTS",
    "capture_reports",
    "graph_block",
    "graph_ffn",
]

# GraphReports of recently captured blocks, appended at capture time (once
# per traced block).  Benchmarks and tests read fusion / batching / staging
# off these; ``capture_reports()`` scopes and clears the list.  Outside a
# capture scope only the most recent reports are kept, so a long-running
# graph-mode process (serving loop) does not accumulate them unboundedly.
LAST_REPORTS: List[Any] = []
_MAX_REPORTS = 64
_CAPTURING = False


def _record_report(report) -> None:
    LAST_REPORTS.append(report)
    if not _CAPTURING and len(LAST_REPORTS) > _MAX_REPORTS:
        del LAST_REPORTS[: -_MAX_REPORTS]


@contextlib.contextmanager
def capture_reports():
    """Collect the GraphReports of every block captured inside the scope."""
    global _CAPTURING
    LAST_REPORTS.clear()
    _CAPTURING = True
    try:
        yield LAST_REPORTS
    finally:
        _CAPTURING = False


def _hnp():
    import repro.hnp as hnp  # lazy: keep models import-light of the frontend

    return hnp


def _force(x):
    """Force a LazyArray in place and return its (possibly tracer) value."""
    return x.block().node.value if hasattr(x, "block") else x


def _graph_norm(xa, p, cfg, kind: str):
    """Norm as a graph node: RMSNorm is the registered ``rmsnorm_scale``
    descriptor (one recorded host launch, graph-capturable); LayerNorm
    (audio encoder only) runs eagerly between forces."""
    hnp = _hnp()
    if kind == "rmsnorm":
        return hnp.rmsnorm_scale(xa, p["scale"], eps=cfg.norm_eps)
    return hnp.array(L.layer_norm(_force(xa), p, cfg.norm_eps))


def _graph_attention(p, h, shape, cfg, positions, window, rope_theta):
    """QKV projection -> RoPE (eager trig) -> attention -> out projection."""
    hnp = _hnp()
    from repro.models.attention import split_qkv

    b, s, _ = shape
    hq, hd = cfg.num_heads, cfg.head_dim
    qkv = hnp.qkv_project(
        h, p["wq"], p["wk"], p["wv"],
        bq=p.get("bq"), bk=p.get("bk"), bv=p.get("bv"),
    )
    q, k, v = split_qkv(_force(qkv), cfg)  # resident for the region
    rope_theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if cfg.mrope:
        q = L.mrope(q, positions, rope_theta)
        k = L.mrope(k, positions, rope_theta)
    else:
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = L.rope(q, pos2d, rope_theta)
        k = L.rope(k, pos2d, rope_theta)
    out = hnp.attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=cfg.causal, window=window,
    )
    o2 = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return hnp.matmul(o2, p["wo"])


def _graph_mamba(p, h, shape, cfg, out_dtype):
    """Projections (z/x and B/C pairs batch into gemm_batched) -> conv
    (eager) -> ``ssd_scan`` with the SiLU gate fused into its launch ->
    gated-norm -> out projection."""
    hnp = _hnp()
    from repro.models.ssm import _causal_conv, ssd_inputs

    b, s, d = shape
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    h2 = h.reshape(b * s, d)
    za = hnp.matmul(h2, p["wz"])       # same shape as wx -> one gemm_batched
    xa = hnp.matmul(h2, p["wx"])
    ba = hnp.matmul(h2, p["wb"])       # same shape as wc -> one gemm_batched
    ca = hnp.matmul(h2, p["wc"])
    dta = hnp.matmul(h2, p["wdt"], out_dtype=jnp.float32)
    hnp.block_all(za, xa, ba, ca, dta)  # one wave: independent GEMMs batch

    def val3(t):
        return _force(t).reshape(b, s, -1)

    z, xin, b_, c_, dt = val3(za), val3(xa), val3(ba), val3(ca), val3(dta)
    conv_in = jnp.concatenate([xin, b_, c_], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    )
    xin = conv_out[..., : cfg.d_inner]
    b_ = conv_out[..., cfg.d_inner : cfg.d_inner + g * n]
    c_ = conv_out[..., cfg.d_inner + g * n :]
    xh, dt_f, a, bh_, ch_ = ssd_inputs(p, xin, b_, c_, dt, cfg)

    ya = hnp.ssd_scan(
        hnp.array(xh), dt_f, a, bh_, ch_, p["d_skip"], chunk=cfg.ssm_chunk
    )
    gate = jax.nn.silu(z.astype(jnp.float32))
    hp = (cfg.ssm_num_heads, cfg.ssm_head_dim)
    ya = ya * hnp.array(gate.reshape(b, s, *hp))  # fuses into the ssd launch
    yn = ya.reshape(b, s, cfg.d_inner).astype(out_dtype)
    yn = hnp.rmsnorm_scale(yn, p["norm"]["scale"], eps=cfg.norm_eps)
    return hnp.matmul(yn, p["wo"])


def _graph_moe(p, h, cfg):
    """MoE FFN: the sort/scatter routing is not expressible as a lazy graph,
    so it runs eagerly on the forced activations — its router matmul and the
    whole grouped expert FFN still dispatch through their registered
    descriptors, so the trace stays uniform."""
    from repro.models import moe as M

    out, aux = M.moe_ffn(p, _force(h), cfg)
    return out, aux


def graph_block(
    p,
    x: jax.Array,
    cfg,
    kind: str,
    is_moe: bool,
    *,
    positions,
    window=None,
    rope_theta=None,
) -> Tuple[jax.Array, jax.Array]:
    """One pre-norm residual block as a captured ``hnp`` graph.

    Mirrors ``transformer._apply_block`` exactly (same descriptors, same
    math); returns ``(x, aux_loss)``.
    """
    hnp = _hnp()
    aux = jnp.zeros((), jnp.float32)
    with hnp.offload_region(f"{kind}-block") as region:
        _record_report(region.report)
        xa = hnp.array(x)
        h1 = _graph_norm(xa, p["norm1"], cfg, cfg.norm_kind)
        if kind == "attn":
            mix = _graph_attention(
                p["mixer"], h1, x.shape, cfg, positions, window, rope_theta
            )
        else:
            mix = _graph_mamba(p["mixer"], h1, x.shape, cfg, x.dtype)
        xres = xa + mix           # residual fuses into the mixer's launch
        if cfg.family != "ssm":
            h2 = _graph_norm(xres, p["norm2"], cfg, cfg.norm_kind)
            if is_moe:
                f, aux = _graph_moe(p["ffn"], h2, cfg)
                out = xres + hnp.array(f)
            else:
                f = hnp.mlp_block(
                    h2, p["ffn"]["w_up"], p["ffn"]["w_down"],
                    gate=p["ffn"].get("w_gate"),
                    b_up=p["ffn"].get("b_up"), b_down=p["ffn"].get("b_down"),
                    kind=cfg.mlp_kind,
                )
                out = xres + f    # residual fuses into the mlp launch
        else:
            out = xres
        return _force(out), aux


def graph_ffn(p, x: jax.Array, cfg, *, residual=None) -> jax.Array:
    """Dense FFN alone as a captured graph (decode path: mixers mutate the
    KV/state caches eagerly, the FFN is the graph-captured half).

    ``residual`` (the block input, pre-norm) is added as a graph node so it
    fuses into the FFN launch; when None the bare FFN output is returned."""
    hnp = _hnp()
    with hnp.offload_region("ffn-block") as region:
        _record_report(region.report)
        f = hnp.mlp_block(
            hnp.array(x), p["w_up"], p["w_down"], gate=p.get("w_gate"),
            b_up=p.get("b_up"), b_down=p.get("b_down"), kind=cfg.mlp_kind,
        )
        if residual is not None:
            f = hnp.array(residual) + f
        return _force(f)
