"""Model facade: embeddings + stack + head, losses, decode, input specs.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure
functions of (params, batch) — ready for ``jax.jit``/``pjit`` in the launch
layer.  Input specs are ``ShapeDtypeStruct``s so the multi-pod dry-run can
lower every (arch × shape) cell without allocating anything.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import blas
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["Model", "build_model", "cross_entropy"]

AUX_LOSS_WEIGHT = 0.01


def _dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE in fp32. logits: (B, S, V); labels: (B, S) int32.

    The label log-prob is picked with an iota==label mask-and-sum rather
    than take_along_axis: a gather along a vocab-sharded axis forces GSPMD
    to all-gather the logits, while the masked sum partitions cleanly
    (elementwise + reduce with a psum over the model axis)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    v = lf.shape[-1]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.where(vocab_iota == labels[..., None], lf, 0.0)
    ll = jnp.sum(picked, axis=-1)
    return jnp.mean(lse - ll)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---- params -----------------------------------------------------------
    def init_params(self, rng: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = _dtype_of(cfg)
        k_embed, k_stack, k_head = jax.random.split(rng, 3)
        params: Dict[str, Any] = {
            "stack": T.init_stack(k_stack, cfg, dtype),
            "final_norm": L.init_norm(cfg.d_model, dtype, kind=cfg.norm_kind),
        }
        if cfg.embed_inputs:
            params["embed"] = (
                jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32)
                * cfg.d_model ** -0.5
            ).astype(dtype)
        if not (cfg.tie_embeddings and cfg.embed_inputs):
            params["head"] = L.init_dense(
                k_head, cfg.d_model, cfg.vocab_size, dtype
            )
        return params

    def param_specs(self, rng: jax.Array):
        return jax.eval_shape(self.init_params, rng)

    # ---- forward ------------------------------------------------------------
    def _embed(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if cfg.embed_inputs:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
            bsz, s = batch["tokens"].shape
        else:
            x = batch["embeds"]
            bsz, s = x.shape[0], x.shape[1]
        if "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None], (bsz, s)
            )
        return x, positions

    def _head(self, params, x) -> jax.Array:
        cfg = self.cfg
        x = L.apply_norm(x, params["final_norm"], cfg.norm_eps, cfg.norm_kind)
        if cfg.tie_embeddings and cfg.embed_inputs:
            return blas.matmul(x, params["embed"].T)
        return blas.matmul(x, params["head"])

    def forward(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """(logits (B, S, V), aux_loss) — training / prefill."""
        x, positions = self._embed(params, batch)
        x, aux = T.apply_stack(params["stack"], x, self.cfg, positions=positions)
        return self._head(params, x), aux

    def loss(self, params, batch) -> jax.Array:
        logits, aux = self.forward(params, batch)
        return cross_entropy(logits, batch["labels"]) + AUX_LOSS_WEIGHT * aux

    # ---- decode --------------------------------------------------------------
    def init_decode_cache(self, batch_size: int, cache_len: int):
        return T.init_decode_cache(
            self.cfg, batch_size, cache_len, _dtype_of(self.cfg)
        )

    def decode_step(self, params, cache, tokens, cache_index):
        """One token: tokens (B, 1) int32 (or embeds (B, 1, D) for stub
        frontends); cache_index scalar int32. Returns (logits (B, V), cache)."""
        cfg = self.cfg
        if cfg.embed_inputs:
            x = jnp.take(params["embed"], tokens, axis=0)
        else:
            x = tokens  # already embedded (B, 1, D)
        x, new_cache = T.decode_stack(params["stack"], cache, x, cache_index, cfg)
        logits = self._head(params, x)
        return logits[:, 0, :], new_cache

    # ---- dry-run input specs ---------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = _dtype_of(cfg)
        if shape.kind in ("train", "prefill"):
            specs: Dict[str, Any] = {}
            if cfg.embed_inputs:
                specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            else:
                specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            if cfg.mrope:
                specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            return specs
        # decode: one new token against a cache of length s
        if cfg.embed_inputs:
            tok = jax.ShapeDtypeStruct((b, 1), i32)
        else:
            tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
        cache = jax.eval_shape(lambda: self.init_decode_cache(b, s))
        return {
            "tokens": tok,
            "cache": cache,
            "cache_index": jax.ShapeDtypeStruct((), i32),
        }


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
