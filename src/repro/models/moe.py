"""Mixture-of-Experts FFN with sort-based, expert-parallel dispatch.

Dispatch is megablox-style: token copies are sorted by assigned expert,
packed into a static-capacity (E, C, d) buffer, run through the grouped
expert FFN (the BLAS seam's registered ``moe_expert_ffn`` descriptor —
experts become the outer parallel grid dim of the device kernel, and the
expert-parallel shard_map is the descriptor's `plan`), and scattered back
weighted by the router gates.  Static capacity keeps every tile MXU-dense
and the whole thing shardable: the (E, …) dims partition over the ``model``
mesh axis (expert parallelism), and the gather/scatter lower to all-to-alls.

The explicit-collective path splits into three stages so the expert FFN
dispatches through the seam like everything else: a route+pack shard_map
(row-local sort/scatter, ONE all-to-all carrying each routed token to its
expert's owner), the ``moe_expert_ffn`` dispatch (its plan keeps experts
chip-local — in_specs match the pack stage's out_specs exactly, so no data
moves), and a combine shard_map (all-to-all back + row-local unpack).
This file contains zero raw ``lax.dot_general`` launch sites and zero bare
``engine().launch`` accounting calls (guard-tested).

Arctic's "dense residual" variant runs a standard dense FFN in parallel and
sums the outputs.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blas
from repro.models import layers as L
from repro.obs import metrics as _metrics
from repro.sharding.annotate import constrain

from repro.compat import shard_map

__all__ = [
    "MoEStepTrace",
    "expert_capacity",
    "init_moe",
    "last_moe_step",
    "moe_ffn",
    "moe_ffn_placed",
    "moe_step_trace",
]


@dataclasses.dataclass(frozen=True)
class MoEStepTrace:
    """One eager MoE dispatch step's routing outcome.

    ``expert_capacity`` overflow used to vanish silently in the packed
    path (the ``keep`` mask just zeroes the overflow copies); this record
    keeps the books: per-expert routed/dropped copy counts, the capacity
    they were clamped to, and the step's ``drop_rate``.  Captured eagerly
    only — under jit tracing the histogram is abstract and no record is
    written (the in-graph math never depends on it)."""

    counts: Tuple[int, ...]     # routed token copies per expert
    capacity: int               # per-(group,expert) slot clamp
    dropped: Tuple[int, ...]    # copies past capacity, per expert
    tokens_routed: int
    tokens_dropped: int
    drop_rate: float            # tokens_dropped / tokens_routed


_MOE_STEPS: collections.deque = collections.deque(maxlen=256)


def last_moe_step() -> Optional[MoEStepTrace]:
    """The most recent eager MoE step record (None before any dispatch)."""
    return _MOE_STEPS[-1] if _MOE_STEPS else None


def moe_step_trace() -> List[MoEStepTrace]:
    """Recent eager MoE step records, oldest first (bounded window)."""
    return list(_MOE_STEPS)


def _note_moe_step(counts, cap: int) -> None:
    """Surface the route/pack histogram + dropped-token accounting.

    ``counts`` is the in-graph per-(group,)expert histogram; eagerly it is
    concrete and the step is recorded (``moe.tokens_dropped{expert=}``
    counters + a :class:`MoEStepTrace`), under jit it is a tracer and the
    capture is skipped."""
    try:
        c = np.asarray(counts, dtype=np.int64)
    except Exception:
        return  # tracing: abstract values never leave the graph
    c = np.atleast_2d(c)                       # (G, E)
    hist = c.sum(axis=0)
    dropped = np.maximum(c - int(cap), 0).sum(axis=0)
    routed = int(hist.sum())
    tot_drop = int(dropped.sum())
    _MOE_STEPS.append(MoEStepTrace(
        counts=tuple(int(v) for v in hist),
        capacity=int(cap),
        dropped=tuple(int(v) for v in dropped),
        tokens_routed=routed,
        tokens_dropped=tot_drop,
        drop_rate=(tot_drop / routed) if routed else 0.0,
    ))
    _metrics.counter("moe.tokens_routed").inc(routed)
    for e_i, d_i in enumerate(dropped):
        if d_i:
            _metrics.counter("moe.tokens_dropped", expert=str(e_i)).inc(
                int(d_i))


def expert_capacity(num_tokens: int, cfg) -> int:
    """Static per-expert slot count (ceil to an MXU-friendly multiple of 8)."""
    ideal = num_tokens * cfg.experts_per_token / cfg.num_experts
    cap = int(math.ceil(ideal * cfg.capacity_factor / 8.0) * 8)
    return max(cap, 8)


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": L.init_dense(ks[0], d, e, jnp.float32, scale=scale),
        "we_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "we_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "we_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * f ** -0.5).astype(dtype),
    }
    if cfg.dense_residual:
        p["dense"] = L.init_mlp(ks[4], d, cfg.d_ff, dtype, cfg.mlp_kind)
    return p


def _top_k_gates(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Softmax-then-topk router (qwen/jamba convention), renormalized."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                  # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx


def _router(p, xf, cfg):
    """Logits, renormalized top-k gates, and Switch-style aux loss.

    Router math stays fp32; the *returned gates* are cast to the payload
    dtype — an fp32 gate multiplying bf16 expert outputs upcasts the whole
    dispatch backward pass to fp32 and doubles the EP wire volume."""
    k, e = cfg.experts_per_token, cfg.num_experts
    logits = blas.matmul(xf, p["router"].astype(xf.dtype), out_dtype=jnp.float32)
    gates, idx = _top_k_gates(logits, k)
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux_loss = e * jnp.sum(me * ce)
    return gates.astype(xf.dtype), idx, aux_loss


def _expert_mlp(p, eb):
    """(E, ..., d) -> (E, ..., d) — ONE seam dispatch for the whole grouped
    expert FFN (gate/up/silu/down); shape-preserving on all free dims."""
    return blas.moe_expert_ffn(eb, p["we_gate"], p["we_up"], p["we_down"])


def _moe_global(p, xf, gates, idx, cfg):
    """Mesh-wide sort dispatch — the naive baseline (§Perf)."""
    t, d = xf.shape
    k, e = cfg.experts_per_token, cfg.num_experts
    cap = expert_capacity(t, cfg)

    flat_expert = idx.reshape(t * k)
    flat_gate = gates.reshape(t * k)
    order = jnp.argsort(flat_expert)                      # GLOBAL sort
    sorted_expert = flat_expert[order]
    sorted_token = order // k
    sorted_gate = flat_gate[order]

    counts = jnp.sum(jax.nn.one_hot(flat_expert, e, dtype=jnp.int32), axis=0)
    _note_moe_step(counts, cap)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_expert]
    keep = rank < cap
    slot = jnp.where(keep, sorted_expert * cap + rank, e * cap)

    buf = jnp.zeros((e * cap + 1, d), xf.dtype)
    buf = buf.at[slot].set(xf[sorted_token] * keep[:, None].astype(xf.dtype))
    y = _expert_mlp(p, buf[: e * cap].reshape(e, cap, d))
    y_flat = jnp.concatenate([y.reshape(e * cap, d), jnp.zeros((1, d), y.dtype)])
    contrib = y_flat[slot] * (sorted_gate * keep).astype(y.dtype)[:, None]
    return jnp.zeros((t, d), xf.dtype).at[sorted_token].add(contrib)


def _dispatch_groups(t: int, cfg) -> int:
    """Largest power-of-two group count from ``cfg.dispatch_groups`` that
    divides ``t`` (shared by the grouped path and the placement-aware
    wrapper so their capacity arithmetic never drifts)."""
    g_ = cfg.dispatch_groups if cfg.dispatch_groups > 0 else 1
    while t % g_:
        g_ //= 2
    return max(g_, 1)


def _moe_grouped(p, xf, gates, idx, cfg, expert_fn=None):
    """Group-local dispatch (§Perf hillclimb #1).

    Tokens are split into G groups aligned with the data shards; the sort,
    rank/capacity bookkeeping and both scatters are *row-local* (vectorized
    over G, so a data-sharded G axis never communicates).  The only
    cross-device traffic is the (G, E) transpose that carries each routed
    token payload to its expert's model-shard and back — the minimal EP
    all-to-all volume (2 · T · k · d bytes globally).

    ``expert_fn`` (default :func:`_expert_mlp`) is the grouped-FFN seam
    call; the placement-aware path substitutes a placed dispatch with the
    *same* lowering, so the output is bitwise-identical either way.
    """
    t, d = xf.shape
    k, e = cfg.experts_per_token, cfg.num_experts
    g_ = _dispatch_groups(t, cfg)
    tg = t // g_
    cap_g = expert_capacity(tg, cfg)                      # per-group capacity

    xg = xf.reshape(g_, tg, d)
    flat_expert = idx.reshape(g_, tg * k)
    flat_gate = gates.reshape(g_, tg * k)
    order = jnp.argsort(flat_expert, axis=-1)             # row-local sorts
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=-1)
    sorted_token = order // k                             # (G, Tg·k)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=-1)

    counts = jnp.sum(
        jax.nn.one_hot(flat_expert, e, dtype=jnp.int32), axis=1
    )                                                     # (G, E)
    _note_moe_step(counts, cap_g)
    starts = jnp.cumsum(counts, axis=-1) - counts
    rank = (
        jnp.arange(tg * k, dtype=jnp.int32)[None]
        - jnp.take_along_axis(starts, sorted_expert, axis=-1)
    )
    keep = rank < cap_g
    slot = jnp.where(keep, sorted_expert * cap_g + rank, e * cap_g)

    # Dropped copies: clamp to a real slot, zeroed by the keep mask —
    # scatter-ADD makes the clamped writes harmless (they add zeros).
    slot = jnp.clip(slot, 0, e * cap_g - 1)
    keep_f = keep.astype(xf.dtype)

    def pack_row(xg_row, tok_row, slot_row, keep_row):
        vals = jnp.take(xg_row, tok_row, axis=0) * keep_row[:, None]
        return jnp.zeros((e * cap_g, d), xg_row.dtype).at[slot_row].add(vals)

    # vmap → scatter with explicit batching dims: row-local under a
    # data-sharded G (advanced gi-indexing defeated the SPMD partitioner).
    buf = jax.vmap(pack_row)(xg, sorted_token, slot, keep_f)   # (G, E·Cg, d)

    # Split E·Cg (unsharded) and transpose: the ONLY cross-device move —
    # a (data <-> model) all-to-all carrying each routed token once.
    ebuf = buf.reshape(g_, e, cap_g, d).swapaxes(0, 1)         # (E, G, Cg, d)
    ebuf = constrain(ebuf, "model", None, None, None)
    y = (expert_fn or _expert_mlp)(p, ebuf)                    # (E, G, Cg, d)
    y_back = y.swapaxes(0, 1)                                  # all-to-all back
    y_back = constrain(y_back, "dp", None, None, None)
    y_flat = y_back.reshape(g_, e * cap_g, d)                  # unsharded merge

    def unpack_row(y_row, tok_row, slot_row, w_row):
        contrib = jnp.take(y_row, slot_row, axis=0) * w_row[:, None]
        return jnp.zeros((tg, d), y_row.dtype).at[tok_row].add(contrib)

    out = jax.vmap(unpack_row)(
        y_flat, sorted_token, slot, (sorted_gate * keep).astype(y_flat.dtype)
    )
    return out.reshape(t, d)


def _moe_shard_map(p, xf, cfg, mesh):
    """Explicit-collective dispatch (§Perf hillclimb, final form).

    Tokens are sharded over (dp × model) — every device routes and packs its
    own ~T/devices tokens locally (sort/rank/scatter never leave the chip),
    then ONE ``lax.all_to_all`` over the model axis carries each routed
    token copy to its expert's owner and one carries results back: the
    minimal EP wire volume.  GSPMD could not be coaxed into this schedule
    (it kept materializing all-gathers around the pack/unpack scatters —
    see §Perf iterations 2-4); shard_map states it exactly.

    The stage structure routes the expert FFN through the seam: route+pack
    ends at an out_spec that *is* the ``moe_expert_ffn`` plan's in_spec
    (experts model-sharded, peer-rows dp-sharded), so the descriptor
    dispatch between the two shard_maps moves no data and the expert GEMMs
    get the same cost/placement/residency record as every other op.
    """
    import numpy as _np
    from jax.sharding import PartitionSpec as P

    t, d = xf.shape
    k, e = cfg.experts_per_token, cfg.num_experts
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_model = mesh.shape["model"]
    n_dp = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tij = t // (n_dp * n_model)
    cap_ij = expert_capacity(tij, cfg)
    e_loc = e // n_model
    tok_spec = P(dp + ("model",), None)
    flat_spec = P(dp + ("model",))

    def route_pack(xf_loc, router):
        # ---- route + pack: all chip-local --------------------------------
        logits = (xf_loc @ router.astype(xf_loc.dtype)).astype(jnp.float32)
        gates, idx = _top_k_gates(logits, k)
        gates = gates.astype(xf_loc.dtype)
        probs = jax.nn.softmax(logits, axis=-1)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp + ("model",)) if dp else jax.lax.pmean(aux, "model")

        flat_e = idx.reshape(tij * k)
        flat_g = gates.reshape(tij * k)
        order = jnp.argsort(flat_e)
        se = flat_e[order]
        st_ = order // k
        sg = flat_g[order]
        counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=0)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(tij * k, dtype=jnp.int32) - starts[se]
        keep = rank < cap_ij
        slot = jnp.clip(se * cap_ij + rank, 0, e * cap_ij - 1)
        vals = xf_loc[st_] * keep[:, None].astype(xf_loc.dtype)
        buf = jnp.zeros((e * cap_ij, d), xf_loc.dtype).at[slot].add(vals)

        # ---- THE all-to-all: expert blocks to their model-shard owners ----
        buf = buf.reshape(n_model, e_loc * cap_ij, d)
        ex = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        # (n_model peers, e_loc·cap_ij, d) -> (e_loc, n_model·cap_ij, d)
        ex = ex.reshape(n_model, e_loc, cap_ij, d).swapaxes(0, 1)
        ex = ex.reshape(e_loc, n_model * cap_ij, d)
        sgk = sg * keep.astype(sg.dtype)
        return ex, slot, st_, sgk, aux

    ex, slot, st_, sgk, aux = shard_map(
        route_pack,
        mesh=mesh,
        in_specs=(tok_spec, P(None, None)),
        out_specs=(P("model", dp, None), flat_spec, flat_spec, flat_spec, P()),
        check_vma=False,
    )(xf, p["router"])

    # ---- expert FFN through the seam: one recorded dispatch whose plan
    # shard_maps experts exactly where the pack stage left them ------------
    y = _expert_mlp(p, ex)

    def combine(y_loc, slot_l, st_l, sgk_l):
        # ---- return trip + local unpack -----------------------------------
        y_ = y_loc.reshape(e_loc, n_model, cap_ij, d).swapaxes(0, 1)
        y_ = y_.reshape(n_model, e_loc * cap_ij, d)
        y_ = jax.lax.all_to_all(y_, "model", split_axis=0, concat_axis=0)
        y_ = y_.reshape(e * cap_ij, d)
        contrib = y_[slot_l] * sgk_l[:, None]
        return jnp.zeros((tij, d), y_.dtype).at[st_l].add(contrib)

    out = shard_map(
        combine,
        mesh=mesh,
        in_specs=(P("model", dp, None), flat_spec, flat_spec, flat_spec),
        out_specs=tok_spec,
        check_vma=False,
    )(y, slot, st_, sgk)
    return out, aux


def _shard_map_usable(cfg, t: int) -> bool:
    from repro.sharding.annotate import _ambient_mesh

    mesh = _ambient_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    try:
        import numpy as _np

        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n = int(_np.prod([mesh.shape[a] for a in dp + ("model",)]))
        return (
            t % n == 0
            and cfg.num_experts % mesh.shape["model"] == 0
            and t // n >= 1
        )
    except Exception:
        return False


def moe_ffn(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). Static-capacity EP dispatch.

    Dispatch mode (cfg.moe_dispatch):
      "auto"    — shard_map explicit collectives when a compatible mesh is
                  ambient, else the grouped GSPMD path (CPU tests, local).
      "grouped" — group-local GSPMD dispatch.
      "global"  — mesh-wide sort (naive §Perf baseline).
    """
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    mode = cfg.moe_dispatch
    if mode == "auto" and _shard_map_usable(cfg, b * s):
        from repro.sharding.annotate import _ambient_mesh

        out, aux_loss = _moe_shard_map(p, xf, cfg, _ambient_mesh())
        if cfg.dense_residual:
            out = out + L.mlp_apply(p["dense"], xf, cfg.mlp_kind)
        return out.reshape(b, s, d), aux_loss

    gates, idx, aux_loss = _router(p, xf, cfg)
    if mode == "global":
        out = _moe_global(p, xf, gates, idx, cfg)
    else:
        out = _moe_grouped(p, xf, gates, idx, cfg)
    if cfg.dense_residual:
        out = out + L.mlp_apply(p["dense"], xf, cfg.mlp_kind)
    return out.reshape(b, s, d), aux_loss


def _host_histogram(idx, e: int) -> Optional[List[int]]:
    """Per-expert routed-copy counts as host ints (None under tracing —
    placement decisions are host-side and eager-only by design)."""
    try:
        flat = np.asarray(idx).reshape(-1)
    except Exception:
        return None
    return [int(v) for v in np.bincount(flat, minlength=e)[:e]]


def _expert_mlp_placed(p, eb, plan):
    """The grouped-FFN seam call with per-expert placed accounting: same
    op, same lowering, one dispatch graph — only the launch bookkeeping
    fans out (``dispatch_placed(..., placement=plan)``)."""
    out, _ = blas.moe_expert_ffn_placed(
        eb, p["we_gate"], p["we_up"], p["we_down"], placement=plan)
    return out


def moe_ffn_placed(
    p, x: jax.Array, cfg, policy=None
) -> Tuple[jax.Array, jax.Array]:
    """Placement-aware grouped MoE dispatch.  x: (B, S, D) -> (out, aux).

    With ``policy`` (an ``repro.core.placement.ExpertPlacementPolicy``)
    attached and enabled, the route stage's per-expert token histogram
    feeds ``policy.step`` (hot experts migrate/replicate d2d, charged on
    the stream clocks) and the grouped-FFN dispatch fans out per expert
    onto the lanes their weight handles live on.  The math path is the
    static grouped dispatch verbatim — with the policy off (or ``None``,
    or under jit tracing where no host histogram exists) this is
    *bitwise-equal* to ``moe_ffn(..., moe_dispatch="grouped")``, and with
    it on only the accounting changes.

    Layer-side dropped-token books (``moe.tokens_dropped{expert=}``, the
    :class:`MoEStepTrace` drop rate) come from the in-graph histogram via
    ``_note_moe_step``; the policy's plan is built with ``record=False``
    so the same drop is never counted twice."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    gates, idx, aux_loss = _router(p, xf, cfg)
    expert_fn = None
    if policy is not None and policy.enabled and policy.attached:
        hist = _host_histogram(idx, cfg.num_experts)
        if hist is not None:
            policy.step(hist)
            g_ = _dispatch_groups(b * s, cfg)
            cap = expert_capacity((b * s) // g_, cfg) * g_
            plan = policy.plan(hist, capacity=cap, record=False)
            expert_fn = lambda pp, eb: _expert_mlp_placed(pp, eb, plan)
    out = _moe_grouped(p, xf, gates, idx, cfg, expert_fn=expert_fn)
    if cfg.dense_residual:
        out = out + L.mlp_apply(p["dense"], xf, cfg.mlp_kind)
    return out.reshape(b, s, d), aux_loss
