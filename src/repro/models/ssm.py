"""Mamba-2 mixer in SSD (state-space duality) form.

SSD recasts the selective-SSM recurrence as chunked *matmuls* — the ideal
workload for a GEMM-offload substrate.  The whole chunked core (within-chunk
quadratic term, inter-chunk state recurrence, D skip) is one registered
``ssd_scan`` descriptor: its host lowering is the jnp oracle composition,
its Pallas lowering runs the ``ssd_chunk_diag`` kernel, and its `plan` is
the head-sharded TP shard_map (all SSD math is per-head, so a model-sharded
head axis needs zero collectives).  Projections go through ``blas.matmul``;
the output projection's ``tp_mode="row"`` form psums once in bf16.

Decode is the raw one-step recurrence on an (B, H, N, P) fp32 state cache —
O(1) per token, which is what makes the ``long_500k`` cell runnable.
This file contains zero raw ``lax.dot_general`` launch sites and zero bare
``engine().launch`` accounting calls (guard-tested).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blas
from repro.models import layers as L

__all__ = ["init_mamba", "mamba_block", "decode_mamba_block", "mamba_state_shapes"]


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_num_heads
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    cw = cfg.ssm_conv_width
    conv_feat = di + 2 * g * n
    ks = jax.random.split(key, 8)
    return {
        "wz": L.init_dense(ks[0], d, di, dtype),
        "wx": L.init_dense(ks[1], d, di, dtype),
        "wb": L.init_dense(ks[2], d, g * n, dtype),
        "wc": L.init_dense(ks[3], d, g * n, dtype),
        "wdt": L.init_dense(ks[4], d, h, dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),           # a = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (cw, conv_feat), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_feat,), dtype),
        "norm": L.init_norm(di, dtype),
        "wo": L.init_dense(ks[6], di, d, dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S via stacked shifts. u: (B, S, F); w: (K, F)."""
    k = w.shape[0]
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        ui = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, : u.shape[1], :]
        out = out + ui.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(u.dtype)


def _project(p, x, cfg):
    z = blas.matmul(x, p["wz"])
    xin = blas.matmul(x, p["wx"])
    b_ = blas.matmul(x, p["wb"])
    c_ = blas.matmul(x, p["wc"])
    dt = blas.matmul(x, p["wdt"], out_dtype=jnp.float32)
    return z, xin, b_, c_, dt


def ssd_inputs(p, xin, b_, c_, dt, cfg):
    """Shape the conv outputs into the per-head ``ssd_scan`` operands."""
    bsz, s = xin.shape[0], xin.shape[1]
    h, pdim = cfg.ssm_num_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    dt_f = jax.nn.softplus(dt + p["dt_bias"])                 # (B, S, H) fp32
    a = -jnp.exp(p["a_log"])                                  # (H,)
    xh = xin.reshape(bsz, s, h, pdim)
    rep = h // g
    bh_ = jnp.repeat(b_.reshape(bsz, s, g, n), rep, axis=2)
    ch_ = jnp.repeat(c_.reshape(bsz, s, g, n), rep, axis=2)
    return xh, dt_f, a, bh_, ch_


def mamba_block(p, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence SSD pass. x: (B, S, D) -> (B, S, D).

    Every heavy piece dispatches through a descriptor: the five input
    projections (``matmul``), the chunked SSD core (``ssd_scan`` — under an
    ambient mesh its plan shards heads with zero collectives), and the
    output projection (``matmul`` with the ``tp_mode="row"`` single-psum TP
    form).  The depthwise conv and gating stay elementwise glue.
    """
    bsz, s, d = x.shape
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim

    z, xin, b_, c_, dt = _project(p, x, cfg)
    conv_in = jnp.concatenate([xin, b_, c_], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    )
    xin = conv_out[..., : cfg.d_inner]
    b_ = conv_out[..., cfg.d_inner : cfg.d_inner + g * n]
    c_ = conv_out[..., cfg.d_inner + g * n :]

    xh, dt_f, a, bh_, ch_ = ssd_inputs(p, xin, b_, c_, dt, cfg)
    y = blas.ssd_scan(xh, dt_f, a, bh_, ch_, p["d_skip"], chunk=cfg.ssm_chunk)
    y = y.reshape(bsz, s, cfg.d_inner)
    y = y * blas.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    return blas.matmul(y, p["wo"], tp_mode="row")


def mamba_state_shapes(cfg, batch: int):
    """(ssm_state, conv_state) shapes for the decode cache."""
    h, n, pdim = cfg.ssm_num_heads, cfg.ssm_state_dim, cfg.ssm_head_dim
    conv_feat = cfg.d_inner + 2 * cfg.ssm_num_groups * cfg.ssm_state_dim
    return (batch, h, n, pdim), (batch, cfg.ssm_conv_width - 1, conv_feat)


def decode_mamba_block(
    p, x: jax.Array, state: Tuple[jax.Array, jax.Array], cfg
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token recurrence. x: (B, 1, D); state: (ssm (B,H,N,P) fp32, conv)."""
    bsz = x.shape[0]
    h, pdim = cfg.ssm_num_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    ssm_state, conv_state = state

    z, xin, b_, c_, dt = _project(p, x, cfg)
    u = jnp.concatenate([xin, b_, c_], axis=-1)[:, 0, :]      # (B, F)
    hist = jnp.concatenate([conv_state, u[:, None, :]], axis=1)  # (B, K, F)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkf,kf->bf", hist.astype(jnp.float32), w)
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv_state = hist[:, 1:, :].astype(conv_state.dtype)

    xin = conv_out[:, : cfg.d_inner]
    b1 = conv_out[:, cfg.d_inner : cfg.d_inner + g * n]
    c1 = conv_out[:, cfg.d_inner + g * n :]

    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"])             # (B, H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                   # (B, H)

    xh = xin.reshape(bsz, h, pdim)
    rep = h // g
    bh_ = jnp.repeat(b1.reshape(bsz, g, n), rep, axis=1)      # (B, H, N)
    ch_ = jnp.repeat(c1.reshape(bsz, g, n), rep, axis=1)

    new_state = (
        decay[..., None, None] * ssm_state
        + jnp.einsum("bh,bhn,bhp->bhnp", dt, bh_, xh)
    )
    y = jnp.einsum("bhn,bhnp->bhp", ch_, new_state)           # (B, H, P)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    return blas.matmul(y, p["wo"]), (new_state, new_conv_state)
