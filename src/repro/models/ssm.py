"""Mamba-2 mixer in SSD (state-space duality) form.

SSD recasts the selective-SSM recurrence as chunked *matmuls* — the ideal
workload for a GEMM-offload substrate.  Per chunk c of length Q:

  Y_diag[c] = (L(c) ∘ (C_c B_c^T)) (dt·X)_c        — quadratic, via the
               ``ssd_chunk_diag`` Pallas kernel / oracle
  S_c       = Σ_j exp(cum_last − cum_j) dt_j B_j ⊗ x_j   — chunk state (N, P)
  carry     : S←exp(Σda) S + S_c  (lax.scan over chunks)
  Y_off[c]  = exp(cum) C_c · S_{c−1}

Decode is the raw one-step recurrence on an (B, H, N, P) fp32 state cache —
O(1) per token, which is what makes the ``long_500k`` cell runnable.
All projections go through the BLAS seam.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blas
from repro.models import layers as L

from repro.compat import shard_map

__all__ = ["init_mamba", "mamba_block", "decode_mamba_block", "mamba_state_shapes"]


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_num_heads
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    cw = cfg.ssm_conv_width
    conv_feat = di + 2 * g * n
    ks = jax.random.split(key, 8)
    return {
        "wz": L.init_dense(ks[0], d, di, dtype),
        "wx": L.init_dense(ks[1], d, di, dtype),
        "wb": L.init_dense(ks[2], d, g * n, dtype),
        "wc": L.init_dense(ks[3], d, g * n, dtype),
        "wdt": L.init_dense(ks[4], d, h, dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),           # a = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (cw, conv_feat), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_feat,), dtype),
        "norm": L.init_norm(di, dtype),
        "wo": L.init_dense(ks[6], di, d, dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S via stacked shifts. u: (B, S, F); w: (K, F)."""
    k = w.shape[0]
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        ui = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, : u.shape[1], :]
        out = out + ui.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(u.dtype)


def _project(p, x, cfg):
    z = blas.matmul(x, p["wz"])
    xin = blas.matmul(x, p["wx"])
    b_ = blas.matmul(x, p["wb"])
    c_ = blas.matmul(x, p["wc"])
    dt = blas.matmul(x, p["wdt"], out_dtype=jnp.float32)
    return z, xin, b_, c_, dt


def _ssd_chunked(xh, dt, a, bh_, ch_, d_skip, chunk):
    """Chunked SSD core: (B, S, H, P) -> (B, S, H, P), any head count.

    All math is per-head — under the TP shard_map each device runs this on
    its local heads with zero collectives."""
    bsz, s, h, pdim = xh.shape
    n = bh_.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q
    da = dt * a                                               # (B, S, H)

    xdt = xh * dt[..., None]

    def to_bh(t):
        t = t.reshape(bsz, nc, q, h, -1).transpose(0, 3, 1, 2, 4)
        return t.reshape(bsz * h, nc, q, t.shape[-1])

    da_c = da.reshape(bsz, nc, q, h)
    cum_c = jnp.cumsum(da_c, axis=2)                          # (B, C, Q, H)
    cum_bh = cum_c.transpose(0, 3, 1, 2).reshape(bsz * h, nc, q)

    x_bh = to_bh(xdt)
    b_bh = to_bh(bh_)
    c_bh = to_bh(ch_)

    from repro.kernels import ref as kref

    y_diag = kref.ssd_chunk_diag_ref(
        x_bh.astype(jnp.float32), cum_bh, b_bh.astype(jnp.float32),
        c_bh.astype(jnp.float32),
    )

    decay_to_end = jnp.exp(cum_bh[:, :, -1:] - cum_bh)
    states = jnp.einsum(
        "zcq,zcqn,zcqp->zcnp",
        decay_to_end,
        b_bh.astype(jnp.float32),
        x_bh.astype(jnp.float32),
    )
    chunk_decay = jnp.exp(cum_bh[:, :, -1])

    def scan_fn(carry, inp):
        st, dec = inp
        prev = carry
        return dec[:, None, None] * prev + st, prev

    init = jnp.zeros((bsz * h, n, pdim), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init, (states.transpose(1, 0, 2, 3), chunk_decay.T)
    )
    prev_states = prev_states.transpose(1, 0, 2, 3)

    y_off = jnp.einsum(
        "zcqn,zcnp,zcq->zcqp",
        c_bh.astype(jnp.float32), prev_states, jnp.exp(cum_bh),
    )
    y = (y_diag + y_off).reshape(bsz, h, s, pdim).transpose(0, 2, 1, 3)
    return y + xh.astype(jnp.float32) * d_skip[None, None, :, None]


def mamba_block(p, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence SSD pass. x: (B, S, D) -> (B, S, D)."""
    from repro.sharding.annotate import _ambient_mesh

    mesh = _ambient_mesh()
    if mesh is not None:
        y = _mamba_block_tp(p, x, cfg, mesh)
        if y is not None:
            return y
    bsz, s, d = x.shape
    h, pdim = cfg.ssm_num_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim

    z, xin, b_, c_, dt = _project(p, x, cfg)
    conv_in = jnp.concatenate([xin, b_, c_], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    )
    xin = conv_out[..., : cfg.d_inner]
    b_ = conv_out[..., cfg.d_inner : cfg.d_inner + g * n]
    c_ = conv_out[..., cfg.d_inner + g * n :]

    dt = jax.nn.softplus(dt + p["dt_bias"])                   # (B, S, H) fp32
    a = -jnp.exp(p["a_log"])                                  # (H,)

    xh = xin.reshape(bsz, s, h, pdim)
    rep = h // g
    bh_ = jnp.repeat(b_.reshape(bsz, s, g, n), rep, axis=2)
    ch_ = jnp.repeat(c_.reshape(bsz, s, g, n), rep, axis=2)

    y = _ssd_chunked(xh, dt, a, bh_, ch_, p["d_skip"], cfg.ssm_chunk)
    y = y.reshape(bsz, s, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    return blas.matmul(y, p["wo"])


def _mamba_block_tp(p, x, cfg, mesh):
    """Whole Mamba-2 block under one shard_map (§Perf iteration 10).

    SSM heads are model-sharded; every piece of the SSD math is per-head
    and therefore chip-local (GSPMD all-reduced the C·Bᵀ chunk einsums —
    55 % of mamba2's wire — because the merged (B·H) batch dim defeats its
    propagation).  Cross-device traffic: the B/C/dt activations are
    computed on sequence slices and all-gathered (tiny), the gated-norm
    variance is one scalar-field psum, and the out-projection psums once —
    the same schedule as the TP attention/MLP blocks.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    if x.ndim != 3 or "model" not in getattr(mesh, "axis_names", ()):
        return None
    n_model = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    h, pdim = cfg.ssm_num_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    di = cfg.d_inner
    bsz, s, d = x.shape
    if (
        n_model <= 1
        or h % n_model
        or di % n_model
        or bsz % n_dp
        or s % cfg.ssm_chunk
    ):
        return None
    h_loc = h // n_model
    di_loc = di // n_model
    rep = h // g

    def local(xl, wz, wx, wb, wc, wdt, dt_bias, a_log, d_skip, conv_w,
              conv_b, norm_scale, wo):
        b, s_, _ = xl.shape
        idx = jax.lax.axis_index("model")

        def dot(u, w):
            return jax.lax.dot_general(
                u, w, (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).astype(xl.dtype)

        z = dot(xl, wz)                                   # (b, s, di_loc)
        xin = dot(xl, wx)                                 # (b, s, di_loc)
        dt_l = jax.lax.dot_general(
            xl, wdt, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                 # (b, s, h_loc) f32
        # B/C on sequence slices, gathered (replicated compute is 16x flops)
        if s_ % n_model == 0:
            seg = s_ // n_model
            xs = jax.lax.dynamic_slice_in_dim(xl, idx * seg, seg, axis=1)
            b_ = jax.lax.all_gather(dot(xs, wb), "model", axis=1, tiled=True)
            c_ = jax.lax.all_gather(dot(xs, wc), "model", axis=1, tiled=True)
        else:
            b_ = dot(xl, wb)
            c_ = dot(xl, wc)

        # depthwise causal conv: local head slice of the x-part weights
        conv_wx = jax.lax.dynamic_slice_in_dim(conv_w, idx * di_loc, di_loc, axis=1)
        conv_bx = jax.lax.dynamic_slice_in_dim(conv_b, idx * di_loc, di_loc, axis=0)
        xin = jax.nn.silu(
            _causal_conv(xin, conv_wx, conv_bx).astype(jnp.float32)
        )
        conv_wbc = conv_w[:, di:]
        conv_bbc = conv_b[di:]
        bc = jnp.concatenate([b_, c_], axis=-1)
        bc = jax.nn.silu(_causal_conv(bc, conv_wbc, conv_bbc).astype(jnp.float32))
        b_, c_ = bc[..., : g * n], bc[..., g * n :]

        dt_f = jax.nn.softplus(dt_l + dt_bias)            # (b, s, h_loc)
        a = -jnp.exp(a_log)                               # (h_loc,)
        xh = xin.reshape(b, s_, h_loc, pdim)
        brep = jnp.repeat(b_.reshape(b, s_, g, n), rep, axis=2)
        crep = jnp.repeat(c_.reshape(b, s_, g, n), rep, axis=2)
        brep = jax.lax.dynamic_slice_in_dim(brep, idx * h_loc, h_loc, axis=2)
        crep = jax.lax.dynamic_slice_in_dim(crep, idx * h_loc, h_loc, axis=2)

        y = _ssd_chunked(xh, dt_f, a, brep, crep, d_skip, cfg.ssm_chunk)
        y = y.reshape(b, s_, di_loc)
        y = y * jax.nn.silu(z.astype(jnp.float32))

        # gated RMSNorm over the FULL d_inner: one scalar-field psum
        local_sq = jnp.sum(jnp.square(y), axis=-1, keepdims=True)
        var = jax.lax.psum(local_sq, "model") / di
        y = y * jax.lax.rsqrt(var + cfg.norm_eps)
        y = (y * norm_scale.astype(jnp.float32)).astype(xl.dtype)

        out = jax.lax.dot_general(
            y, wo, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        from repro.models.layers import psum_cast_dtype

        out = jax.lax.psum(out.astype(psum_cast_dtype(xl.dtype)), "model")
        return out.astype(xl.dtype)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),
            P(None, "model"), P(None, "model"),        # wz, wx
            P(None, None), P(None, None),              # wb, wc
            P(None, "model"),                          # wdt
            P("model"), P("model"), P("model"),        # dt_bias, a_log, d_skip
            P(None, None), P(None),                    # conv_w, conv_b
            P("model"),                                # norm scale
            P("model", None),                          # wo
        ),
        out_specs=P(dp, None, None),
        check_vma=False,
    )
    # seam accounting (global workload)
    from repro.core import cost_model as _cm
    from repro.core.hero import engine as _engine

    itemsize = jnp.dtype(x.dtype).itemsize
    _engine().launch(
        _cm.gemm_cost(bsz * s, 2 * di + 2 * g * n + h + d, d, itemsize),
        dtype=str(x.dtype), shape_key=f"tp-mamba-proj:{x.shape}",
        pallas_eligible=True,
    )
    _engine().launch(
        _cm.gemm_cost(bsz * s, 2 * n, cfg.ssm_chunk, itemsize, batch=h,
                      op="ssd_chunk"),
        dtype=str(x.dtype), shape_key=f"tp-ssd:{x.shape}",
        pallas_eligible=True,
    )
    return fn(
        x, p["wz"], p["wx"], p["wb"], p["wc"], p["wdt"], p["dt_bias"],
        p["a_log"], p["d_skip"], p["conv_w"], p["conv_b"],
        p["norm"]["scale"], p["wo"],
    )


def mamba_state_shapes(cfg, batch: int):
    """(ssm_state, conv_state) shapes for the decode cache."""
    h, n, pdim = cfg.ssm_num_heads, cfg.ssm_state_dim, cfg.ssm_head_dim
    conv_feat = cfg.d_inner + 2 * cfg.ssm_num_groups * cfg.ssm_state_dim
    return (batch, h, n, pdim), (batch, cfg.ssm_conv_width - 1, conv_feat)


def decode_mamba_block(
    p, x: jax.Array, state: Tuple[jax.Array, jax.Array], cfg
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token recurrence. x: (B, 1, D); state: (ssm (B,H,N,P) fp32, conv)."""
    bsz = x.shape[0]
    h, pdim = cfg.ssm_num_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    ssm_state, conv_state = state

    z, xin, b_, c_, dt = _project(p, x, cfg)
    u = jnp.concatenate([xin, b_, c_], axis=-1)[:, 0, :]      # (B, F)
    hist = jnp.concatenate([conv_state, u[:, None, :]], axis=1)  # (B, K, F)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkf,kf->bf", hist.astype(jnp.float32), w)
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv_state = hist[:, 1:, :].astype(conv_state.dtype)

    xin = conv_out[:, : cfg.d_inner]
    b1 = conv_out[:, cfg.d_inner : cfg.d_inner + g * n]
    c1 = conv_out[:, cfg.d_inner + g * n :]

    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"])             # (B, H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                   # (B, H)

    xh = xin.reshape(bsz, h, pdim)
    rep = h // g
    bh_ = jnp.repeat(b1.reshape(bsz, g, n), rep, axis=1)      # (B, H, N)
    ch_ = jnp.repeat(c1.reshape(bsz, g, n), rep, axis=1)

    new_state = (
        decay[..., None, None] * ssm_state
        + jnp.einsum("bh,bhn,bhp->bhnp", dt, bh_, xh)
    )
    y = jnp.einsum("bhn,bhnp->bhp", ch_, new_state)           # (B, H, P)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    return blas.matmul(y, p["wo"]), (new_state, new_conv_state)
