"""Stack assembly: pre-norm residual blocks, scanned over layers.

Two stack layouts:

* **uniform** — every layer has the same pytree structure: params are stacked
  on a leading (L, …) axis and applied with one ``lax.scan``.  Per-layer
  *data* (attention window, RoPE theta) rides along as scanned arrays, which
  is how gemma3's 5:1 local:global pattern and danube's SWA share one code
  path (the window is a traced scalar inside the scan body).
* **hybrid (jamba)** — layers repeat with period P (= 8): one scan over
  L/P super-blocks, the P sub-layers unrolled inside the body (attn at
  ``attn_layer_offset``, Mamba elsewhere; MoE FFN every
  ``moe_layer_period``-th sub-layer).

``lax.scan`` keeps the HLO O(1) in depth — essential for compiling 80-layer
models on the dry-run host — and ``jax.checkpoint`` on the body gives
per-layer remat (saved residuals = layer inputs only).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, GLOBAL_WINDOW
from repro.core import accounting
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

__all__ = [
    "init_stack",
    "apply_stack",
    "init_decode_cache",
    "decode_stack",
]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: str, is_moe: bool, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg.d_model, dtype, kind=cfg.norm_kind)}
    if kind == "attn":
        p["mixer"] = A.init_attention(ks[0], cfg, dtype)
    else:
        p["mixer"] = S.init_mamba(ks[0], cfg, dtype)
    if cfg.family != "ssm":
        p["norm2"] = L.init_norm(cfg.d_model, dtype, kind=cfg.norm_kind)
        if is_moe:
            p["ffn"] = M.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.mlp_kind)
    return p


def _apply_block(
    p,
    x,
    cfg: ArchConfig,
    kind: str,
    is_moe: bool,
    *,
    positions,
    window=None,
    rope_theta=None,
):
    if cfg.forward_mode == "graph":
        # Whole-block graph capture: the hnp scheduler fuses elementwise
        # epilogues, batches independent projections and threads residency
        # across the block (models/forward.py).  Same descriptors, same math.
        from repro.models import forward as F

        return F.graph_block(
            p, x, cfg, kind, is_moe,
            positions=positions, window=window, rope_theta=rope_theta,
        )
    h = L.apply_norm(x, p["norm1"], cfg.norm_eps, cfg.norm_kind)
    if kind == "attn":
        mix = A.attention_block(
            p["mixer"], h, cfg, positions=positions, window=window,
            rope_theta=rope_theta,
        )
    else:
        mix = S.mamba_block(p["mixer"], h, cfg)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if cfg.family != "ssm":
        h = L.apply_norm(x, p["norm2"], cfg.norm_eps, cfg.norm_kind)
        if is_moe:
            f, aux = M.moe_ffn(p["ffn"], h, cfg)
        else:
            f = L.mlp_apply(p["ffn"], h, cfg.mlp_kind)
        x = x + f
    return x, aux


# ---------------------------------------------------------------------------
# per-layer static data (windows / rope thetas) as scan arrays
# ---------------------------------------------------------------------------

def _layer_data(cfg: ArchConfig, seq_len: int):
    windows = np.array(
        [min(cfg.layer_window(i, seq_len), GLOBAL_WINDOW) for i in range(cfg.num_layers)],
        np.int32,
    )
    thetas = np.array(
        [cfg.layer_rope_theta(i) for i in range(cfg.num_layers)], np.float32
    )
    return jnp.asarray(windows), jnp.asarray(thetas)


def _uniform_window_static(cfg: ArchConfig) -> Optional[int]:
    """If all layers share one window, return it (enables the Pallas path)."""
    ws = {cfg.layer_window(i, 0) for i in range(cfg.num_layers)}
    if len(ws) == 1:
        w = ws.pop()
        return None if w >= GLOBAL_WINDOW else int(w)
    return None


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ArchConfig, dtype):
    if cfg.uniform_stack:
        kind = cfg.layer_kind(0)
        is_moe = cfg.layer_is_moe(0)
        keys = jax.random.split(key, cfg.num_layers)
        return jax.vmap(
            lambda k: _init_block(k, cfg, kind, is_moe, dtype)
        )(keys)
    # hybrid: stack super-blocks
    period = cfg.attn_layer_period or cfg.moe_layer_period
    n_sb = cfg.num_layers // period
    keys = jax.random.split(key, n_sb)

    def init_sb(k):
        sub_keys = jax.random.split(k, period)
        return {
            f"sub{j}": _init_block(
                sub_keys[j], cfg, cfg.layer_kind(j), cfg.layer_is_moe(j), dtype
            )
            for j in range(period)
        }

    return jax.vmap(init_sb)(keys)


# ---------------------------------------------------------------------------
# stack apply (training / prefill)
# ---------------------------------------------------------------------------

def apply_stack(params, x, cfg: ArchConfig, *, positions):
    seq_len = x.shape[1]
    if cfg.uniform_stack:
        kind = cfg.layer_kind(0)
        is_moe = cfg.layer_is_moe(0)
        windows, thetas = _layer_data(cfg, seq_len)

        def body(carry, xs):
            h, aux = carry
            lp, w, th = xs
            with accounting.scaled(cfg.num_layers):  # scan body runs L times
                h, a = _apply_block(
                    lp, h, cfg, kind, is_moe,
                    positions=positions, window=w, rope_theta=th,
                )
            return (h, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   (params, windows, thetas))
        return x, aux

    period = cfg.attn_layer_period or cfg.moe_layer_period

    def body(carry, lp):
        h, aux = carry
        with accounting.scaled(cfg.num_layers // period):
            for j in range(period):
                h, a = _apply_block(
                    lp[f"sub{j}"], h, cfg, cfg.layer_kind(j), cfg.layer_is_moe(j),
                    positions=positions, window=None, rope_theta=cfg.rope_theta,
                )
                aux = aux + a
        return (h, aux), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params)
    return x, aux


# ---------------------------------------------------------------------------
# decode cache + one-token decode
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype):
    """Cache pytree with a leading stacked-layer axis (scanned with params)."""
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.uniform_stack:
        n = cfg.num_layers
        if cfg.family == "ssm":
            ssm_shape, conv_shape = S.mamba_state_shapes(cfg, batch)
            return {
                "ssm": jnp.zeros((n, *ssm_shape), jnp.float32),
                "conv": jnp.zeros((n, *conv_shape), dtype),
            }
        eff = cache_len
        if cfg.sliding_window:
            eff = min(cache_len, cfg.sliding_window)  # rolling SWA buffer
        return {
            "k": jnp.zeros((n, batch, hkv, eff, hd), dtype),
            "v": jnp.zeros((n, batch, hkv, eff, hd), dtype),
        }
    # hybrid (jamba): one attn + (period-1) mamba sub-layers per super-block
    period = cfg.attn_layer_period
    n_sb = cfg.num_layers // period
    ssm_shape, conv_shape = S.mamba_state_shapes(cfg, batch)
    return {
        "k": jnp.zeros((n_sb, batch, hkv, cache_len, hd), dtype),
        "v": jnp.zeros((n_sb, batch, hkv, cache_len, hd), dtype),
        "ssm": jnp.zeros((n_sb, period - 1, *ssm_shape), jnp.float32),
        "conv": jnp.zeros((n_sb, period - 1, *conv_shape), dtype),
    }


def _decode_block(p, x, cache_slices, cache_index, cfg, kind, *, window, rope_theta):
    """One layer of single-token decode. Returns (x, new_cache_slices)."""
    h = L.apply_norm(x, p["norm1"], cfg.norm_eps, cfg.norm_kind)
    if kind == "attn":
        mix, (k_new, v_new) = A.decode_attention_block(
            p["mixer"], h, (cache_slices["k"], cache_slices["v"]),
            cache_index, cfg, window=window, rope_theta=rope_theta,
        )
        new_cache = {"k": k_new, "v": v_new}
    else:
        mix, (ssm_new, conv_new) = S.decode_mamba_block(
            p["mixer"], h, (cache_slices["ssm"], cache_slices["conv"]), cfg
        )
        new_cache = {"ssm": ssm_new, "conv": conv_new}
    x = x + mix
    if cfg.family != "ssm":
        h = L.apply_norm(x, p["norm2"], cfg.norm_eps, cfg.norm_kind)
        if cfg.layer_is_moe(0) and cfg.uniform_stack:
            f, _ = M.moe_ffn(p["ffn"], h, cfg)
        elif "ffn" in p:
            if cfg.forward_mode == "graph":
                # Decode's graph half: mixers mutate caches eagerly, the
                # dense FFN is captured (residual fused into its launch).
                from repro.models import forward as F

                f = F.graph_ffn(p["ffn"], h, cfg, residual=x)
                return f, new_cache
            f = L.mlp_apply(p["ffn"], h, cfg.mlp_kind)
        else:
            f = 0.0
        x = x + f
    return x, new_cache


def decode_stack(params, cache, x, cache_index, cfg: ArchConfig):
    """x: (B, 1, D). Scans layers, threading per-layer cache slices."""
    if cfg.uniform_stack:
        kind = cfg.layer_kind(0)
        windows, thetas = _layer_data(cfg, 0)

        def body(carry, xs):
            h = carry
            lp, csl, w, th = xs
            with accounting.scaled(cfg.num_layers):
                h, new_c = _decode_block(
                    lp, h, csl, cache_index, cfg, kind, window=w, rope_theta=th
                )
            return h, new_c

        x, new_cache = jax.lax.scan(body, x, (params, cache, windows, thetas))
        return x, new_cache

    period = cfg.attn_layer_period

    def body(carry, xs):
        h = carry
        lp, csl = xs
        new_c = dict(csl)
        mi = 0
        _scale = accounting.scaled(cfg.num_layers // period)
        _scale.__enter__()
        for j in range(period):
            kind = cfg.layer_kind(j)
            sub = lp[f"sub{j}"]
            hh = L.apply_norm(h, sub["norm1"], cfg.norm_eps, cfg.norm_kind)
            if kind == "attn":
                mix, (kn, vn) = A.decode_attention_block(
                    sub["mixer"], hh, (csl["k"], csl["v"]), cache_index, cfg,
                    rope_theta=cfg.rope_theta,
                )
                new_c["k"], new_c["v"] = kn, vn
            else:
                mix, (sn, cn) = S.decode_mamba_block(
                    sub["mixer"], hh,
                    (csl["ssm"][mi], csl["conv"][mi]), cfg,
                )
                new_c["ssm"] = new_c["ssm"].at[mi].set(sn)
                new_c["conv"] = new_c["conv"].at[mi].set(cn)
                mi += 1
            h = h + mix
            hh = L.apply_norm(h, sub["norm2"], cfg.norm_eps, cfg.norm_kind)
            if cfg.layer_is_moe(j):
                f, _ = M.moe_ffn(sub["ffn"], hh, cfg)
            else:
                f = L.mlp_apply(sub["ffn"], hh, cfg.mlp_kind)
            h = h + f
        _scale.__exit__(None, None, None)
        return h, new_c

    x, new_cache = jax.lax.scan(body, x, (params, cache))
    return x, new_cache
