"""GQA attention block: QKV projections (BLAS seam) + RoPE/M-RoPE + KV cache."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blas
from repro.core import cost_model as cm
from repro.core.hero import engine
from repro.models import layers as L
from repro.sharding.annotate import _ambient_mesh

from repro.compat import shard_map

__all__ = ["init_attention", "attention_block", "decode_attention_block"]


def _attention_block_tp(p, x, cfg, positions, window, rope_theta, mesh):
    """Whole attention block under one shard_map (§Perf hillclimb #2).

    Q heads are model-sharded (wq/wo column/row slices); kv projections are
    replicated (kv heads < model-axis size on every assigned GQA arch, and
    they are tiny).  The ONLY cross-device traffic is one bf16 psum of the
    block output in forward and one bf16 psum of dX in backward — GSPMD's
    schedule all-reduced the fp32 dot products (2x wire) and added per-
    projection reductions.  Returns None when topology/shapes don't apply.
    """
    from jax.sharding import PartitionSpec as P

    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if x.ndim != 3 or "model" not in getattr(mesh, "axis_names", ()):
        return None
    n_model = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if hq % n_model or x.shape[0] % n_dp or n_model <= 1:
        return None
    hq_loc = hq // n_model

    bq = p.get("bq", jnp.zeros((hq * hd,), x.dtype))
    bk = p.get("bk", jnp.zeros((hkv * hd,), x.dtype))
    bv = p.get("bv", jnp.zeros((hkv * hd,), x.dtype))
    window_arr = jnp.asarray(
        (1 << 30) if window is None else window, jnp.int32
    )
    theta_arr = jnp.asarray(rope_theta, jnp.float32)
    # Fully-manual shard_map (all mesh axes). A partial-manual variant
    # (axis_names={"model"}) would let the dW data-reductions sink out of
    # the microbatch loop, but it trips an XLA:CPU AllReducePromotion
    # crash at production sizes ("Invalid binary instruction opcode copy");
    # on TPU the while-loop all-reduce code-motion pass performs the same
    # hoist on this form. Documented in EXPERIMENTS §Perf.
    pos_spec = P(dp, None) if positions.ndim == 2 else P(None, dp, None)

    def local(xl, pos_l, win, th, wq, bq_, wk, bk_, wv, bv_, wo):
        b, s, _ = xl.shape
        idx = jax.lax.axis_index("model")
        q = (jax.lax.dot_general(xl, wq, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             .astype(xl.dtype) + bq_).reshape(b, s, hq_loc, hd)
        # kv projections can't shard over heads (hkv < model axis): shard
        # them over the SEQUENCE instead and all-gather the small kv
        # activations — computing them replicated costs +16x kv-proj FLOPs
        # (measured +28% whole-step dot-FLOPs on qwen2 before this).
        if s % n_model == 0:
            seg = s // n_model
            xs = jax.lax.dynamic_slice_in_dim(xl, idx * seg, seg, axis=1)
            k_p = (jax.lax.dot_general(xs, wk, (((2,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
                   .astype(xl.dtype) + bk_)
            v_p = (jax.lax.dot_general(xs, wv, (((2,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
                   .astype(xl.dtype) + bv_)
            k = jax.lax.all_gather(k_p, "model", axis=1, tiled=True)
            v = jax.lax.all_gather(v_p, "model", axis=1, tiled=True)
            k = k.reshape(b, s, hkv, hd)
            v = v.reshape(b, s, hkv, hd)
        else:
            k = (jax.lax.dot_general(xl, wk, (((2,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
                 .astype(xl.dtype) + bk_).reshape(b, s, hkv, hd)
            v = (jax.lax.dot_general(xl, wv, (((2,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
                 .astype(xl.dtype) + bv_).reshape(b, s, hkv, hd)
        if cfg.mrope:
            q = L.mrope(q, pos_l, th)
            k = L.mrope(k, pos_l, th)
        else:
            pos2d = pos_l if pos_l.ndim == 2 else pos_l[0]
            q = L.rope(q, pos2d, th)
            k = L.rope(k, pos2d, th)
        # GQA across the shard boundary: local q heads are the contiguous
        # global heads [idx·hq_loc, …); select their kv heads explicitly.
        grp = hq // hkv
        if grp > 1:
            k = jnp.repeat(k, grp, axis=2)
            v = jnp.repeat(v, grp, axis=2)
        start = jax.lax.axis_index("model") * hq_loc
        k = jax.lax.dynamic_slice_in_dim(k, start, hq_loc, axis=2)
        v = jax.lax.dynamic_slice_in_dim(v, start, hq_loc, axis=2)
        out = blas.attention_math(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=cfg.causal, window=win,
        )
        out = out.transpose(0, 2, 1, 3).reshape(b, s, hq_loc * hd)
        y = jax.lax.dot_general(
            out, wo, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        from repro.models.layers import psum_cast_dtype

        y = jax.lax.psum(y.astype(psum_cast_dtype(xl.dtype)), "model")
        return y.astype(xl.dtype)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dp, None, None), pos_spec, P(), P(),
            P(None, "model"), P("model"),
            P(None, None), P(None),
            P(None, None), P(None),
            P("model", None),
        ),
        out_specs=P(dp, None, None),
        check_vma=False,
    )
    # Seam accounting for the block (global workload, recorded once).
    b, s, dm = x.shape
    itemsize = jnp.dtype(x.dtype).itemsize
    engine().launch(
        cm.gemm_cost(b * s, (hq + 2 * hkv) * hd + dm, dm, itemsize, op="gemm"),
        dtype=str(x.dtype), shape_key=f"tp-attn-proj:{x.shape}",
        pallas_eligible=True,
    )
    engine().launch(
        cm.attention_cost(b, s, s, hq, hd, itemsize,
                          window=None if window is None else None),
        dtype=str(x.dtype), shape_key=f"tp-attn:{x.shape}",
        pallas_eligible=True,
    )
    return fn(
        x, positions, window_arr, theta_arr,
        p["wq"], bq, p["wk"], bk, p["wv"], bv, p["wo"],
    )


def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.init_dense(ks[0], d, hq * hd, dtype),
        "wk": L.init_dense(ks[1], d, hkv * hd, dtype),
        "wv": L.init_dense(ks[2], d, hkv * hd, dtype),
        "wo": L.init_dense(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _project_qkv(p, x, cfg, positions, rope_theta):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = blas.linear(x, p["wq"], p.get("bq")).reshape(b, s, hq, hd)
    k = blas.linear(x, p["wk"], p.get("bk")).reshape(b, s, hkv, hd)
    v = blas.linear(x, p["wv"], p.get("bv")).reshape(b, s, hkv, hd)
    if cfg.mrope:
        q = L.mrope(q, positions, rope_theta)
        k = L.mrope(k, positions, rope_theta)
    elif cfg.causal or True:  # encoders also use rotary in this zoo (conv-pos stubbed)
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = L.rope(q, pos2d, rope_theta)
        k = L.rope(k, pos2d, rope_theta)
    return q, k, v


def attention_block(
    p,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    window=None,
    rope_theta=None,
) -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, S, D)."""
    b, s, _ = x.shape
    rope_theta = rope_theta if rope_theta is not None else cfg.rope_theta
    import os as _os

    mesh = _ambient_mesh()
    if mesh is not None and not _os.environ.get("REPRO_DISABLE_TP_ATTN"):
        out = _attention_block_tp(p, x, cfg, positions, window, rope_theta, mesh)
        if out is not None:
            return out
    q, k, v = _project_qkv(p, x, cfg, positions, rope_theta)
    qh = q.transpose(0, 2, 1, 3)  # (B, Hq, S, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    eff_window = None
    if window is not None:
        eff_window = window  # may be a traced per-layer scalar
    out = blas.attention(qh, kh, vh, causal=cfg.causal, window=eff_window)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * cfg.head_dim)
    return blas.matmul(out, p["wo"])


def decode_attention_block(
    p,
    x: jax.Array,
    cache: Tuple[jax.Array, jax.Array],
    cache_index: jax.Array,
    cfg,
    *,
    window=None,
    rope_theta=None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode with a (rolling, for SWA) KV cache.

    x: (B, 1, D); cache: k/v each (B, Hkv, S_cache, hd); cache_index: ()
    int32 — number of tokens already in the cache (also the position of the
    new token).  For SWA archs ``S_cache`` is the window size and writes wrap
    (rolling buffer); positions stay absolute so RoPE is correct either way.
    """
    b, s1, _ = x.shape
    assert s1 == 1
    k_cache, v_cache = cache
    s_cache = k_cache.shape[2]
    rope_theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if cfg.mrope:
        positions = jnp.broadcast_to(cache_index, (3, b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(cache_index, (b, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, rope_theta)
    qh = q.transpose(0, 2, 1, 3)                      # (B, Hq, 1, hd)
    slot = jnp.mod(cache_index, s_cache)              # rolling for SWA
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.transpose(0, 2, 1, 3), (0, 0, slot, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.transpose(0, 2, 1, 3), (0, 0, slot, 0)
    )
    # Slot validity as a [lo, hi) range: before the first wrap only slots
    # < cache_index + 1 hold data (and a per-layer window, possibly a
    # traced scalar, bounds lo); after wrapping every slot holds one of
    # the most recent s_cache tokens (rolling buffer == SWA semantics; for
    # full-attention archs this models a fixed steady-state budget).
    hi = jnp.minimum(cache_index + 1, s_cache)
    lo = jnp.zeros((), jnp.int32)
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        unwrapped_lo = jnp.maximum(cache_index - w + 1, 0)
        lo = jnp.where(cache_index >= s_cache, 0, unwrapped_lo)

    # Dispatch through the seam: the flash-decode Pallas kernel streams the
    # cache once (serving hot loop); the masked-math path is the shardable
    # host form the dry-run lowers.
    from repro.core import cost_model as cm
    from repro.core.hero import engine

    hd = cfg.head_dim
    cost = cm.attention_cost(
        b, 1, s_cache, cfg.num_heads, hd, jnp.dtype(x.dtype).itemsize
    )
    backend = engine().launch(
        cost,
        dtype=str(x.dtype),
        shape_key=f"decode-attn:{k_cache.shape}",
        pallas_eligible=hd >= 8 and x.dtype in (jnp.float32, jnp.bfloat16),
    )
    if backend == "device-pallas":
        from repro.kernels import ops as kops

        lo_b = jnp.broadcast_to(lo, (b,)).astype(jnp.int32)
        hi_b = jnp.broadcast_to(hi, (b,)).astype(jnp.int32)
        out = kops.flash_decode(
            qh[:, :, 0, :], k_cache, v_cache, lo_b, hi_b,
            interpret=engine().policy.interpret,
        )[:, :, None, :]
    else:
        slots = jnp.arange(s_cache, dtype=jnp.int32)
        kv_valid = jnp.logical_and(slots >= lo, slots < hi)
        out = blas.attention_math(
            qh, k_cache, v_cache, causal=False, kv_mask=kv_valid
        )
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, cfg.num_heads * cfg.head_dim)
    return blas.matmul(out, p["wo"]), (k_cache, v_cache)
