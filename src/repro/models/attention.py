"""GQA attention block: QKV projections (BLAS seam) + RoPE/M-RoPE + KV cache.

Every contraction and the attention math itself dispatch through registered
``OffloadOp`` descriptors — ``qkv_project`` (fused 3-way input projection,
sequence-sharded TP shard_map as its plan), ``attention``, ``decode_attention``
and ``matmul`` (``tp_mode="row"`` gives the output projection its single
bf16-psum tensor-parallel form).  This file contains zero raw
``lax.dot_general`` launch sites and zero bare ``engine().launch`` accounting
calls: placement, cost and residency are stamped on every record by the one
dispatch path in ``repro.core.dispatch`` (guard-tested).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blas
from repro.models import layers as L

__all__ = ["init_attention", "attention_block", "decode_attention_block"]


def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.init_dense(ks[0], d, hq * hd, dtype),
        "wk": L.init_dense(ks[1], d, hkv * hd, dtype),
        "wv": L.init_dense(ks[2], d, hkv * hd, dtype),
        "wo": L.init_dense(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def split_qkv(qkv: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split the fused (..., (Hq+2·Hkv)·hd) projection into per-head q/k/v."""
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    nq, nk = hq * hd, hkv * hd
    lead = qkv.shape[:-1]
    q = qkv[..., :nq].reshape(*lead, hq, hd)
    k = qkv[..., nq : nq + nk].reshape(*lead, hkv, hd)
    v = qkv[..., nq + nk :].reshape(*lead, hkv, hd)
    return q, k, v


def _project_qkv(p, x, cfg, positions, rope_theta):
    """Fused input projection (one seam dispatch) + rotary embedding."""
    qkv = blas.qkv_project(
        x, p["wq"], p["wk"], p["wv"],
        bq=p.get("bq"), bk=p.get("bk"), bv=p.get("bv"),
    )
    q, k, v = split_qkv(qkv, cfg)
    if cfg.mrope:
        q = L.mrope(q, positions, rope_theta)
        k = L.mrope(k, positions, rope_theta)
    else:  # encoders also use rotary in this zoo (conv-pos stubbed)
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = L.rope(q, pos2d, rope_theta)
        k = L.rope(k, pos2d, rope_theta)
    return q, k, v


def attention_block(
    p,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    window=None,
    rope_theta=None,
) -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, S, D).

    Under an ambient model-parallel mesh the seam resolves the TP forms as
    descriptor plans: ``qkv_project`` sequence-shards the input projection
    (FLOPs / n_model, one tiled all-gather of the small qkv activations),
    the attention host math partitions on the q-head dim, and the output
    projection's ``tp_mode="row"`` shard_map psums once in bf16.
    """
    import os as _os

    b, s, _ = x.shape
    rope_theta = rope_theta if rope_theta is not None else cfg.rope_theta
    q, k, v = _project_qkv(p, x, cfg, positions, rope_theta)
    qh = q.transpose(0, 2, 1, 3)  # (B, Hq, S, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    eff_window = None
    if window is not None:
        eff_window = window  # may be a traced per-layer scalar
    out = blas.attention(qh, kh, vh, causal=cfg.causal, window=eff_window)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * cfg.head_dim)
    # The kill-switch disables BOTH TP forms of this block (the qkv_project
    # plan honors it inside the seam): with it set, no shard_map lowers here.
    tp_mode = None if _os.environ.get("REPRO_DISABLE_TP_ATTN") else "row"
    return blas.matmul(out, p["wo"], tp_mode=tp_mode)


def decode_attention_block(
    p,
    x: jax.Array,
    cache: Tuple[jax.Array, jax.Array],
    cache_index: jax.Array,
    cfg,
    *,
    window=None,
    rope_theta=None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode with a (rolling, for SWA) KV cache.

    x: (B, 1, D); cache: k/v each (B, Hkv, S_cache, hd); cache_index: ()
    int32 — number of tokens already in the cache (also the position of the
    new token).  For SWA archs ``S_cache`` is the window size and writes wrap
    (rolling buffer); positions stay absolute so RoPE is correct either way.
    """
    b, s1, _ = x.shape
    assert s1 == 1
    k_cache, v_cache = cache
    s_cache = k_cache.shape[2]
    rope_theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if cfg.mrope:
        positions = jnp.broadcast_to(cache_index, (3, b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(cache_index, (b, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, rope_theta)
    qh = q.transpose(0, 2, 1, 3)                      # (B, Hq, 1, hd)
    slot = jnp.mod(cache_index, s_cache)              # rolling for SWA
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.transpose(0, 2, 1, 3), (0, 0, slot, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.transpose(0, 2, 1, 3), (0, 0, slot, 0)
    )
    # Slot validity as a [lo, hi) range: before the first wrap only slots
    # < cache_index + 1 hold data (and a per-layer window, possibly a
    # traced scalar, bounds lo); after wrapping every slot holds one of
    # the most recent s_cache tokens (rolling buffer == SWA semantics; for
    # full-attention archs this models a fixed steady-state budget).
    hi = jnp.minimum(cache_index + 1, s_cache)
    lo = jnp.zeros((), jnp.int32)
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        unwrapped_lo = jnp.maximum(cache_index - w + 1, 0)
        lo = jnp.where(cache_index >= s_cache, 0, unwrapped_lo)

    # Through the seam: the flash-decode Pallas kernel streams the cache
    # once (serving hot loop); the masked-math host form is the shardable
    # path the dry-run lowers.  Routing, accounting and placement all come
    # from the registered descriptor.
    out = blas.decode_attention(qh, k_cache, v_cache, lo, hi)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, cfg.num_heads * cfg.head_dim)
    return blas.matmul(out, p["wo"]), (k_cache, v_cache)
