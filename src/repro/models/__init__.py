"""repro.models — composable model zoo; every matmul goes through repro.core.blas."""

from repro.models.model import Model, build_model, cross_entropy

__all__ = ["Model", "build_model", "cross_entropy"]
