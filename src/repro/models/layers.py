"""Shared model layers. Every contraction goes through the BLAS seam."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blas
# Re-export: the TP psum dtype policy lives with the seam now (attention.py
# and ssm.py import it from here).
from repro.core.blas import psum_cast_dtype  # noqa: F401

__all__ = [
    "rms_norm",
    "layer_norm",
    "rope",
    "mrope",
    "mlp_apply",
    "psum_cast_dtype",
    "init_dense",
    "init_norm",
]


# ---------------------------------------------------------------------------
# init helpers (pure; callers pass split keys)
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype, *, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype, *, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# norms (fp32 internals)
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, p, eps: float) -> jax.Array:
    """RMSNorm through the registered ``rmsnorm_scale`` descriptor — the
    norm epilogue every block pays is scored and traced like any other op
    (host-only: it never wins an offload alone, but the auto policy can now
    see it and the graph frontend captures it)."""
    return blas.rmsnorm_scale(x, p["scale"], eps=eps)


def layer_norm(x: jax.Array, p, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(x, p, eps, kind: str):
    return rms_norm(x, p, eps) if kind == "rmsnorm" else layer_norm(x, p, eps)


# ---------------------------------------------------------------------------
# RoPE (theta may be a traced per-layer scalar — gemma3 local/global)
# ---------------------------------------------------------------------------

def _rope_rotate(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32; theta: scalar (may be traced)."""
    d = x.shape[-1]
    half = d // 2
    theta = jnp.asarray(theta, jnp.float32)
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (B, S, half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)


def mrope(x: jax.Array, positions: jax.Array, theta, sections=(2, 3, 3)) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — temporal / height / width position streams.  The
    rotary half-dim is split into ``sections`` (2:3:3 of every 8 dims, per
    the paper), each rotated by its own stream.  Text tokens carry identical
    streams, reducing to standard RoPE.
    """
    d = x.shape[-1]
    half = d // 2
    theta = jnp.asarray(theta, jnp.float32)
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    total = sum(sections)
    # Partition the frequency axis into contiguous section bands.
    bounds = []
    start = 0
    for s in sections:
        size = (half * s) // total
        bounds.append((start, start + size))
        start += size
    bounds[-1] = (bounds[-1][0], half)
    ang_parts = []
    for (lo, hi), stream in zip(bounds, range(3)):
        pos = positions[stream].astype(jnp.float32)[..., None]     # (B, S, 1)
        ang_parts.append(pos * inv_freq[lo:hi])
    ang = jnp.concatenate(ang_parts, axis=-1)                      # (B, S, half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU) — dense FFN through the BLAS seam
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype, kind: str):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": init_dense(ks[0], d, d_ff, dtype),
            "w_up": init_dense(ks[1], d, d_ff, dtype),
            "w_down": init_dense(ks[2], d_ff, d, dtype),
        }
    return {
        "w_up": init_dense(ks[0], d, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": init_dense(ks[1], d_ff, d, dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def mlp_apply(p, x: jax.Array, kind: str) -> jax.Array:
    """Dense FFN through the registered ``mlp_block`` descriptor.

    Previously this hand-rolled the whole-block TP shard_map (raw
    ``lax.dot_general`` launch sites bypassing the seam) plus a bare
    ``engine().launch`` for the cost.  The descriptor now owns all of it:
    TP applicability is its ``plan``, the dense form its host lowering, the
    hand-tiled MXU GEMMs its Pallas lowering — one dispatch, one record,
    placement always threaded."""
    if kind == "swiglu":
        return blas.mlp_block(
            x, p["w_up"], p["w_down"], gate=p["w_gate"], kind="swiglu"
        )
    return blas.mlp_block(
        x, p["w_up"], p["w_down"], b_up=p["b_up"], b_down=p["b_down"],
        kind="gelu",
    )
