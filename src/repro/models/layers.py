"""Shared model layers. Every contraction goes through the BLAS seam."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blas

from repro.compat import shard_map

__all__ = [
    "rms_norm",
    "layer_norm",
    "rope",
    "mrope",
    "mlp_apply",
    "init_dense",
    "init_norm",
]


# ---------------------------------------------------------------------------
# init helpers (pure; callers pass split keys)
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype, *, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype, *, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# norms (fp32 internals)
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, p, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, p, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(x, p, eps, kind: str):
    return rms_norm(x, p, eps) if kind == "rmsnorm" else layer_norm(x, p, eps)


# ---------------------------------------------------------------------------
# RoPE (theta may be a traced per-layer scalar — gemma3 local/global)
# ---------------------------------------------------------------------------

def _rope_rotate(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32; theta: scalar (may be traced)."""
    d = x.shape[-1]
    half = d // 2
    theta = jnp.asarray(theta, jnp.float32)
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (B, S, half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)


def mrope(x: jax.Array, positions: jax.Array, theta, sections=(2, 3, 3)) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — temporal / height / width position streams.  The
    rotary half-dim is split into ``sections`` (2:3:3 of every 8 dims, per
    the paper), each rotated by its own stream.  Text tokens carry identical
    streams, reducing to standard RoPE.
    """
    d = x.shape[-1]
    half = d // 2
    theta = jnp.asarray(theta, jnp.float32)
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    total = sum(sections)
    # Partition the frequency axis into contiguous section bands.
    bounds = []
    start = 0
    for s in sections:
        size = (half * s) // total
        bounds.append((start, start + size))
        start += size
    bounds[-1] = (bounds[-1][0], half)
    ang_parts = []
    for (lo, hi), stream in zip(bounds, range(3)):
        pos = positions[stream].astype(jnp.float32)[..., None]     # (B, S, 1)
        ang_parts.append(pos * inv_freq[lo:hi])
    ang = jnp.concatenate(ang_parts, axis=-1)                      # (B, S, half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU) — dense FFN through the BLAS seam
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype, kind: str):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": init_dense(ks[0], d, d_ff, dtype),
            "w_up": init_dense(ks[1], d, d_ff, dtype),
            "w_down": init_dense(ks[2], d_ff, d, dtype),
        }
    return {
        "w_up": init_dense(ks[0], d, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": init_dense(ks[1], d_ff, d, dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def psum_cast_dtype(dtype):
    """Reduction dtype for TP psums. bf16 on real hardware (halves wire
    bytes); f32 on the XLA:CPU emulation backend, whose AllReducePromotion
    pass crashes cloning bf16 all-reduces produced by partially-manual
    shard_maps (observed: 'Invalid binary instruction opcode copy')."""
    import jax as _jax

    if _jax.default_backend() == "cpu" and jnp.dtype(dtype) == jnp.bfloat16:
        return jnp.float32
    return dtype


def _mlp_block_tp(p, x: jax.Array, kind: str, mesh) -> Optional[jax.Array]:
    """Whole MLP under one shard_map: d_ff column/row slices stay local,
    ONE bf16 psum forward + one backward (§Perf hillclimb #2).  GSPMD's
    schedule all-reduces the fp32 products and pays per-projection dX
    reductions.  Returns None when topology/shapes don't apply."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    if x.ndim != 3 or "model" not in getattr(mesh, "axis_names", ()):
        return None
    n_model = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    d_ff = p["w_up"].shape[1] if "w_up" in p else p["w_gate"].shape[1]
    if x.shape[0] % n_dp or d_ff % n_model or n_model <= 1:
        return None

    if kind == "swiglu":

        def local(xl, wg, wu, wd):
            g = jax.lax.dot_general(xl, wg, (((2,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            u = jax.lax.dot_general(xl, wu, (((2,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            h = (jax.nn.silu(g) * u).astype(xl.dtype)
            y = jax.lax.dot_general(h, wd, (((2,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            y = jax.lax.psum(y.astype(psum_cast_dtype(xl.dtype)), "model")
            return y.astype(xl.dtype)

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(dp, None, None), P(None, "model"), P(None, "model"),
                      P("model", None)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )
        _record_mlp_cost(x, d_ff, 3)
        return fn(x, p["w_gate"], p["w_up"], p["w_down"])

    def local_gelu(xl, wu, bu, wd, bd):
        h = jax.lax.dot_general(xl, wu, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) + bu
        h = jax.nn.gelu(h).astype(xl.dtype)
        y = jax.lax.dot_general(h, wd, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        y = jax.lax.psum(y.astype(psum_cast_dtype(xl.dtype)), "model")
        return y.astype(xl.dtype) + bd.astype(xl.dtype)

    fn = shard_map(
        local_gelu, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, "model"), P("model"),
                  P("model", None), P(None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )
    _record_mlp_cost(x, d_ff, 2)
    return fn(x, p["w_up"], p["b_up"], p["w_down"], p["b_down"])


def _record_mlp_cost(x, d_ff, n_mats):
    from repro.core import cost_model as cm
    from repro.core.hero import engine

    b, s, d = x.shape
    engine().launch(
        cm.gemm_cost(b * s, d_ff * n_mats, d, jnp.dtype(x.dtype).itemsize),
        dtype=str(x.dtype), shape_key=f"tp-mlp:{x.shape}x{d_ff}",
        pallas_eligible=True,
    )


def mlp_apply(p, x: jax.Array, kind: str) -> jax.Array:
    import os as _os

    from repro.sharding.annotate import _ambient_mesh

    mesh = _ambient_mesh()
    if mesh is not None and not _os.environ.get("REPRO_DISABLE_TP_MLP"):
        y = _mlp_block_tp(p, x, kind, mesh)
        if y is not None:
            return y
    if kind == "swiglu":
        g = blas.matmul(x, p["w_gate"])
        u = blas.matmul(x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return blas.matmul(h, p["w_down"])
    h = blas.linear(x, p["w_up"], p["b_up"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return blas.linear(h, p["w_down"], p["b_down"])
