"""Deterministic, shardable token data pipeline.

Two sources behind one iterator interface:

  * ``SyntheticLM``   — seeded Zipfian token stream (training smoke/e2e runs
    need realistic rank-frequency structure, not uniform noise);
  * ``MemmapTokens``  — flat binary token file (np.memmap), the standard
    "tokenized corpus on shared storage" layout used by real clusters.

Determinism + fault tolerance: batch ``i`` is a pure function of
(seed, step) — after a restart the pipeline resumes from the step recorded
in the checkpoint with no stream state to persist.  Multi-host sharding:
each host materializes only its ``(host_id, num_hosts)`` slice of the
global batch (``local_batch``), matching the pjit data-sharding layout.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "MemmapTokens", "make_batches"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    host_id: int = 0
    num_hosts: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        # Zipf over a capped support, mapped into the vocab.
        raw = rng.zipf(self.zipf_a, size=(self.local_batch, self.seq_len + 1))
        tokens = (raw - 1) % self.vocab_size
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass
class MemmapTokens:
    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        n_seqs = (len(self._data) - 1) // self.seq_len
        if n_seqs < 1:
            raise ValueError(f"{self.path}: too small for seq_len={self.seq_len}")
        self._n_seqs = n_seqs

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        idx = rng.integers(0, self._n_seqs, size=self.local_batch)
        starts = idx * self.seq_len
        tok = np.stack(
            [self._data[s : s + self.seq_len + 1] for s in starts]
        ).astype(np.int32)
        tok %= self.vocab_size
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


def make_batches(source, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield source.batch(step)
        step += 1
