"""repro.data — deterministic sharded token pipeline."""

from repro.data.pipeline import MemmapTokens, SyntheticLM, make_batches

__all__ = ["MemmapTokens", "SyntheticLM", "make_batches"]
