"""``repro.hnp`` — the lazy NumPy-like frontend (alias of
:mod:`repro.frontend.api`).

::

    import repro.hnp as hnp

    h = hnp.tanh(hnp.array(x) @ w1)
    y = h @ w2                      # nothing has executed yet
    out = hnp.asnumpy(y)            # the whole graph lowers onto the cluster

Any op registered in :mod:`repro.core.dispatch` is reachable here by name
(``hnp.gemm``, ``hnp.attention``, ...) — resolved lazily against the
registry, so new descriptors appear with zero frontend changes.
"""

from repro.frontend.api import *  # noqa: F401,F403
from repro.frontend import api as _api
from repro.frontend.api import __all__  # noqa: F401


def __getattr__(name: str):
    # Delegate unknown names to the api module's registry passthrough.
    return getattr(_api, name)
