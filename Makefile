PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: collect check test bench bench-smoke ci

# Fast gate: the whole suite must *collect* with zero errors (seconds, not
# minutes) — catches missing-dependency and import-drift regressions before
# any test runs.
collect:
	$(PYTHON) -m pytest --collect-only -q

# Tier-1 verify: collect gate first, then the suite.
check: collect
	$(PYTHON) -m pytest -x -q

test: check

bench:
	PYTHONPATH=src:. $(PYTHON) -m benchmarks.cluster_scaling

# Perf trajectory gate: fast modeled sweeps -> BENCH_offload.json (gemm
# sweep, cluster scaling, serve makespan pinned vs unpinned).
bench-smoke:
	PYTHONPATH=src:. $(PYTHON) -m benchmarks.run --smoke

# CI entry point: tier-1 suite, then the perf snapshot.
ci: check bench-smoke
