PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: collect check test bench bench-smoke bench-gate ci frontend import-time lint trace trace-smoke

# Frontend import-time gate: every repro.frontend module (and repro.hnp)
# must import in <1s cold — the lazy layer stays import-light (no
# module-scope jax).  Fails `make collect` on regression.
import-time:
	$(PYTHON) tools/check_import_time.py

# Fast gate: the whole suite must *collect* with zero errors (seconds, not
# minutes) — catches missing-dependency and import-drift regressions before
# any test runs.
collect: import-time
	$(PYTHON) -m pytest --collect-only -q

# Tier-1 verify: collect gate first, then the suite.
check: collect
	$(PYTHON) -m pytest -x -q

test: check

# Static-analysis gate (repro.analysis): the AST lint rules + registry
# closure over src/, then the graph verifier + stream race detector over a
# smoke hnp workload (validate=True region on a 4-device modeled cluster).
lint:
	$(PYTHON) tools/repro_lint.py
	$(PYTHON) tools/repro_lint.py --smoke-races

# The hnp graph-frontend suite in isolation (parity, fusion, batching,
# residency threading) — the fast loop while working on repro/frontend.
frontend:
	$(PYTHON) -m pytest tests/test_frontend.py -q

bench:
	PYTHONPATH=src:. $(PYTHON) -m benchmarks.cluster_scaling

# Perf trajectory gate: fast modeled sweeps -> BENCH_offload.json (gemm
# sweep, pipelined staging, cluster scaling, serve makespan pinned vs
# unpinned, hnp fused graph vs eager chain) + one deduped headline line in
# BENCH_trajectory.jsonl.
bench-smoke:
	PYTHONPATH=src:. $(PYTHON) -m benchmarks.run --smoke

# Headline assertions over the smoke artifacts: pipelined_speedup >= 1.3,
# tpu-v5e large-n steady copy_fraction < 0.6, n=2048 offload within 15% of
# max(copy, compute), trajectory free of duplicate headline lines, plus the
# obs contract: trace_smoke.json non-empty with every ticket covered by a
# span, and a metrics snapshot in BENCH_offload.json.
bench-gate:
	PYTHONPATH=src:. $(PYTHON) tools/check_bench_gate.py

# Perfetto trace of the smoke workloads (gemm chain / hnp graph / streaming
# burst) + top-10 self-time per lane on stdout.  Load trace.json at
# https://ui.perfetto.dev.
trace:
	$(PYTHON) tools/repro_trace.py --smoke --summary -o trace.json

# CI artifact flavor: same capture, no summary, fixed filename the bench
# gate's check_obs pass reads back.
trace-smoke:
	$(PYTHON) tools/repro_trace.py --smoke -o trace_smoke.json

# CI entry point: tier-1 suite, the static-analysis gate, then the perf
# snapshot + trace capture + headline gate.
ci: check lint bench-smoke trace-smoke bench-gate
