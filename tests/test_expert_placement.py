"""Dynamic expert placement: determinism, parity, hysteresis, token books.

The placement policy is pure modeled control flow (no wall clock, no jax
tracing) — every test here is exact: same seed => same decisions, policy
off => bitwise-equal MoE output, routed = processed + dropped to the
token.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.races import (
    check_expert_migrations,
    check_ticket_streams,
)
from repro.configs import get_arch
from repro.core import engine, offload_policy
from repro.core.placement import (
    ExpertPlacementPolicy,
    MigrationEdge,
    PlacementConfig,
    _split_tokens,
    placement_sweep,
    run_skewed_workload,
    zipf_histogram,
    zipf_shares,
)
from repro.models import moe as M
from repro.obs import metrics as obs_metrics

CFG = dataclasses.replace(
    get_arch("qwen3-moe-30b-a3b").reduced(), moe_dispatch="grouped"
)


def _setup(seed=0, b=2, s=8):
    rng = jax.random.PRNGKey(seed)
    params = M.init_moe(rng, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, CFG.d_model)) * 0.3
    return params, x


# ---------------------------------------------------------------------------
# Same-seed determinism
# ---------------------------------------------------------------------------

def test_same_seed_same_decisions_and_makespan():
    a = run_skewed_workload(zipf_s=1.2, seed=7, dynamic=True, steps=48)
    b = run_skewed_workload(zipf_s=1.2, seed=7, dynamic=True, steps=48)
    assert a.decision_log == b.decision_log
    assert a.makespan_s == b.makespan_s
    assert a.tokens_dropped == b.tokens_dropped


def test_different_seed_may_differ_but_conserves():
    r = run_skewed_workload(zipf_s=1.2, seed=11, dynamic=True, steps=48)
    assert r.tokens_routed == r.tokens_processed + r.tokens_dropped


# ---------------------------------------------------------------------------
# Static-vs-dynamic bitwise parity (policy off => same numbers out)
# ---------------------------------------------------------------------------

def test_policy_off_bitwise_parity():
    params, x = _setup()
    want, aux_want = M.moe_ffn(params, x, CFG)
    for policy in (None, ):
        got, aux_got = M.moe_ffn_placed(params, x, CFG, policy=policy)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        assert np.array_equal(np.asarray(aux_got), np.asarray(aux_want))


def test_policy_disabled_bitwise_parity():
    params, x = _setup()
    want, _ = M.moe_ffn(params, x, CFG)
    with offload_policy(mode="device", platform="tpu-v5e", num_devices=4):
        pol = ExpertPlacementPolicy(
            PlacementConfig(num_experts=CFG.num_experts, enabled=False),
            engine(),
        )
        pol.attach()
        got, _ = M.moe_ffn_placed(params, x, CFG, policy=pol)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_policy_enabled_changes_accounting_not_math():
    """The fan-out path replaces launch bookkeeping only: with the policy
    live (handles pinned, histogram fed, sub-launches issued) the layer
    output stays bitwise-equal to the static grouped path."""
    params, x = _setup(b=4, s=16)
    want, _ = M.moe_ffn(params, x, CFG)
    with offload_policy(mode="device", platform="tpu-v5e", num_devices=4):
        pol = ExpertPlacementPolicy(
            PlacementConfig(num_experts=CFG.num_experts), engine()
        )
        pol.attach()
        got, _ = M.moe_ffn_placed(params, x, CFG, policy=pol)
        fanned = sum(
            1 for dev in engine().devices for t in dev.inflight
            if t.op == "moe_expert_ffn"
        )
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert fanned > 1  # per-expert sub-launches actually fanned out


# ---------------------------------------------------------------------------
# Migration hysteresis — no ping-pong under an oscillating histogram
# ---------------------------------------------------------------------------

def test_hysteresis_no_ping_pong():
    e, tokens = 16, 1024
    hot = [tokens - 15 * 20] + [20] * (e - 1)       # expert 0 dominates
    cold = [20] + [(tokens - 20) // (e - 1)] * (e - 1)
    with offload_policy(mode="device", platform="tpu-v5e", num_devices=4):
        pol = ExpertPlacementPolicy(PlacementConfig(num_experts=e), engine())
        pol.attach()
        for i in range(64):
            pol.step(hot if i % 2 == 0 else cold)
        moves = [d for d in pol.decisions
                 if d.kind == "migrate" and d.expert == 0]
    # The amortization margin, not the trigger cadence, kills ping-pong:
    # once expert 0 sits on its best lane the return move never pays.
    assert len(moves) <= 1
    # and it never bounced straight back where it came from
    if moves:
        assert moves[0].src_device != moves[0].dst_device


# ---------------------------------------------------------------------------
# Replica token-split correctness
# ---------------------------------------------------------------------------

def test_split_tokens_laws():
    parts, dropped = _split_tokens(1000, 2, 256)
    assert parts == [256, 256] and dropped == 488
    parts, dropped = _split_tokens(100, 2, 256)
    assert sum(parts) == 100 and dropped == 0
    assert max(parts) - min(parts) <= 1   # even split, remainder first
    parts, dropped = _split_tokens(7, 1, None)
    assert parts == [7] and dropped == 0


def test_replica_token_split_in_plan():
    e = 8
    with offload_policy(mode="device", platform="tpu-v5e", num_devices=4):
        cluster = engine()
        pol = ExpertPlacementPolicy(PlacementConfig(num_experts=e), cluster)
        pol.attach()
        home = pol.home[0]
        replica_lane = next(l for l in pol.lanes if l != home)
        cluster.replicate_handle(pol.handles[0], replica_lane)
        pol.replica_lanes[0].append(replica_lane)
        hist = [1000] + [10] * (e - 1)
        plan = pol.plan(hist, capacity=256)
        subs0 = [s for s in plan.sub_launches if s.expert == 0]
    assert {s.device_id for s in subs0} == {home, replica_lane}
    assert [s.tokens for s in subs0] == [256, 256]   # cap per copy
    assert plan.dropped_by_expert[0] == 1000 - 512
    assert plan.tokens_routed == sum(hist)
    assert plan.tokens_routed == plan.tokens_processed + plan.tokens_dropped


def test_replication_fires_under_extreme_skew():
    r = run_skewed_workload(zipf_s=1.8, seed=0, dynamic=True)
    assert r.replications >= 1
    # the replica relieves capacity pressure: fewer drops than static
    s = run_skewed_workload(zipf_s=1.8, seed=0, dynamic=False)
    assert r.tokens_dropped < s.tokens_dropped


# ---------------------------------------------------------------------------
# Zipf-skew makespan acceptance + race-freedom of the real workload
# ---------------------------------------------------------------------------

def test_zipf_skew_dynamic_beats_static():
    stat = run_skewed_workload(zipf_s=1.2, seed=0, dynamic=False)
    dyn = run_skewed_workload(zipf_s=1.2, seed=0, dynamic=True)
    assert dyn.makespan_s <= stat.makespan_s
    assert stat.makespan_s / dyn.makespan_s >= 1.2   # the gated headline
    assert dyn.migrations + dyn.replications >= 1


def test_skewed_workload_is_race_free():
    r = run_skewed_workload(zipf_s=1.2, seed=0, dynamic=True)
    assert check_ticket_streams(r.ticket_streams) == []
    assert check_expert_migrations(r.migration_edges) == []
    for edge in r.migration_edges:
        assert edge.migrate_issue_s >= edge.src_drain_s - 1e-9


def test_migration_race_rule_flags_early_d2d():
    bad = MigrationEdge(
        expert=3, handle_name="moe/expert3", src_device=0, dst_device=2,
        migrate_issue_s=1.0, src_drain_s=2.0,
    )
    v = check_expert_migrations([bad])
    assert len(v) == 1
    assert v[0].rule == "race/expert-migrate-before-drain"


def test_sweep_json_safe_and_conserving():
    import json

    sw = placement_sweep(zipf_points=(1.2,), steps=32, tokens_per_step=512)
    json.dumps(sw)   # artifact must serialize as-is
    (p,) = sw["points"]
    assert p["seed"] == sw["seed"]
    for side in ("static", "dynamic"):
        assert p[side]["tokens_unaccounted"] == 0


# ---------------------------------------------------------------------------
# Dropped-token accounting (satellite 2)
# ---------------------------------------------------------------------------

def test_zipf_shares_normalized():
    sh = zipf_shares(16, 1.2)
    assert abs(sum(sh) - 1.0) < 1e-12
    assert sh == sorted(sh, reverse=True)
    import random

    hist = zipf_histogram(random.Random(0), 16, 1.2, 4096)
    assert sum(hist) == 4096 and len(hist) == 16


def test_policy_drop_counters_and_books():
    e = 8
    with offload_policy(mode="device", platform="tpu-v5e", num_devices=4):
        pol = ExpertPlacementPolicy(PlacementConfig(num_experts=e), engine())
        pol.attach()
        with obs_metrics.collect() as reg:
            pol.plan([1000] + [10] * (e - 1), capacity=64)
        rollup = reg.rollup()
    assert pol.tokens_routed == pol.tokens_processed + pol.tokens_dropped
    assert pol.tokens_dropped == 1000 - 64
    assert pol.dropped_by_expert[0] == 936
    assert sum(pol.dropped_by_expert) == 936
    assert rollup.get("moe.tokens_dropped{expert=0}") == 936.0


def test_moe_step_trace_drop_rate():
    cfg = dataclasses.replace(CFG, capacity_factor=0.1)   # force drops
    params, x = _setup(b=4, s=16)
    with obs_metrics.collect() as reg:
        M.moe_ffn(params, x, cfg)
        trace = M.last_moe_step()
    assert trace is not None
    assert trace.tokens_dropped > 0
    assert trace.tokens_routed == int(np.asarray(trace.counts).sum())
    assert trace.drop_rate == pytest.approx(
        trace.tokens_dropped / trace.tokens_routed)
    dropped_metric = sum(
        v for k, v in reg.rollup().items()
        if k.startswith("moe.tokens_dropped")
    )
    assert dropped_metric == float(trace.tokens_dropped)


def test_moe_step_trace_no_drops_at_high_capacity():
    params, x = _setup()
    M.moe_ffn(params, x, CFG)
    trace = M.last_moe_step()
    assert trace is not None and trace.drop_rate == 0.0
