"""Streaming serve engine (``repro.launch.streaming``).

Property-style coverage for the ISSUE 8 invariants: same-seed determinism
(identical event streams, no wall-clock reads), admission control (rejected
requests never reach a device timeline), the decode slot pool bound, p99
monotonicity in offered load, the slot-refill happens-before edge (paired
with its ``race/slot-refill-before-complete`` rule), and the headline
acceptance — continuous batching beating the lock-step baseline on the
same bursty trace.  Everything runs on modeled time with the full (non
reduced) arch config: no model is built, so these are fast.
"""

import dataclasses

import pytest

from repro.analysis.races import (
    check_slot_refills,
    check_ticket_streams,
)
from repro.core import accounting
from repro.launch.streaming import (
    SLO,
    ArrivalTrace,
    StreamConfig,
    bursty_trace,
    estimate_capacity,
    offered_load_sweep,
    poisson_trace,
    replay_trace,
    scale_trace,
    serve_lockstep,
    serve_stream,
)

ARCH = "yi-6b"


def small_cfg(**kw) -> StreamConfig:
    return StreamConfig(**{"num_devices": 4, "prefill_lanes": 1,
                           "decode_slots": 8, **kw})


# ---------------------------------------------------------------------------
# arrival generators
# ---------------------------------------------------------------------------

def test_generators_are_seed_deterministic():
    a = poisson_trace(80.0, 1.0, seed=3)
    b = poisson_trace(80.0, 1.0, seed=3)
    assert a.requests == b.requests
    c = poisson_trace(80.0, 1.0, seed=4)
    assert c.requests != a.requests
    x = bursty_trace(80.0, 1.0, seed=3)
    y = bursty_trace(80.0, 1.0, seed=3)
    assert x.requests == y.requests


def test_bursty_trace_is_bursty_but_rate_matched():
    t = bursty_trace(100.0, 2.0, seed=0, burst_factor=3.0,
                     burst_fraction=0.3, period_s=0.25)
    # average rate lands near the requested qps
    assert 0.7 * 100.0 < t.offered_qps < 1.3 * 100.0
    # arrival density inside burst windows beats the quiet windows
    hot = sum(1 for r in t.requests if (r.arrival_s % 0.25) / 0.25 < 0.3)
    cold = len(t.requests) - hot
    assert hot / 0.3 > cold / 0.7


def test_scale_trace_preserves_population_and_compresses_time():
    base = bursty_trace(50.0, 1.0, seed=1)
    hot = scale_trace(base, 2.0)
    assert len(hot.requests) == len(base.requests)
    for r0, r1 in zip(base.requests, hot.requests):
        assert (r1.prompt_len, r1.output_len, r1.req_class) == (
            r0.prompt_len, r0.output_len, r0.req_class
        )
        assert r1.arrival_s == pytest.approx(r0.arrival_s / 2.0)
        # deadline budget rides along unchanged
        if r0.deadline_s:
            assert r1.deadline_s - r1.arrival_s == pytest.approx(
                r0.deadline_s - r0.arrival_s
            )
    assert hot.offered_qps == pytest.approx(2.0 * base.offered_qps)


def test_replay_trace_sorts_and_stamps_deadlines():
    t = replay_trace([(0.5, 8, 4), (0.1, 16, 2)], deadline_budget_s=1.0)
    assert [r.arrival_s for r in t.requests] == [0.1, 0.5]
    assert t.requests[0].deadline_s == pytest.approx(1.1)


def test_stream_config_validation():
    with pytest.raises(ValueError):
        StreamConfig(admission="bogus")
    with pytest.raises(ValueError):
        StreamConfig(num_devices=2, prefill_lanes=2)


# ---------------------------------------------------------------------------
# determinism: the regression the seed satellite asks for
# ---------------------------------------------------------------------------

def test_same_seed_runs_produce_identical_event_streams():
    trace = bursty_trace(100.0, 0.6, seed=11)
    r1 = serve_stream(ARCH, trace, config=small_cfg())
    r2 = serve_stream(ARCH, trace, config=small_cfg())
    assert r1.events == r2.events
    assert r1.point_dict() == r2.point_dict()
    assert [len(v) for v in r1.ticket_log.values()] == [
        len(v) for v in r2.ticket_log.values()
    ]


def test_different_seed_changes_the_event_stream():
    r1 = serve_stream(ARCH, bursty_trace(100.0, 0.6, seed=11),
                      config=small_cfg())
    r2 = serve_stream(ARCH, bursty_trace(100.0, 0.6, seed=12),
                      config=small_cfg())
    assert r1.events != r2.events


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def overload_trace(seed=0, duration=0.6):
    cap = estimate_capacity(ARCH, small_cfg())
    return bursty_trace(3.0 * cap, duration, seed=seed)


def test_rejected_requests_never_appear_in_device_timelines():
    cfg = small_cfg(admission="queue", max_queue=4)
    rep = serve_stream(ARCH, overload_trace(), config=cfg)
    rejected = [m for m in rep.metrics if not m.admitted]
    assert rejected, "overload with a 4-deep queue must shed load"
    keys = {
        t.shape_key for stream in rep.ticket_log.values() for t in stream
    }
    for m in rejected:
        assert f"prefill-{m.rid}" not in keys
        assert f"kv-{m.rid}" not in keys
        assert m.tokens_out == 0
        assert m.first_token_s == 0.0
        assert not m.completed


def test_slo_admission_sheds_load_and_protects_the_tail():
    rep = serve_stream(ARCH, overload_trace(), config=small_cfg())
    assert rep.reject_rate > 0.0
    # backpressure is the mechanism that keeps the *served* population
    # inside SLO at 3x overload
    assert rep.slo.meets_slo, rep.slo.as_dict()


def test_admission_none_serves_everything():
    rep = serve_stream(
        ARCH, bursty_trace(60.0, 0.5, seed=2),
        config=small_cfg(admission="none"),
    )
    assert rep.rejected == 0
    assert rep.completed == rep.admitted == len(rep.metrics)


# ---------------------------------------------------------------------------
# slot pool + refill happens-before
# ---------------------------------------------------------------------------

def test_decode_slots_never_exceed_pool_size():
    cfg = small_cfg(num_devices=2, decode_slots=4)   # single decode lane
    rep = serve_stream(ARCH, bursty_trace(80.0, 0.6, seed=5), config=cfg)
    assert 0 < rep.max_active_slots <= 4
    multi = small_cfg(decode_slots=6)
    rep2 = serve_stream(ARCH, bursty_trace(150.0, 0.6, seed=5), config=multi)
    assert rep2.max_active_slots <= 6 * (multi.num_devices - multi.prefill_lanes)


def test_slot_refill_issued_at_or_after_freeing_complete():
    rep = serve_stream(ARCH, bursty_trace(120.0, 0.8, seed=7),
                       config=small_cfg())
    assert rep.slot_refills, "a busy run must exercise the refill path"
    for r in rep.slot_refills:
        assert r.refill_issue_s >= r.freed_complete_s - 1e-9
    assert check_slot_refills(rep.slot_refills) == []


def test_slot_refill_race_rule_fires_on_corrupted_edge():
    rep = serve_stream(ARCH, bursty_trace(120.0, 0.5, seed=7),
                       config=small_cfg())
    bad = dataclasses.replace(
        rep.slot_refills[0],
        refill_issue_s=rep.slot_refills[0].freed_complete_s - 1e-3,
    )
    violations = check_slot_refills([bad])
    assert [v.rule for v in violations] == ["race/slot-refill-before-complete"]


def test_streaming_ticket_streams_are_race_free():
    rep = serve_stream(ARCH, bursty_trace(120.0, 0.8, seed=9),
                       config=small_cfg())
    violations = check_ticket_streams(rep.ticket_log)
    assert violations == [], "\n".join(v.render() for v in violations)
    # disaggregation really ran: prefill lane issued prefills, decode
    # lanes issued steps and received kv migrations
    kinds = {t.kind for s in rep.ticket_log.values() for t in s}
    assert "d2d" in kinds and "launch" in kinds


def test_adaptive_controller_stays_in_bounds():
    rep = serve_stream(ARCH, overload_trace(seed=3), config=small_cfg())
    assert 1 <= rep.min_slot_target <= small_cfg().decode_slots


# ---------------------------------------------------------------------------
# latency properties
# ---------------------------------------------------------------------------

def test_p99_ttft_monotone_non_decreasing_in_offered_load():
    # fixed seed, identical population, admission and adaptivity off:
    # more offered load can only deepen queues
    cfg = small_cfg(admission="none", adaptive=False)
    cap = estimate_capacity(ARCH, cfg)
    base = bursty_trace(1.5 * cap, 1.0, seed=0)
    p99s = []
    for u in (0.4, 0.8, 1.5):
        rep = serve_stream(ARCH, scale_trace(base, u / 1.5), config=cfg)
        p99s.append(rep.slo.overall.ttft.p99_s)
    assert p99s[0] <= p99s[1] + 1e-9
    assert p99s[1] <= p99s[2] + 1e-9


def test_request_metrics_are_causally_ordered():
    rep = serve_stream(ARCH, bursty_trace(90.0, 0.5, seed=4),
                       config=small_cfg())
    for m in rep.metrics:
        if not m.completed:
            continue
        assert m.arrival_s <= m.prefill_done_s <= m.first_token_s <= m.finish_s
        assert m.tokens_out == m.output_len
        assert len(m.token_latencies_s) == m.output_len - 1
        assert all(lat > 0 for lat in m.token_latencies_s)


# ---------------------------------------------------------------------------
# the headline: continuous batching vs lock-step on the same trace
# ---------------------------------------------------------------------------

def test_continuous_beats_lockstep_on_same_bursty_trace():
    cfg = small_cfg()
    cap = estimate_capacity(ARCH, cfg)
    trace = bursty_trace(2.0 * cap, 1.0, seed=0)
    cont = serve_stream(ARCH, trace, config=cfg)
    lock = serve_lockstep(ARCH, trace, config=cfg)
    assert cont.sustained_qps >= 1.3 * lock.sustained_qps
    # lock-step's batch-forming wait shows up exactly where expected
    assert lock.slo.overall.ttft.p99_s > cont.slo.overall.ttft.p99_s


def test_offered_load_sweep_produces_the_bench_section():
    sweep = offered_load_sweep(ARCH, utils=(0.5, 1.0, 2.0), seed=0)
    assert len(sweep["points"]) == 3
    assert sweep["seed"] == 0
    for p in sweep["points"]:
        for key in ("sustained_qps", "reject_rate", "ttft_p99_ms",
                    "per_token_p99_ms"):
            assert key in p
    assert sweep["max_qps_at_slo"] > 0
    assert sweep["continuous_vs_lockstep"]["speedup"] >= 1.3


# ---------------------------------------------------------------------------
# SLO accounting primitives (core/accounting.py additions)
# ---------------------------------------------------------------------------

def test_percentile_is_linear_interpolation():
    assert accounting.percentile([], 99) == 0.0
    assert accounting.percentile([5.0], 50) == 5.0
    vals = [1.0, 2.0, 3.0, 4.0]
    assert accounting.percentile(vals, 0) == 1.0
    assert accounting.percentile(vals, 100) == 4.0
    assert accounting.percentile(vals, 50) == pytest.approx(2.5)


def test_slo_report_excludes_rejected_and_classes_roll_up():
    mk = accounting.RequestMetrics
    ms = [
        mk(rid=0, req_class="a", arrival_s=0.0, prompt_len=4, output_len=2,
           first_token_s=0.1, finish_s=0.2, tokens_out=2,
           token_latencies_s=[0.1]),
        mk(rid=1, req_class="b", arrival_s=0.0, prompt_len=4, output_len=2,
           first_token_s=0.3, finish_s=0.5, tokens_out=2,
           token_latencies_s=[0.2]),
        mk(rid=2, req_class="a", arrival_s=0.0, prompt_len=4, output_len=2,
           admitted=False),
    ]
    rep = accounting.slo_report(ms, ttft_slo_s=0.4, per_token_slo_s=0.3)
    assert set(rep.classes) == {"a", "b", "all"}
    assert rep.overall.requests == 2          # the rejected one is excluded
    assert rep.overall.ttft.max_s == pytest.approx(0.3)
    assert rep.meets_slo
    tight = accounting.slo_report(ms, ttft_slo_s=0.2)
    assert not tight.meets_slo


def test_lockstep_report_is_well_formed():
    trace = bursty_trace(60.0, 0.4, seed=1)
    rep = serve_lockstep(ARCH, trace, config=small_cfg())
    assert rep.engine == "lockstep"
    assert rep.completed == len(trace.requests)
    assert rep.slot_refills == []
    assert check_ticket_streams(rep.ticket_log) == []
