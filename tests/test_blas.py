"""The BLAS seam: numerical equivalence across dispatch policies + tracing."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blas, engine, offload_policy, offload_trace
from repro.kernels import ref

RNG = np.random.default_rng(42)


def _randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.fixture(autouse=True)
def _reset_engine():
    engine().reset()
    yield
    engine().reset()


@pytest.mark.parametrize("policy", ["host", "device", "auto"])
def test_gemm_same_result_any_policy(policy):
    a, b = _randn(64, 48), _randn(48, 80)
    expect = np.asarray(a) @ np.asarray(b)
    with offload_policy(mode=policy):
        got = blas.gemm(a, b)
    np.testing.assert_allclose(np.asarray(got), expect, rtol=2e-5, atol=2e-5)


def test_gemm_pallas_interpret_matches():
    a, b = _randn(96, 64), _randn(64, 96)
    with offload_policy(mode="device", use_pallas=True, interpret=True):
        got = blas.gemm(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a) @ np.asarray(b), rtol=2e-5, atol=2e-5
    )


def test_gemm_transposes():
    a, b = _randn(32, 64), _randn(48, 32)
    got = blas.gemm(a, b, transpose_a=True, transpose_b=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a).T @ np.asarray(b).T, rtol=2e-5, atol=2e-5
    )


def test_matmul_leading_dims():
    x, w = _randn(4, 7, 32), _randn(32, 16)
    got = blas.matmul(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x) @ np.asarray(w), rtol=2e-5, atol=2e-5
    )


def test_gemm_batched():
    a, b = _randn(5, 24, 16), _randn(5, 16, 8)
    got = blas.gemm_batched(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a) @ np.asarray(b), rtol=2e-5, atol=2e-5
    )


def test_syrk_host_only_and_correct():
    a = _randn(24, 40)
    with offload_policy(mode="device", use_pallas=True, interpret=True):
        with offload_trace() as t:
            got = blas.syrk(a)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a) @ np.asarray(a).T, rtol=2e-5, atol=2e-5
    )
    (rec,) = t.records
    assert rec.backend != "device-pallas"  # syrk.c compiled host-only (paper)


def test_vector_ops():
    x, y = _randn(128), _randn(128)
    np.testing.assert_allclose(
        float(blas.dot(x, y)), float(np.dot(np.asarray(x), np.asarray(y))),
        rtol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(blas.axpy(2.0, x, y)),
        2.0 * np.asarray(x) + np.asarray(y), rtol=1e-6,
    )
    np.testing.assert_allclose(
        float(blas.nrm2(x)), float(np.linalg.norm(np.asarray(x))), rtol=1e-5
    )


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16), (False, None)])
def test_attention_host_vs_ref(causal, window):
    q = _randn(2, 4, 48, 16)
    k = _randn(2, 2, 48, 16)
    v = _randn(2, 2, 48, 16)
    got = blas.attention(q, k, v, causal=causal, window=window)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_attention_chunked_long_kv_matches_direct():
    """Force the chunked online-softmax path and compare against ref."""
    import repro.core.blas as B

    old = B._DIRECT_ATTN_MAX_KV
    B._DIRECT_ATTN_MAX_KV = 32  # force chunking
    try:
        q = _randn(1, 2, 64, 8)
        k = _randn(1, 2, 64, 8)
        v = _randn(1, 2, 64, 8)
        got = blas.attention(q, k, v, causal=True)
    finally:
        B._DIRECT_ATTN_MAX_KV = old
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_traced_window_matches_static():
    q, k, v = _randn(1, 2, 32, 8), _randn(1, 2, 32, 8), _randn(1, 2, 32, 8)
    got = blas.attention(q, k, v, causal=True, window=jnp.int32(8))
    want = ref.attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_trace_records_regions():
    a, b = _randn(256, 256), _randn(256, 256)
    with offload_policy(mode="device", platform="hesoc-vcu128"):
        with offload_trace() as t:
            blas.gemm(a, b)
    (rec,) = t.records
    assert rec.backend.startswith("device")
    assert rec.regions.copy_s > 0 and rec.regions.compute_s > 0
    assert rec.cost.flops == 2 * 256**3


def test_auto_policy_small_stays_host_on_hesoc():
    with offload_policy(mode="auto", platform="hesoc-vcu128"):
        with offload_trace() as t:
            blas.gemm(_randn(16, 16), _randn(16, 16))
    assert t.records[0].backend == "host"


def test_engine_boots_on_first_offload():
    eng = engine()
    assert not eng.booted
    with offload_policy(mode="device"):
        blas.gemm(_randn(32, 32), _randn(32, 32))
    assert eng.booted
