"""Optimizers: AdamW math, 8-bit moment quantization, grad compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.optim import (
    adamw,
    adamw8bit,
    clip_by_global_norm,
    compress_decompress,
    compressed_psum,
    constant,
    init_error_buffer,
    warmup_cosine,
)
from repro.optim.adamw import QBLOCK, _dequantize, _quantize


def _params():
    return {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]]), "b": jnp.asarray([0.1, -0.1])}


def test_adamw_first_step_matches_reference():
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.0
    init, update = adamw(constant(lr), b1=b1, b2=b2, eps=eps, weight_decay=wd,
                         max_grad_norm=1e9)
    p = _params()
    st_ = init(p)
    g = jax.tree_util.tree_map(jnp.ones_like, p)
    p2, st2 = update(g, st_, p)
    # bias-corrected first step of Adam with unit grads = lr * 1/(1+eps')
    for leaf in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda a, b_: a - b_, p, p2)
    ):
        np.testing.assert_allclose(np.asarray(leaf), lr, rtol=1e-4)


def test_weight_decay_pulls_to_zero():
    init, update = adamw(constant(0.1), weight_decay=0.5, max_grad_norm=1e9)
    p = {"w": jnp.asarray([10.0])}
    st_ = init(p)
    g = {"w": jnp.asarray([0.0])}
    p2, _ = update(g, st_, p)
    assert float(p2["w"][0]) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 20.0, rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5
    )


@given(st.integers(1, 2000), st.floats(0.01, 100.0))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bounded(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    qt = _quantize(x)
    y = _dequantize(qt, x.shape)
    # blockwise int8: per-element error <= its block max / 254 (global max
    # is a valid, looser bound for any block layout)
    bound = float(np.abs(np.asarray(x)).max()) / 127.0 * 0.5 + 1e-6
    assert np.all(np.abs(np.asarray(y - x)) <= bound)
    # shape-preserving payload, axis-aligned scales
    assert qt.q.shape == x.shape
    assert x.shape[-1] % qt.scale.shape[-1] == 0


def test_adamw8bit_tracks_fp32_closely():
    init32, up32 = adamw(constant(0.05), max_grad_norm=1e9)
    init8, up8 = adamw8bit(constant(0.05), max_grad_norm=1e9)
    p32 = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(512,)), jnp.float32)}
    p8 = jax.tree_util.tree_map(jnp.copy, p32)
    s32, s8 = init32(p32), init8(p8)
    for i in range(5):
        g = {"w": jnp.asarray(np.random.default_rng(i).normal(size=(512,)), jnp.float32)}
        p32, s32 = up32(g, s32, p32)
        p8, s8 = up8(g, s8, p8)
    diff = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
    scale = float(jnp.max(jnp.abs(p32["w"]))) + 1e-9
    assert diff / scale < 0.05, diff


def test_error_feedback_preserves_sum():
    """Error feedback: over many steps, compressed grads sum ≈ true sum."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64, np.float32)
    applied_sum = np.zeros(64, np.float32)
    g0 = {"w": jnp.zeros(64)}
    err = init_error_buffer(g0)
    for i in range(50):
        g = rng.normal(size=64).astype(np.float32) * (1 + i % 3)
        true_sum += g
        cg, err = compress_decompress({"w": jnp.asarray(g)}, err)
        applied_sum += np.asarray(cg["w"])
    resid = np.abs(true_sum - applied_sum).max()
    assert resid < np.abs(true_sum).max() * 0.02 + 0.5


def test_compressed_psum_over_real_axis():
    """int8 error-feedback psum under shard_map ≈ exact psum (subprocess
    with 4 forced devices)."""
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys
    import textwrap as _tw
    from pathlib import Path as _Path

    script = _tw.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.optim import compressed_psum, init_error_buffer

        mesh = jax.make_mesh((4,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 256))  # per-device rows

        def local(g_loc):
            grads = {"w": g_loc[0]}
            err = init_error_buffer(grads)
            out, err2 = compressed_psum(grads, err, "data")
            return out["w"], err2["w"]

        fn = shard_map(local, mesh=mesh, in_specs=P("data", None),
                           out_specs=(P(None), P("data")),
                           check_vma=False)
        with mesh:
            got, err = fn(g)
        want = jnp.sum(g, axis=0)
        rel = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
        print(json.dumps({"rel": rel, "err_nonzero": bool(jnp.any(err != 0))}))
        """
    )
    env = dict(_os.environ)
    env["PYTHONPATH"] = str(_Path(__file__).resolve().parents[1] / "src")
    out = _sp.run([_sys.executable, "-c", script], env=env,
                  capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = _json.loads(out.stdout.strip().splitlines()[-1])
    # int8 quantization: ~1% relative error on the reduced sum, error
    # feedback buffers carry the residual
    assert rec["rel"] < 0.05, rec
    assert rec["err_nonzero"]


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(jnp.int32(0))) == 0.0
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)
    assert float(fn(jnp.int32(55))) > float(fn(jnp.int32(90)))
