"""Fault tolerance: bitwise-identical restart, heartbeats, stragglers, elastic."""

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.data import SyntheticLM
from repro.launch.steps import TrainOptions, init_train_state, make_train_step
from repro.models import build_model
from repro.runtime import (
    HeartbeatMonitor,
    StragglerMonitor,
    WorkerFailure,
    replan,
    run_with_recovery,
)


def _training_setup(tmp_path):
    cfg = dataclasses.replace(get_arch("yi-6b").reduced(), num_microbatches=1)
    model = build_model(cfg)
    opts = TrainOptions(peak_lr=1e-3, warmup_steps=1, total_steps=100)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state, _ = init_train_state(model, params, opts)
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=5)
    step_jit = jax.jit(make_train_step(model, opts))
    return model, params, opt_state, data, step_jit


def _run(tmp_path, inject_failure_at=None, num_steps=12):
    """Drive run_with_recovery; optionally fail once at a given step."""
    model, params, opt_state, data, step_jit = _training_setup(tmp_path)
    ck = Checkpointer(tmp_path / ("fail" if inject_failure_at else "clean"), keep=3)
    state = {"params": params, "opt": opt_state}
    failed = {"done": False}

    def step_fn(step):
        if inject_failure_at is not None and step == inject_failure_at and not failed["done"]:
            failed["done"] = True
            raise WorkerFailure(f"injected pod failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        p, o, _, m = step_jit(state["params"], state["opt"], None, batch)
        state["params"], state["opt"] = p, o
        return float(m["loss"]), 0.0

    def save_fn(step):
        ck.save(step, (state["params"], state["opt"]))

    def restore_fn():
        (state["params"], state["opt"]), step = ck.restore(
            (state["params"], state["opt"])
        )
        return step

    save_fn(0)
    final, log, restarts = run_with_recovery(
        num_steps=num_steps, start_step=0, step_fn=step_fn,
        save_fn=save_fn, restore_fn=restore_fn, checkpoint_every=4,
    )
    return [m for _, m in log], restarts


def test_restart_bitwise_identical(tmp_path):
    """A run with an injected failure + restart must produce the exact same
    loss sequence as an uninterrupted run (deterministic data + step)."""
    clean, r0 = _run(tmp_path, inject_failure_at=None)
    faulty, r1 = _run(tmp_path, inject_failure_at=6)
    assert r0 == 0 and r1 == 1
    # deduplicate replayed steps: compare per-step final values
    last = {}
    for i, l in enumerate(faulty):
        last[i if i < len(clean) else i] = l
    # the faulty log replays steps 4..6; compare the last occurrence per step
    # simpler: final losses at the tail must match bitwise
    assert faulty[-1] == clean[-1]
    assert faulty[-2] == clean[-2]


def test_heartbeat_failure_detection():
    t = {"now": 0.0}
    hb = HeartbeatMonitor(num_hosts=4, timeout_s=10.0, clock=lambda: t["now"])
    t["now"] = 5.0
    for h in (0, 1, 3):
        hb.beat(h)
    t["now"] = 12.0
    assert hb.failed_hosts() == [2]
    assert not hb.healthy()


def test_straggler_detection():
    sm = StragglerMonitor(num_hosts=4, window=8, threshold=1.5)
    for i in range(8):
        for h in range(4):
            sm.record(h, 1.0 if h != 3 else 2.5)
    assert sm.stragglers() == [3]


def test_straggler_needs_history():
    sm = StragglerMonitor(num_hosts=2)
    assert sm.stragglers() == []


def test_elastic_replan_batch_split():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    plan2 = replan(mesh, shapes, global_batch=64, num_hosts=2)
    plan8 = replan(mesh, shapes, global_batch=64, num_hosts=8)
    assert plan2.local_batch == 32 and plan8.local_batch == 8
    with pytest.raises(ValueError):
        replan(mesh, shapes, global_batch=10, num_hosts=3)


def test_recovery_gives_up_after_max_restarts(tmp_path):
    def step_fn(step):
        raise WorkerFailure("always")

    with pytest.raises(WorkerFailure):
        run_with_recovery(
            num_steps=5, start_step=0, step_fn=step_fn,
            save_fn=lambda s: None, restore_fn=lambda: 0, max_restarts=2,
        )


# ---------------------------------------------------------------------------
# Device loss with pinned handles (KV caches survive via host re-stage)
# ---------------------------------------------------------------------------

def test_supervisor_restages_lost_device_handles():
    from repro.core import offload_trace
    from repro.core.hero import HeroCluster
    from repro.runtime.fault_tolerance import ClusterSupervisor

    c = HeroCluster(num_devices=3)
    h = c.pin_handle("kv-cache-7", float(1 << 20), device_id=1)
    keep = c.pin_handle("kv-cache-8", float(1 << 18), device_id=2)
    sup = ClusterSupervisor(cluster=c)
    with offload_trace() as t:
        ev = sup.fail_device(1)
    assert ev.unstaged_handles == ("kv-cache-7",)
    ((name, new_dev),) = ev.restaged
    assert name == "kv-cache-7" and new_dev in (0, 2)
    # the handle is live again, resident on a survivor
    assert h.valid and h.device_id == new_dev
    assert c.device(new_dev).is_resident("kv-cache-7")
    # survivor-homed handles are untouched
    assert keep.device_id == 2
    # the re-stage paid a full host->device copy, recorded on the new lane
    (rec,) = [r for r in t.records if r.op == "restage"]
    assert rec.device_id == new_dev and rec.regions.copy_s > 0


# ---------------------------------------------------------------------------
# Elastic cluster grow/shrink at checkpoint boundaries
# ---------------------------------------------------------------------------

def test_elastic_grow_preserves_state():
    from repro.core.hero import HeroCluster
    from repro.runtime import resize_cluster
    from repro.runtime.fault_tolerance import ClusterSupervisor

    c = HeroCluster(num_devices=2)
    h = c.pin_handle("weights", 1 << 16, device_id=1)
    sup = ClusterSupervisor(cluster=c)
    ev = resize_cluster(c, 4, supervisor=sup)
    assert (ev.before, ev.after) == (2, 4) and ev.restaged == ()
    assert c.num_devices == 4
    # existing handle untouched; new devices cold and heartbeat-tracked
    assert h.valid and h.device_id == 1
    assert not c.device(3).booted
    assert set(sup._last) == {0, 1, 2, 3}
    assert sup.silent_devices() == []


def test_elastic_shrink_restages_handles_and_reschedules_work():
    from repro.core import offload_trace
    from repro.core.hero import HeroCluster, LaunchTicket
    from repro.runtime import resize_cluster

    c = HeroCluster(num_devices=4)
    keep = c.pin_handle("kv-keep", 1 << 14, device_id=0)
    lost = c.pin_handle("kv-lost", 1 << 20, device_id=3)
    c.device(3).enqueue(LaunchTicket(op="gemm", shape_key="w", offload_s=1.0))
    with offload_trace() as t:
        ev = resize_cluster(c, 2)
    assert (ev.before, ev.after) == (4, 2) and c.num_devices == 2
    ((name, new_dev),) = ev.restaged
    assert name == "kv-lost" and 0 <= new_dev < 2
    assert lost.valid and lost.device_id == new_dev
    assert c.device(new_dev).is_resident("kv-lost")
    assert keep.device_id == 0
    # the re-stage paid a full host->device copy on the keeper's lane
    (rec,) = [r for r in t.records if r.op == "restage"]
    assert rec.device_id == new_dev and rec.regions.copy_s > 0
    # the removed lane's in-flight ticket moved onto a keeper
    assert sum(len(c.device(i).inflight) for i in range(2)) >= 1


def test_elastic_resize_bounds():
    import pytest as _pytest

    from repro.core.hero import HeroCluster

    c = HeroCluster(num_devices=2)
    with _pytest.raises(ValueError):
        c.resize(0)
    assert c.resize(2) == []  # no-op


def test_supervisor_total_loss_leaves_handles_unstaged():
    from repro.core.hero import HeroCluster
    from repro.runtime.fault_tolerance import ClusterSupervisor

    c = HeroCluster(num_devices=1)
    h = c.pin_handle("kv", 128.0, device_id=0)
    sup = ClusterSupervisor(cluster=c)
    ev = sup.fail_device(0)
    assert ev.total_loss
    assert ev.unstaged_handles == ("kv",) and ev.restaged == ()
    assert not h.valid  # nowhere to go until a device is recovered
