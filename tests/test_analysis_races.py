"""Pass 2 (``repro.analysis.races``) — the happens-before checker over
``LaunchTicket`` event streams.

Real workloads (pipelined staging, cross-wave prefetch, d2d migration,
failure requeue) must check race-free; each injected corruption — compute
starting before its copy-ready leg, clocks running backwards, a launch
outrunning a staging copy, a resident launch charging DMA — produces its
named violation with the offending ticket chain.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.hnp as hnp
from repro.analysis.races import (
    StreamRaceError,
    assert_race_free,
    check_cluster,
    check_ticket_streams,
    ticket_streams,
)
from repro.core import engine, offload_policy


def rules(violations):
    return {v.rule for v in violations}


def _run_workload(**policy):
    """Force a two-wave hnp workload; return the live per-device streams."""
    engine().reset()
    kw = dict(mode="device", num_devices=2, scheduler="cost-aware")
    kw.update(policy)
    with offload_policy(**kw):
        with hnp.offload_region("races"):
            a = hnp.array(np.ones((128, 96), np.float32))
            w1 = np.ones((96, 128), np.float32)
            w2 = np.ones((128, 64), np.float32)
            h = hnp.tanh(a @ w1)
            hnp.asnumpy(h @ w2)
        return ticket_streams()


def _seed(streams, dev_key, idx, **replace):
    out = {k: list(v) for k, v in streams.items()}
    out[dev_key][idx] = dataclasses.replace(out[dev_key][idx], **replace)
    return out


def _first(streams):
    dev = next(k for k in sorted(streams) if streams[k])
    return dev, streams[dev][0]


# ---------------------------------------------------------------------------
# clean paths
# ---------------------------------------------------------------------------

def test_serial_workload_is_race_free():
    streams = _run_workload(pipeline_staging=False)
    assert sum(len(v) for v in streams.values()) > 0
    assert check_ticket_streams(streams) == []


def test_pipelined_prefetch_workload_is_race_free():
    streams = _run_workload(pipeline_staging=True, prefetch_staging=True)
    assert check_ticket_streams(streams) == []
    kinds = {t.kind for v in streams.values() for t in v}
    assert "launch" in kinds


def test_d2d_migration_edges_are_race_free():
    engine().reset()
    with offload_policy(mode="device", num_devices=2):
        eng = engine()
        h = eng.pin_handle("mig", 1 << 20, device_id=0)
        eng.migrate_handle(h, 1)
        streams = ticket_streams()
    kinds = {t.kind for v in streams.values() for t in v}
    assert "d2d" in kinds
    assert check_ticket_streams(streams) == []


def test_failure_requeue_is_race_free():
    engine().reset()
    with offload_policy(mode="device", num_devices=2):
        with hnp.offload_region("ft"):
            a = hnp.array(np.ones((64, 64), np.float32))
            hnp.asnumpy(a @ a)
        engine().fail_device(0) if engine().devices[0].inflight else \
            engine().fail_device(1)
        streams = ticket_streams()
    assert check_ticket_streams(streams) == []


def test_fully_resident_launch_charges_zero_dma():
    engine().reset()
    with offload_policy(mode="device", num_devices=1):
        from repro.core.dispatch import dispatch

        x = np.ones((64, 64), np.float32)
        eng = engine()
        h = eng.pin_handle("res", float(3 * x.nbytes), device_id=0)
        dispatch("matmul", x, x, handle=h, resident_fraction=1.0)
        streams = ticket_streams()
    launches = [t for v in streams.values() for t in v if t.kind == "launch"]
    assert launches and launches[0].resident_fraction >= 1.0
    assert launches[0].copy_done_s == pytest.approx(launches[0].issue_s)
    assert check_ticket_streams(streams) == []


def test_check_cluster_reads_live_engine():
    engine().reset()
    with offload_policy(mode="device", num_devices=2):
        with hnp.offload_region("live"):
            a = hnp.array(np.ones((64, 64), np.float32))
            hnp.asnumpy(a @ a)
        assert check_cluster() == []
        assert_race_free()
    engine().reset()


# ---------------------------------------------------------------------------
# injected corruption -> named violations (the ISSUE's error-path matrix)
# ---------------------------------------------------------------------------

def test_injected_compute_before_copy_ready():
    streams = _run_workload()
    dev, t = _first(streams)
    bad = _seed(streams, dev, 0, compute_start_s=t.copy_ready_s - 0.25)
    v = check_ticket_streams(bad)
    assert "race/compute-before-copy-ready" in rules(v)
    assert any(f"dev{dev}[0]" in x.where for x in v)


def test_injected_complete_before_copy_done():
    streams = _run_workload()
    dev, t = _first(streams)
    bad = _seed(streams, dev, 0, complete_s=t.copy_done_s - 0.25)
    assert "race/complete-before-copy-done" in rules(check_ticket_streams(bad))


def test_injected_non_monotone_dma_clock():
    streams = _run_workload(num_devices=1)
    dev = next(k for k, v in streams.items() if len(v) >= 2)
    first = streams[dev][0]
    bad = _seed(streams, dev, 1, issue_s=first.copy_done_s - 1.0)
    v = check_ticket_streams(bad)
    assert "race/dma-clock-monotone" in rules(v)
    assert any("->" in x.where for x in v)  # reports the ticket chain


def test_injected_non_monotone_compute_clock():
    streams = _run_workload(num_devices=1)
    dev = next(k for k, v in streams.items() if len(v) >= 2)
    first = streams[dev][0]
    bad = _seed(streams, dev, 1,
                compute_start_s=first.complete_s - 1.0,
                copy_ready_s=first.complete_s - 1.0,
                issue_s=first.complete_s - 1.0)
    assert "race/compute-clock-monotone" in rules(check_ticket_streams(bad))


def test_injected_launch_outrunning_prefetch_copy():
    # single device: the cross-wave prefetch and its consumer launch share
    # one stream, so the staging->compute happens-before edge is checkable
    streams = _run_workload(prefetch_staging=True, num_devices=1)
    target = None
    for dev, tickets in streams.items():
        for i, t in enumerate(tickets):
            if t.kind == "prefetch" and any(
                u.kind == "launch" for u in tickets[i + 1:]
            ):
                target = (dev, i, t)
    assert target is not None, "workload must prefetch ahead of a launch"
    dev, i, s = target
    assert check_ticket_streams(streams) == []
    bad = _seed(streams, dev, i, copy_done_s=s.copy_done_s + 100.0,
                complete_s=s.complete_s + 100.0)
    v = check_ticket_streams(bad)
    assert "race/read-before-copy-done" in rules(v)
    assert any("prefetch" in x.where for x in v)


def test_injected_resident_launch_charging_dma():
    streams = _run_workload()
    dev, t = _first(streams)
    assert t.copy_done_s > t.issue_s        # it really did stage bytes
    bad = _seed(streams, dev, 0, resident_fraction=1.0)
    assert "race/resident-charged-dma" in rules(check_ticket_streams(bad))


def test_injected_device_mismatch():
    streams = _run_workload()
    dev, _ = _first(streams)
    bad = _seed(streams, dev, 0, device_id=dev + 5)
    assert "race/device-mismatch" in rules(check_ticket_streams(bad))


def test_assert_race_free_raises_with_named_rule():
    streams = _run_workload()
    dev, t = _first(streams)
    bad = _seed(streams, dev, 0, compute_start_s=t.copy_ready_s - 0.25)
    with pytest.raises(StreamRaceError) as exc:
        assert_race_free(bad)
    assert "race/compute-before-copy-ready" in str(exc.value)


@settings(max_examples=10)
@given(
    st.integers(min_value=1, max_value=3),
    st.booleans(),
    st.sampled_from(["least-loaded", "round-robin", "cost-aware"]),
)
def test_random_topologies_are_race_free(num_devices, prefetch, scheduler):
    streams = _run_workload(
        num_devices=num_devices,
        prefetch_staging=prefetch,
        scheduler=scheduler,
    )
    assert check_ticket_streams(streams) == []
