"""The ``repro.hnp`` lazy frontend: parity, fusion, batching, residency.

Four contracts:

1. **Parity** — an ``hnp`` expression graph computes the same values as the
   pure-NumPy reference across host / device / device-pallas(interpret)
   backends and f32 / bf16 dtypes (hypothesis-style sweep over shapes).
2. **Fusion** — single-consumer elementwise chains (bias add, activations)
   fold into their producer's launch: no extra dispatch records.
3. **Batching** — independent same-shape GEMMs in one wave stack into a
   single ``gemm_batched`` launch.
4. **Residency** (the key win) — an intermediate consumed on-device stays
   device-resident: zero host readback bytes recorded for it, strictly
   fewer staged bytes and strictly less modeled time than the eager
   ``blas.*`` equivalent of the same chain.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.hnp as hnp
from repro.core import blas, engine, offload_policy, offload_trace

RNG = np.random.default_rng(11)

BACKEND_POLICIES = {
    "host": dict(mode="host"),
    "device": dict(mode="device"),
    "device-pallas-interpret": dict(
        mode="device", use_pallas=True, interpret=True
    ),
}


@pytest.fixture(autouse=True)
def _reset_engine():
    engine().reset()
    yield
    engine().reset()


def _arr(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _np32(x):
    return np.asarray(x, np.float32)


def _assert_close(got, want, dtype, msg=""):
    tol = dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )
    scale = max(1.0, float(np.max(np.abs(want))))
    np.testing.assert_allclose(
        _np32(got) / scale, _np32(want) / scale, err_msg=msg, **tol
    )


# ---------------------------------------------------------------------------
# 1. Parity
# ---------------------------------------------------------------------------

@settings(max_examples=6)
@given(
    m=st.integers(min_value=8, max_value=48),
    k=st.integers(min_value=8, max_value=40),
    n=st.integers(min_value=8, max_value=32),
)
def test_graph_parity_mlp_chain(m, k, n):
    """tanh(x @ w1 + b) @ w2 matches NumPy on every backend x dtype."""
    for dtype in (jnp.float32, jnp.bfloat16):
        x = _arr(m, k, dtype=dtype)
        w1 = _arr(k, n, dtype=dtype)
        b = _arr(n, dtype=dtype)
        w2 = _arr(n, k, dtype=dtype)
        ref = np.tanh(
            _np32(x) @ _np32(w1) + _np32(b)
        ) @ _np32(w2)
        for backend, pol in BACKEND_POLICIES.items():
            engine().reset()
            with offload_policy(**pol):
                y = hnp.tanh(hnp.linear(hnp.array(x), w1, b)) @ w2
                got = hnp.asnumpy(y)
            _assert_close(got, ref, dtype, f"{backend} {dtype}")


def test_graph_parity_elementwise_reductions():
    x = _arr(6, 10)
    y = _arr(6, 10)
    a = hnp.array(x)
    b = hnp.array(y)
    got = hnp.asnumpy((a * 2.0 + b / 3.0 - 1.0).sum(axis=1))
    want = (_np32(x) * 2.0 + _np32(y) / 3.0 - 1.0).sum(axis=1)
    _assert_close(got, want, jnp.float32)
    got2 = hnp.asnumpy(hnp.maximum(a, b).mean())
    _assert_close(got2, np.maximum(_np32(x), _np32(y)).mean(), jnp.float32)
    got3 = hnp.asnumpy(hnp.relu(a).T)
    _assert_close(got3, np.maximum(_np32(x), 0.0).T, jnp.float32)


def test_registered_ops_appear_in_hnp_for_free():
    """Seam contract: anything in the op registry is graph-capturable by
    name — including ops this test never heard of."""
    from repro.core import dispatch as dsp

    assert set(dsp.registered_ops()) <= {
        name for name in dsp.registered_ops() if callable(getattr(hnp, name))
    }
    sq = _arr(24, 16)
    got = hnp.asnumpy(hnp.syrk(hnp.array(sq)))
    _assert_close(got, _np32(sq) @ _np32(sq).T, jnp.float32)
    v = _arr(32)
    got = hnp.asnumpy(hnp.axpy(2.0, hnp.array(v), hnp.array(v)))
    _assert_close(got, 3.0 * _np32(v), jnp.float32)


def test_unknown_hnp_attribute_raises():
    with pytest.raises(AttributeError, match="registered ops"):
        hnp.cholesky  # noqa: B018


# ---------------------------------------------------------------------------
# 2. Fusion
# ---------------------------------------------------------------------------

def test_elementwise_chain_fuses_into_producer_launch():
    x, w1, w2 = _arr(32, 64), _arr(64, 48), _arr(48, 16)
    b = _arr(48)
    with offload_policy(mode="device"):
        with offload_trace() as t:
            with hnp.offload_region("fuse") as region:
                h = hnp.tanh(hnp.linear(hnp.array(x), w1, b))
                y = h @ w2
                got = hnp.asnumpy(y)
    # two matmuls -> exactly two dispatch records; bias-add + tanh fused
    ops = [r.op for r in t.records]
    assert ops.count("gemm") == 2 and len([o for o in ops if o != "d2d_copy"]) == 2
    head = region.report.launches[0]
    assert head.fused == ("add", "tanh")
    ref = np.tanh(_np32(x) @ _np32(w1) + _np32(b)) @ _np32(w2)
    _assert_close(got, ref, jnp.float32)


# ---------------------------------------------------------------------------
# 2b. Common-subexpression + dead-node elimination
# ---------------------------------------------------------------------------

def test_cse_duplicate_subtree_launches_once():
    """Structurally identical subtrees collapse before scheduling: the
    duplicated tanh(x @ w) chain dispatches ONE gemm, the report counts the
    eliminated nodes, and both outside references stay valid."""
    x, w = _arr(24, 32), _arr(32, 24)
    a = hnp.array(x)
    y1 = hnp.tanh(a @ w)
    y2 = hnp.tanh(a @ w)          # distinct nodes, identical structure
    total = y1 + y2
    with offload_policy(mode="device"):
        with offload_trace() as t:
            with hnp.offload_region("cse") as region:
                got = hnp.asnumpy(total)
    heavy = [r for r in t.records if r.op != "d2d_copy"]
    assert [r.op for r in heavy] == ["gemm"], heavy
    assert region.report.nodes_eliminated >= 2  # dup matmul + dup tanh
    ref = 2.0 * np.tanh(_np32(x) @ _np32(w))
    _assert_close(got, ref, jnp.float32)
    # the collapsed duplicate carries its representative's value
    _assert_close(np.asarray(y2), np.tanh(_np32(x) @ _np32(w)), jnp.float32)


def test_cse_keeps_distinct_leaves_apart():
    """Equal-shaped but distinct leaves must NOT collapse (identity-keyed)."""
    x1, x2, w = _arr(16, 16), _arr(16, 16), _arr(16, 16)
    got = hnp.asnumpy(hnp.array(x1) @ w + hnp.array(x2) @ w)
    _assert_close(got, _np32(x1) @ _np32(w) + _np32(x2) @ _np32(w), jnp.float32)


def test_block_all_batches_across_roots():
    """Forcing independent roots in one pass lets same-shape GEMMs batch."""
    x, w1, w2 = _arr(16, 32), _arr(32, 16), _arr(32, 16)
    a = hnp.array(x)
    y1, y2 = a @ w1, a @ w2
    with offload_policy(mode="device"):
        with offload_trace() as t:
            hnp.block_all(y1, y2)
    assert [r.op for r in t.records if r.op != "d2d_copy"] == ["gemm_batched"]
    _assert_close(np.asarray(y1), _np32(x) @ _np32(w1), jnp.float32)
    _assert_close(np.asarray(y2), _np32(x) @ _np32(w2), jnp.float32)


# ---------------------------------------------------------------------------
# 3. Batching
# ---------------------------------------------------------------------------

def test_independent_same_shape_gemms_batch_into_one_launch():
    xs = [_arr(24, 32) for _ in range(3)]
    w = _arr(32, 24)
    with offload_policy(mode="device"):
        with offload_trace() as t:
            with hnp.offload_region("batch") as region:
                ys = [hnp.array(x) @ w for x in xs]
                total = ys[0] + ys[1] + ys[2]
                got = hnp.asnumpy(total)
    assert [r.op for r in t.records if r.op != "d2d_copy"] == ["gemm_batched"]
    assert all(r.batched for r in region.report.launches)
    assert len(region.report.launches) == 3
    want = sum(_np32(x) @ _np32(w) for x in xs)
    _assert_close(got, want, jnp.float32)


# ---------------------------------------------------------------------------
# 4. Residency threading
# ---------------------------------------------------------------------------

def _eager_chain(x, ws):
    h = blas.matmul(x, ws[0])
    h = jnp.tanh(h)
    h = blas.matmul(h, ws[1])
    h = jnp.tanh(h)
    return blas.matmul(h, ws[2])


def _graph_chain(x, ws):
    h = hnp.tanh(hnp.array(x) @ ws[0])
    h = hnp.tanh(h @ ws[1])
    return h @ ws[2]


def test_on_device_intermediate_records_zero_host_readback():
    """Regression: an intermediate produced and consumed on device must not
    round-trip through host DRAM — zero readback bytes on its report, and
    its consumer's record carries the residency credit."""
    x = _arr(64, 128)
    ws = [_arr(128, 128), _arr(128, 128), _arr(128, 64)]
    with offload_policy(mode="device", num_devices=1):
        with offload_trace() as t:
            with hnp.offload_region("resident") as region:
                got = hnp.asnumpy(_graph_chain(x, ws))
    launches = region.report.launches
    assert len(launches) == 3
    for intermediate in launches[:-1]:
        assert intermediate.readback_bytes == 0.0, intermediate
    # only the final result pays readback
    assert launches[-1].readback_bytes > 0.0
    # consumers' trace records carry the exact residency credit
    recs = [r for r in t.records if r.op != "d2d_copy"]
    assert recs[1].resident_fraction > 0.0
    assert recs[2].resident_fraction > 0.0
    assert recs[0].staged_bytes_charged < recs[0].cost.staged_bytes
    ref = np.tanh(np.tanh(_np32(x) @ _np32(ws[0])) @ _np32(ws[1])) @ _np32(ws[2])
    _assert_close(got, ref, jnp.float32)


def test_fused_graph_beats_eager_chain_on_staging_and_modeled_time():
    """Acceptance: the fused 3-GEMM chain beats the eager ``blas.*``
    equivalent on modeled time with strictly fewer host<->device staging
    bytes (residency reuse visible in the DMA timeline)."""
    x = _arr(128, 256)
    ws = [_arr(256, 256), _arr(256, 256), _arr(256, 128)]
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        engine().reset()
        with offload_trace() as t_eager:
            eager = _eager_chain(x, ws)
        engine().reset()
        with offload_trace() as t_graph:
            with hnp.offload_region("chain"):
                graph = hnp.asnumpy(_graph_chain(x, ws))
    _assert_close(graph, eager, jnp.float32)

    staged_eager = t_eager.total_staged_bytes_charged()
    staged_graph = t_graph.total_staged_bytes_charged()
    assert staged_graph < staged_eager, (staged_graph, staged_eager)

    def modeled_time(t):
        copy, fork, comp, _ = t.totals()
        return copy + fork + comp + t.total_d2d_s()

    assert modeled_time(t_graph) < modeled_time(t_eager)
    assert t_graph.cluster_makespan_s() <= t_eager.cluster_makespan_s()


def test_offload_region_shares_residency_across_forces():
    """Within one region, an intermediate forced early stays resident for
    later expressions; handles die with the region (multi-op lifetime)."""
    x, w1, w2 = _arr(32, 64), _arr(64, 64), _arr(64, 32)
    with offload_policy(mode="device", num_devices=1):
        with offload_trace() as t:
            with hnp.offload_region("shared") as region:
                h = hnp.array(x) @ w1
                first = hnp.asnumpy(h)       # forces h, stays resident
                second = hnp.asnumpy(h @ w2)  # reuses the resident value
            assert engine().handles_on(0) == []  # region released its pins
    recs = [r for r in t.records if r.op != "d2d_copy"]
    assert recs[1].resident_fraction > 0.0  # h was credited as resident
    _assert_close(second, (_np32(x) @ _np32(w1)) @ _np32(w2), jnp.float32)
    _assert_close(first, _np32(x) @ _np32(w1), jnp.float32)


def test_per_graph_rollup_in_accounting():
    x, w = _arr(16, 32), _arr(32, 16)
    with offload_policy(mode="device"):
        with offload_trace() as t:
            with hnp.offload_region("g1"):
                hnp.asnumpy(hnp.array(x) @ w)
            blas.matmul(x, w)  # eager call outside any graph
    groups = t.by_graph()
    assert set(groups) == {"g1", ""}
    assert groups["g1"].calls == 1
    assert groups["g1"].staged_bytes_charged <= groups["g1"].staged_bytes


def test_pinned_leaf_weights_credit_residency():
    x, w = _arr(32, 64), _arr(64, 32)
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        wa = hnp.array(w, pin=True)
        with offload_trace() as t:
            got = hnp.asnumpy(hnp.array(x) @ wa)
    (rec,) = [r for r in t.records if r.op != "d2d_copy"]
    assert rec.resident_fraction > 0.0
    _assert_close(got, _np32(x) @ _np32(w), jnp.float32)
