import os

# Smoke tests and benches must see the single real CPU device — the 512-way
# host-device forcing belongs ONLY to launch/dryrun.py (see system design).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# ---------------------------------------------------------------------------
# Optional-dependency shim: hypothesis.
#
# Five test modules are property tests written against hypothesis.  The
# package is optional in this container; without it, a hard import would
# abort collection for the whole suite.  When hypothesis is missing we
# install a deterministic fallback into sys.modules: @given runs the test
# over a fixed, seeded set of examples (boundary values first, then
# pseudo-random draws from the declared ranges).  Coverage is thinner than
# real hypothesis but deterministic and dependency-free; with hypothesis
# installed this shim is inert.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import inspect
    import itertools
    import random
    import sys
    import types

    _DEFAULT_EXAMPLES = 25
    _MAX_EXAMPLES_CAP = 25

    class _UnsatisfiedAssumption(Exception):
        """Raised by assume(False): skip the current example, not fail."""

    def _assume(condition):
        if not condition:
            raise _UnsatisfiedAssumption()
        return True

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample          # (rng, index) -> value

        def example_at(self, rng, i):
            return self._sample(rng, i)

    def _integers(min_value=0, max_value=2**31 - 1):
        lo, hi = int(min_value), int(max_value)

        def sample(rng, i):
            if i == 0:
                return lo
            if i == 1:
                return hi
            return rng.randint(lo, hi)

        return _Strategy(sample)

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)

        def sample(rng, i):
            if i == 0:
                return lo
            if i == 1:
                return hi
            return rng.uniform(lo, hi)

        return _Strategy(sample)

    def _sampled_from(elements):
        elems = list(elements)

        def sample(rng, i):
            if i < len(elems):
                return elems[i]
            return elems[rng.randrange(len(elems))]

        return _Strategy(sample)

    def _booleans():
        return _sampled_from([False, True])

    def _just(value):
        return _Strategy(lambda rng, i: value)

    def _lists(elements, min_size=0, max_size=10):
        lo, hi = int(min_size), int(max_size)

        def sample(rng, i):
            if i == 0:
                size = lo
            elif i == 1:
                size = hi
            else:
                size = rng.randint(lo, hi)
            return [elements.example_at(rng, i) for _ in range(size)]

        return _Strategy(sample)

    def _tuples(*strategies):
        def sample(rng, i):
            return tuple(s.example_at(rng, i) for s in strategies)

        return _Strategy(sample)

    def _one_of(*strategies):
        # accept both one_of(a, b) and one_of([a, b])
        strats = (
            list(strategies[0])
            if len(strategies) == 1 and isinstance(strategies[0], (list, tuple))
            else list(strategies)
        )

        def sample(rng, i):
            if i < len(strats):
                return strats[i].example_at(rng, i)
            return strats[rng.randrange(len(strats))].example_at(rng, i)

        return _Strategy(sample)

    def _none():
        return _just(None)

    def _text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=8):
        chars = list(alphabet)
        lo, hi = int(min_size), int(max_size)

        def sample(rng, i):
            size = lo if i == 0 else (hi if i == 1 else rng.randint(lo, hi))
            return "".join(chars[rng.randrange(len(chars))] for _ in range(size))

        return _Strategy(sample)

    def _settings(**kw):
        def deco(fn):
            fn._shim_settings = dict(getattr(fn, "_shim_settings", {}), **kw)
            return fn

        return deco

    def _given(*pos_strategies, **kw_strategies):
        def deco(fn):
            params = [
                p.name
                for p in inspect.signature(fn).parameters.values()
                if p.kind
                in (p.POSITIONAL_OR_KEYWORD, p.POSITIONAL_ONLY, p.KEYWORD_ONLY)
            ]
            bound = dict(kw_strategies)
            if pos_strategies:
                # hypothesis fills positional strategies against the
                # rightmost parameters, in order
                names = [n for n in params if n not in bound]
                tail = names[-len(pos_strategies):]
                bound.update(zip(tail, pos_strategies))

            def wrapper():
                cfg = getattr(wrapper, "_shim_settings", {}) or getattr(
                    fn, "_shim_settings", {}
                )
                n = min(
                    int(cfg.get("max_examples", _DEFAULT_EXAMPLES)),
                    _MAX_EXAMPLES_CAP,
                )
                rng = random.Random(f"repro-shim:{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    kwargs = {
                        name: strat.example_at(rng, i)
                        for name, strat in bound.items()
                    }
                    try:
                        fn(**kwargs)
                    except _UnsatisfiedAssumption:
                        continue
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (deterministic shim, "
                            f"case {i}): {kwargs!r}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            wrapper._shim_settings = dict(getattr(fn, "_shim_settings", {}))
            wrapper.hypothesis_shim = True
            return wrapper

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = _assume
    _hyp.__is_repro_shim__ = True
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.just = _just
    _st.lists = _lists
    _st.tuples = _tuples
    _st.one_of = _one_of
    _st.none = _none
    _st.text = _text
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
