import os

# Smoke tests and benches must see the single real CPU device — the 512-way
# host-device forcing belongs ONLY to launch/dryrun.py (see system design).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
