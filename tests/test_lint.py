"""Pass 3 (``repro.analysis.lint``) — the repo lint rule engine.

The clean tree lints clean (that is what ``make lint`` gates); each rule
fires with its name on a seeded offending file; the repo-level registry
closure catches a dangling pallas fetch / missing parity sample.
"""

import pathlib
import subprocess
import sys

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.lint import (
    RULES,
    check_registry_closure,
    lint_file,
    repo_root,
    run_lint,
)

ROOT = repo_root()


def rules_of(violations):
    return {v.rule for v in violations}


def _write(root, rel, source):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    return p


# ---------------------------------------------------------------------------
# the clean tree
# ---------------------------------------------------------------------------

def test_clean_tree_lints_clean():
    violations = run_lint(ROOT)
    assert violations == [], "\n".join(v.render() for v in violations)


def test_registry_closure_clean_on_tree():
    assert check_registry_closure(ROOT) == []


def test_rule_table_names_are_unique_and_scoped():
    names = [r.name for r in RULES]
    assert len(names) == len(set(names))
    for r in RULES:
        assert r.paths and r.description


def test_cli_exits_zero_on_clean_tree():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "repro_lint.py")],
        capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


# ---------------------------------------------------------------------------
# seeded offenders -> named rules (the regression tests per AST rule)
# ---------------------------------------------------------------------------

def test_dot_general_under_models_is_flagged(tmp_path):
    p = _write(tmp_path, "src/repro/models/bad.py",
               "def f(lax, a, b):\n"
               "    return lax.dot_general(a, b, (((1,), (0,)), ((), ())))\n")
    v = lint_file(p, tmp_path)
    assert rules_of(v) == {"models-no-dot-general"}
    assert "src/repro/models/bad.py:2" in v[0].where


def test_bare_engine_launch_under_models_is_flagged(tmp_path):
    p = _write(tmp_path, "src/repro/models/bad.py",
               "from repro.core.hero import engine\n"
               "def f(cost):\n"
               "    return engine().launch(cost)\n")
    assert rules_of(lint_file(p, tmp_path)) == {"models-no-bare-launch"}


def test_jax_probe_outside_compat_is_flagged(tmp_path):
    p = _write(tmp_path, "src/repro/core/probe.py",
               "import jax.numpy as jnp\n"
               "HAS = hasattr(jnp, 'einsum')\n")
    assert rules_of(lint_file(p, tmp_path)) == {"no-jax-probe-outside-compat"}


def test_jax_probe_inside_compat_is_exempt(tmp_path):
    p = _write(tmp_path, "src/repro/compat.py",
               "import jax\nHAS = hasattr(jax, 'sharding')\n")
    assert lint_file(p, tmp_path) == []


def test_module_scope_jax_import_in_frontend_is_flagged(tmp_path):
    p = _write(tmp_path, "src/repro/frontend/bad.py", "import jax\n")
    v = lint_file(p, tmp_path)
    assert rules_of(v) == {"frontend-import-light"}
    p2 = _write(tmp_path, "src/repro/analysis/bad.py",
                "from jax.experimental import pallas\n")
    assert rules_of(lint_file(p2, tmp_path)) == {"frontend-import-light"}


def test_type_checking_and_function_scope_imports_are_exempt(tmp_path):
    p = _write(tmp_path, "src/repro/frontend/ok.py",
               "from typing import TYPE_CHECKING\n"
               "if TYPE_CHECKING:\n"
               "    import jax\n"
               "def f():\n"
               "    import jax.numpy as jnp\n"
               "    return jnp\n")
    assert lint_file(p, tmp_path) == []


def test_trace_record_without_device_id_is_flagged(tmp_path):
    p = _write(tmp_path, "src/repro/core/rec.py",
               "from repro.core.accounting import OffloadRecord\n"
               "def f(**kw):\n"
               "    return OffloadRecord(op='gemm', **kw)\n")
    assert lint_file(p, tmp_path) == []        # **kwargs may carry it
    p2 = _write(tmp_path, "src/repro/core/rec2.py",
                "from repro.core.accounting import OffloadRecord\n"
                "def f():\n"
                "    return OffloadRecord(op='gemm')\n")
    assert rules_of(lint_file(p2, tmp_path)) == {"trace-record-device-id"}


def test_wallclock_in_streaming_is_flagged(tmp_path):
    p = _write(tmp_path, "src/repro/launch/streaming.py",
               "import time\n"
               "def drive():\n"
               "    return time.time()\n")
    v = lint_file(p, tmp_path)
    assert rules_of(v) == {"serve-no-wallclock"}
    # both the import and the clock read are named
    assert len(v) == 2
    p2 = _write(tmp_path, "src/repro/launch/costing.py",
                "from time import perf_counter\n"
                "def cost():\n"
                "    return perf_counter()\n")
    assert "serve-no-wallclock" in rules_of(lint_file(p2, tmp_path))


def test_wallclock_rule_catches_aliases_and_datetime(tmp_path):
    p = _write(tmp_path, "src/repro/launch/streaming.py",
               "import time as _t\n"
               "from datetime import datetime\n"
               "def f():\n"
               "    return _t.perf_counter(), datetime.now()\n")
    v = lint_file(p, tmp_path)
    assert rules_of(v) == {"serve-no-wallclock"}
    msgs = "\n".join(x.render() for x in v)
    assert "perf_counter" in msgs and "datetime.now" in msgs


def test_wallclock_rule_scoped_to_streaming_paths(tmp_path):
    # serve.py's wall-clock reads time real jit execution — out of scope
    p = _write(tmp_path, "src/repro/launch/serve.py",
               "import time\nT0 = time.time()\n")
    assert lint_file(p, tmp_path) == []


def test_parse_error_is_reported_not_raised(tmp_path):
    p = _write(tmp_path, "src/repro/models/broken.py", "def f(:\n")
    assert rules_of(lint_file(p, tmp_path)) == {"parse-error"}


@settings(max_examples=8)
@given(st.sampled_from(["getattr", "hasattr"]), st.text(min_size=1, max_size=6))
def test_probe_rule_tracks_jax_aliases(fn, alias):
    import keyword

    if not alias.isidentifier() or keyword.iskeyword(alias):
        alias = "j_" + alias
    src = f"import jax as {alias}\nX = {fn}({alias}, 'vmap', None)\n"
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        root = pathlib.Path(d)
        p = _write(root, "src/repro/core/x.py", src)
        assert rules_of(lint_file(p, root)) == {"no-jax-probe-outside-compat"}


# ---------------------------------------------------------------------------
# registry closure on a seeded broken tree
# ---------------------------------------------------------------------------

_BLAS = """
def register(op): pass
class OffloadOp: pass
def pallas_lowering(name): pass
register(OffloadOp(name="gemm"))
register(OffloadOp(name="ghost_op"))
pallas_lowering("gemm")
pallas_lowering("missing_row")
"""

_OPS = """
PALLAS_LOWERINGS = {"gemm": None}
"""

_SAMPLES = """
def _samples(dtype):
    return {"gemm": None, "stale_op": None}
"""


def test_registry_closure_catches_all_three_breaks(tmp_path):
    _write(tmp_path, "src/repro/core/blas.py", _BLAS)
    _write(tmp_path, "src/repro/kernels/ops.py", _OPS)
    _write(tmp_path, "tests/test_dispatch.py", _SAMPLES)
    v = check_registry_closure(tmp_path)
    msgs = "\n".join(x.render() for x in v)
    assert rules_of(v) == {"registry-closure"}
    assert "missing_row" in msgs       # pallas fetch with no table row
    assert "ghost_op" in msgs          # registered op with no parity sample
    assert "stale_op" in msgs          # sample for an unregistered op


def test_run_lint_includes_repo_rules_on_seeded_tree(tmp_path):
    _write(tmp_path, "src/repro/core/blas.py", _BLAS)
    _write(tmp_path, "src/repro/kernels/ops.py", _OPS)
    _write(tmp_path, "tests/test_dispatch.py", _SAMPLES)
    _write(tmp_path, "src/repro/models/bad.py",
           "def f(lax, a, b):\n    return lax.dot_general(a, b, None)\n")
    v = run_lint(tmp_path)
    assert {"models-no-dot-general", "registry-closure"} <= rules_of(v)
