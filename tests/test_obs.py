"""repro.obs — span tracing, trace export, metrics, flight recorder.

The design contract under test (ISSUE 9):

* tracing is observation-only: a tracer-on run is bitwise-identical to a
  tracer-off run (results AND the streaming event trail);
* spans nest well on their lanes and export to valid Chrome trace-event
  JSON (paired flows/asyncs, non-negative ts/dur);
* the flight recorder keeps an exact last-K window per device and rides
  ``AnalysisError`` when a rule fires;
* metrics fan out to every stacked ``collect()`` scope and roll up to a
  flat JSON-able dict;
* the ``obs-modeled-time-only`` lint rule patrols the instrumented files;
* requeued tickets stay on the accounting books (the ISSUE 9 bugfix).
"""

import collections
import json

import numpy as np
import pytest

from repro.core import blas, offload_trace
from repro.core.cost_model import gemm_cost
from repro.core.hero import HeroCluster, LaunchTicket, engine, offload_policy
from repro.obs import flight, metrics, spans, trace_export


@pytest.fixture(autouse=True)
def _reset_engine():
    engine().reset()
    yield
    engine().reset()


def _chain():
    rng = np.random.default_rng(0)
    a = np.asarray(rng.normal(size=(128, 128)), np.float32)
    b = np.asarray(rng.normal(size=(128, 128)), np.float32)
    with offload_policy(mode="device", num_devices=2, pipeline_staging=True):
        engine().reset()
        y = blas.gemm(a, b)
        y = blas.gemm(np.asarray(y), b)
    return np.asarray(y)


@pytest.fixture(scope="module")
def serve_tracer():
    """One traced streaming-burst run shared by the export tests."""
    from repro.launch.streaming import bursty_trace, serve_stream

    engine().reset()
    with spans.span_trace("serve") as tr:
        rep = serve_stream("yi-6b", bursty_trace(60.0, 0.5, seed=0))
    engine().reset()
    return tr, rep


# ---------------------------------------------------------------------------
# Observation-only contract
# ---------------------------------------------------------------------------

def test_tracer_on_is_bitwise_identical_and_off_records_nothing():
    assert spans.current_tracer() is None
    idle = spans.SpanTracer("idle")         # constructed but never installed
    y_off = _chain()
    assert idle.spans == [] and idle.counters == []
    with spans.span_trace("on") as tr:
        y_on = _chain()
    assert tr.spans and tr.counters          # instrumentation fired
    assert np.array_equal(y_off, y_on)       # ...and changed nothing
    assert spans.current_tracer() is None


def test_streaming_event_trail_identical_with_tracer_on(serve_tracer):
    from repro.launch.streaming import bursty_trace, serve_stream

    _, rep_on = serve_tracer
    engine().reset()
    rep_off = serve_stream("yi-6b", bursty_trace(60.0, 0.5, seed=0))
    assert rep_off.events == rep_on.events
    assert rep_off.completed == rep_on.completed


# ---------------------------------------------------------------------------
# Lane structure
# ---------------------------------------------------------------------------

def test_spans_are_ordered_and_nest_within_same_lane_parents():
    with spans.span_trace("t") as tr:
        _chain()
    by_id = {s.span_id: s for s in tr.spans}
    slices = [s for s in tr.spans if s.kind == spans.KIND_SPAN]
    assert slices
    assert any(s.lane.endswith("/dma") for s in slices)
    assert any(s.lane.endswith("/compute") for s in slices)
    for s in slices:
        assert s.t1_s >= s.t0_s
        p = by_id.get(s.parent_id) if s.parent_id is not None else None
        if p is not None and p.kind == spans.KIND_SPAN and p.lane == s.lane:
            assert s.t0_s >= p.t0_s - 1e-9
            assert s.t1_s <= p.t1_s + 1e-9


def test_dispatch_phase_instants_parent_under_dispatch_span():
    with spans.span_trace("t") as tr:
        _chain()
    by_id = {s.span_id: s for s in tr.spans}
    phases = [s for s in tr.spans if s.kind == spans.KIND_INSTANT
              and s.name in ("cost", "plan", "launch", "lower")]
    assert phases
    for ph in phases:
        assert ph.parent_id is not None
        assert by_id[ph.parent_id].name.startswith("dispatch:")


def test_end_closes_abandoned_inner_opens():
    tr = spans.SpanTracer("t")
    outer = tr.begin("outer", "c", "host", 0.0)
    tr.begin("inner", "c", "host", 1.0)       # never explicitly ended
    tr.end(outer, 5.0)
    names = {s.name: s for s in tr.spans}
    assert names["inner"].t1_s == 5.0
    assert names["inner"].parent_id == names["outer"].span_id
    assert tr._stack == []


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_is_valid_and_json_round_trips(serve_tracer):
    tr, _ = serve_tracer
    trace = trace_export.chrome_trace(tr, meta={"run": "test"})
    assert trace_export.validate_chrome_trace(trace) == []
    assert trace["run"] == "test"
    back = json.loads(json.dumps(trace))
    assert back["traceEvents"] == trace["traceEvents"]
    for ev in back["traceEvents"]:
        assert "ph" in ev
        if ev["ph"] == "M":
            continue
        assert ev["ts"] >= 0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0


def test_flow_and_async_events_pair_up(serve_tracer):
    tr, _ = serve_tracer
    def count(kind):
        return collections.Counter(
            s.pair_id for s in tr.spans if s.kind == kind)
    assert count(spans.KIND_FLOW_S) and \
        count(spans.KIND_FLOW_S) == count(spans.KIND_FLOW_F)
    # every request lifecycle opened is closed (drain closes stragglers)
    assert count(spans.KIND_ASYNC_B) and \
        count(spans.KIND_ASYNC_B) == count(spans.KIND_ASYNC_E)


def test_counter_tracks_export_as_C_events(serve_tracer):
    tr, _ = serve_tracer
    assert tr.counters
    trace = trace_export.chrome_trace(tr)
    cs = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    assert cs
    assert all(isinstance(e["args"], dict) for e in cs)


def test_self_time_subtracts_direct_children():
    tr = spans.SpanTracer("t")
    parent = tr.emit("p", "c", "lane", 0.0, 10.0)
    tr.emit("k", "c", "lane", 2.0, 5.0, parent_id=parent.span_id)
    st = trace_export.self_time(tr.spans)
    assert st["lane"]["p"] == pytest.approx(7.0)
    assert st["lane"]["k"] == pytest.approx(3.0)
    assert "p" in trace_export.summarize(tr.spans)


def test_validator_catches_unpaired_flow():
    tr = spans.SpanTracer("t")
    tr.emit("half-flow", "c", "lane", 1.0, 1.0,
            kind=spans.KIND_FLOW_S, pair_id=99)
    trace = trace_export.chrome_trace(tr)
    assert trace_export.validate_chrome_trace(trace) != []


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------

def test_flight_recorder_keeps_exact_last_k_and_rides_analysis_errors():
    from repro.analysis.races import StreamRaceError, assert_race_free

    flight.configure(4)
    try:
        c = HeroCluster(num_devices=1)
        for i in range(7):
            c.launch(gemm_cost(512, 512, 512, 2), dtype="bfloat16",
                     shape_key=f"k{i}")
        bad = LaunchTicket(
            op="gemm", shape_key="bad", offload_s=1.0, issue_s=0.0,
            copy_ready_s=5.0, copy_done_s=6.0, compute_start_s=1.0,
            complete_s=2.0, device_id=0,
        )
        with pytest.raises(StreamRaceError) as ei:
            assert_race_free({0: [bad]})
        fl = ei.value.flight
        assert fl is not None and fl["capacity"] == 4
        window = fl["tickets"]["0"]
        assert [t["shape_key"] for t in window] == ["k3", "k4", "k5", "k6"]
        assert fl["violations"]
    finally:
        flight.configure(flight.DEFAULT_CAPACITY)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_metrics_labels_rollup_and_nested_collect_scopes():
    with metrics.collect() as outer:
        metrics.counter("hits", dev="0").inc()
        with metrics.collect() as inner:
            metrics.counter("hits", dev="0").inc(2.0)
            metrics.gauge("depth").set(7)
            metrics.histogram("lat", op="gemm").observe(1.0)
            metrics.histogram("lat", op="gemm").observe(3.0, n=3.0)
        metrics.counter("hits", dev="1").inc()
    r = outer.rollup()
    assert r["hits{dev=0}"] == 3.0
    assert r["hits{dev=1}"] == 1.0
    assert r["depth"] == 7.0
    assert r["lat{op=gemm}.count"] == 4.0
    assert r["lat{op=gemm}.sum"] == 10.0
    assert r["lat{op=gemm}.min"] == 1.0
    assert r["lat{op=gemm}.max"] == 3.0
    assert json.loads(json.dumps(r)) == r      # JSON-able as-is
    ri = inner.rollup()
    assert ri["hits{dev=0}"] == 2.0            # only its own scope's events
    assert "hits{dev=1}" not in ri


def test_stream_report_carries_metrics_rollup(serve_tracer):
    _, rep = serve_tracer
    point = rep.point_dict()
    assert point["metrics"]
    assert any(k.startswith("serve.admitted") for k in point["metrics"])


def test_dispatch_and_stream_counters_fire():
    with metrics.collect() as reg:
        _chain()
    r = reg.rollup()
    assert r.get("dispatch.calls{op=gemm}", 0) >= 2
    assert r.get("stream.tickets{kind=launch}", 0) >= 2


# ---------------------------------------------------------------------------
# obs-modeled-time-only lint rule
# ---------------------------------------------------------------------------

def test_obs_modeled_time_rule_fires_on_wallclock(tmp_path):
    from repro.analysis.lint import lint_file

    p = tmp_path / "src" / "repro" / "obs" / "bad.py"
    p.parent.mkdir(parents=True)
    p.write_text("import time\ndef now():\n    return time.time()\n")
    v = lint_file(p, tmp_path)
    assert {x.rule for x in v} == {"obs-modeled-time-only"}
    # ...and patrols the instrumented call sites, not just repro/obs
    p2 = tmp_path / "src" / "repro" / "core" / "dispatch.py"
    p2.parent.mkdir(parents=True)
    p2.write_text("from datetime import datetime\nT = datetime.now()\n")
    assert "obs-modeled-time-only" in {x.rule for x in lint_file(p2, tmp_path)}


# ---------------------------------------------------------------------------
# Requeue accounting (the ISSUE 9 bugfix)
# ---------------------------------------------------------------------------

def _burst(cluster):
    for i in range(4):
        cluster.launch(gemm_cost(512, 512, 512, 2), dtype="bfloat16",
                       shape_key=f"r{i}")


def test_requeued_compute_stays_on_the_accounting_books():
    # control: same burst, no failure
    c2 = HeroCluster(num_devices=2, scheduler="round-robin")
    with offload_trace() as t2:
        _burst(c2)
    base_compute = t2.by_device()[1].compute_s
    base_busy = t2.device_timelines()[1].compute_busy_s

    c = HeroCluster(num_devices=2, scheduler="round-robin")
    with offload_trace() as t:
        _burst(c)
        moved = c.fail_device(0)
    assert moved and all(dev == 1 for _, dev in moved)

    requeues = [r for r in t.records if r.note.startswith("requeue")]
    assert len(requeues) == len(moved)
    requeued = sum(r.regions.compute_s for r in requeues)
    assert requeued > 0
    for r in requeues:
        assert r.backend == "device" and r.device_id == 1
        assert r.op == "gemm"                  # op survives the move
        assert r.regions.copy_s == 0.0         # compute charged exactly once,
        assert r.regions.fork_join_s == 0.0    # no phantom re-staging

    # the survivor's rollups grew by exactly the requeued compute
    # (previously: the move recorded nothing and this delta was zero)
    assert t.by_device()[1].compute_s == pytest.approx(
        base_compute + requeued)
    assert t.device_timelines()[1].compute_busy_s == pytest.approx(
        base_busy + requeued)
    # the aborted attempts stay charged to the lost lane
    assert t.by_device()[0].compute_s == pytest.approx(
        t2.by_device()[0].compute_s)
