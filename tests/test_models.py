"""Per-arch smoke tests (reduced configs) + decode/forward consistency +
the registry-closure guard and eager-vs-graph forward parity."""

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import build_model

ARCHS = [a for a in list_archs() if a != "paper-gemm"]
RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Registry closure: the model zoo must dispatch exclusively through
# registered OffloadOp descriptors — no raw contraction launch sites, no
# bare engine accounting that the scheduler/cost model/trace cannot see.
# ---------------------------------------------------------------------------

def test_registry_closure_no_raw_launch_sites_in_models():
    """The two call-site patterns the seam refactor eliminated —
    ``*.dot_general(...)`` contractions and bare ``engine().launch(...)``
    accounting — are now named rules in the shared lint engine
    (``repro.analysis.lint``); this is a thin assertion that the model zoo
    stays clean under them."""
    import repro.models
    from repro.analysis.lint import RULES, lint_file, repo_root

    rules = [r for r in RULES
             if r.name in ("models-no-dot-general", "models-no-bare-launch")]
    assert len(rules) == 2, "lint engine must keep both models/ rules"
    root = repo_root()
    offenders = []
    for f in sorted(pathlib.Path(repro.models.__file__).parent.glob("*.py")):
        offenders.extend(lint_file(f, root, rules))
    assert not offenders, (
        "raw launch sites reappeared under src/repro/models/: "
        + "; ".join(v.render() for v in offenders)
        + " — register an OffloadOp descriptor instead (core/blas.py)"
    )


def _batch_for(cfg, b=2, s=16):
    if cfg.embed_inputs:
        tokens = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)
        batch = {"tokens": tokens}
    else:
        batch = {
            "embeds": jax.random.normal(RNG, (b, s, cfg.d_model), jnp.float32) * 0.1
        }
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
        batch["positions"] = pos
    batch["labels"] = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_one_train_step(arch):
    """Assigned-arch requirement: reduced config, one forward + train step
    on CPU, output shapes + no NaNs."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(RNG)
    batch = _batch_for(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))

    from repro.launch.steps import TrainOptions, init_train_state, make_train_step

    cfg1 = dataclasses.replace(cfg, num_microbatches=1)
    model1 = build_model(cfg1)
    opts = TrainOptions()
    opt_state, err = init_train_state(model1, params, opts)
    step = jax.jit(make_train_step(model1, opts))
    p2, o2, _, metrics = step(params, opt_state, None, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-moe-30b-a3b", "hubert-xlarge"])
def test_loss_decreases(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), num_microbatches=1)
    model = build_model(cfg)
    params = model.init_params(RNG)
    batch = _batch_for(cfg, b=4, s=16)

    from repro.launch.steps import TrainOptions, init_train_state, make_train_step

    opts = TrainOptions(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state, _ = init_train_state(model, params, opts)
    step = jax.jit(make_train_step(model, opts))
    losses = []
    err = None
    for _ in range(5):
        params, opt_state, err, m = step(params, opt_state, err, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-370m", "jamba-1.5-large-398b", "h2o-danube-1.8b", "gemma3-27b"])
def test_decode_matches_forward(arch):
    """Prefill through the decode path must reproduce forward logits —
    validates KV caches, rolling SWA buffers, SSD state recurrence, and
    hybrid cache threading in one shot."""
    cfg = get_arch(arch).reduced()
    # chunk must divide seq for the forward path; decode is step-by-step
    s = 16
    if cfg.ssm_state_dim:
        cfg = dataclasses.replace(cfg, ssm_chunk=8)
    model = build_model(cfg)
    params = model.init_params(RNG)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, s), 0, cfg.vocab_size)
    logits_fwd, _ = model.forward(params, {"tokens": tokens})

    cache = model.init_decode_cache(2, s)
    logits_dec = None
    for t in range(s):
        logits_dec, cache = model.decode_step(
            params, cache, tokens[:, t : t + 1], jnp.int32(t)
        )
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_fwd[:, -1, :], np.float32),
        rtol=2e-2, atol=2e-2,
    )


# ---------------------------------------------------------------------------
# Eager vs graph forward parity: cfg.forward_mode="graph" lowers each block
# as an hnp expression graph through the SAME registered descriptors, so the
# outputs must match within dtype tolerance on every backend — for the
# attention, SSM, and MoE block families.
# ---------------------------------------------------------------------------

_GRAPH_PARITY_ARCHS = ("yi-6b", "mamba2-370m", "qwen3-moe-30b-a3b")

_GRAPH_BACKENDS = {
    "host": dict(mode="host"),
    "device": dict(mode="device"),
    "device-pallas-interpret": dict(
        mode="device", use_pallas=True, interpret=True
    ),
}


@pytest.mark.parametrize("backend", sorted(_GRAPH_BACKENDS))
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_graph_forward_matches_eager(backend, dtype):
    from repro.core import engine, offload_policy

    tol = dict(rtol=6e-2, atol=6e-2) if dtype == "bfloat16" else dict(
        rtol=2e-4, atol=2e-4
    )
    for arch in _GRAPH_PARITY_ARCHS:
        cfg = dataclasses.replace(get_arch(arch).reduced(), dtype=dtype)
        model = build_model(cfg)
        params = model.init_params(RNG)
        batch = _batch_for(cfg)
        model_g = build_model(dataclasses.replace(cfg, forward_mode="graph"))
        with offload_policy(**_GRAPH_BACKENDS[backend]):
            engine().reset()
            logits, aux = model.forward(params, batch)
            engine().reset()
            logits_g, aux_g = model_g.forward(params, batch)
        np.testing.assert_allclose(
            np.asarray(logits_g, np.float32), np.asarray(logits, np.float32),
            err_msg=f"{arch} on {backend}/{dtype}", **tol,
        )
        np.testing.assert_allclose(
            float(aux_g), float(aux), rtol=1e-3, atol=1e-4,
            err_msg=f"{arch} aux on {backend}/{dtype}",
        )


def test_graph_forward_fuses_and_threads_residency():
    """The graph forward must actually exploit the graph: at least one
    fused elementwise epilogue (residual add / gate) per captured block
    kind, and strictly fewer staged bytes than eager under mode=device."""
    from repro.core import engine, offload_policy, offload_trace
    from repro.models import forward as F

    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init_params(RNG)
    batch = _batch_for(cfg)
    model_g = build_model(dataclasses.replace(cfg, forward_mode="graph"))
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        engine().reset()
        with offload_trace() as t_eager:
            model.forward(params, batch)
        engine().reset()
        with F.capture_reports() as reports:
            with offload_trace() as t_graph:
                model_g.forward(params, batch)
    assert reports, "graph forward captured no blocks"
    fused_launches = sum(
        1 for rep in reports for launch in rep.launches if launch.fused
    )
    assert fused_launches >= 1, "no elementwise epilogue fused"
    staged_eager = t_eager.total_staged_bytes_charged()
    staged_graph = t_graph.total_staged_bytes_charged()
    assert staged_graph < staged_eager, (staged_graph, staged_eager)


def test_swa_rolling_cache_bounded():
    """Danube's rolling cache must stay at window size regardless of
    decode length (what makes long_500k runnable)."""
    cfg = get_arch("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    cache = model.init_decode_cache(1, 1024)
    assert cache["k"].shape[3] == cfg.sliding_window  # bounded, not 1024


def test_gemma3_local_global_pattern():
    cfg = get_arch("gemma3-27b")
    kinds = [cfg.layer_window(i, 10**6) for i in range(12)]
    assert kinds[:5] == [1024] * 5 and kinds[5] > 10**5
    assert kinds[6:11] == [1024] * 5 and kinds[11] > 10**5
    thetas = [cfg.layer_rope_theta(i) for i in range(6)]
    assert thetas[:5] == [1.0e4] * 5 and thetas[5] == 1.0e6


def test_jamba_layer_pattern():
    cfg = get_arch("jamba-1.5-large-398b")
    kinds = [cfg.layer_kind(i) for i in range(8)]
    assert kinds.count("attn") == 1 and kinds[4] == "attn"
    assert [cfg.layer_is_moe(i) for i in range(4)] == [False, True, False, True]


def test_param_counts_match_billing():
    """Sanity: param_count() is within 20% of the advertised size."""
    expected = {
        "qwen2-72b": 72e9,
        "yi-6b": 6e9,
        "jamba-1.5-large-398b": 398e9,
        "arctic-480b": 480e9,
        "mamba2-370m": 370e6,
        "h2o-danube-1.8b": 1.8e9,
        "gemma3-27b": 27e9,
        "qwen3-moe-30b-a3b": 30e9,
    }
    for arch, n in expected.items():
        got = get_arch(arch).param_count()
        assert abs(got - n) / n < 0.35, f"{arch}: {got:.2e} vs {n:.2e}"


def test_active_params_moe():
    cfg = get_arch("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < total / 4  # 8 of 128 experts
    assert abs(active - 3e9) / 3e9 < 0.5
