"""Hypothesis property tests on attention-math invariants."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import blas


def _qkv(seed, b=1, hq=2, hkv=1, s=24, d=8):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(k1, (b, hq, s, d)),
        jax.random.normal(k2, (b, hkv, s, d)),
        jax.random.normal(k3, (b, hkv, s, d)),
    )


@given(seed=st.integers(0, 10_000), t=st.integers(1, 22))
@settings(max_examples=15, deadline=None)
def test_causality(seed, t):
    """Output at position t must not depend on k/v/q beyond t."""
    q, k, v = _qkv(seed)
    y1 = blas.attention_math(q, k, v, causal=True)
    k2 = k.at[:, :, t + 1 :, :].set(99.0)
    v2 = v.at[:, :, t + 1 :, :].set(-99.0)
    y2 = blas.attention_math(q, k2, v2, causal=True)
    np.testing.assert_allclose(
        np.asarray(y1[:, :, : t + 1]), np.asarray(y2[:, :, : t + 1]),
        rtol=1e-5, atol=1e-5,
    )


@given(seed=st.integers(0, 10_000), w=st.integers(1, 24))
@settings(max_examples=15, deadline=None)
def test_window_ge_seq_equals_full(seed, w):
    """A window ≥ seq length must equal full causal attention; and any
    window must only attend inside the window."""
    q, k, v = _qkv(seed)
    s = q.shape[2]
    full = blas.attention_math(q, k, v, causal=True)
    same = blas.attention_math(q, k, v, causal=True, window=s + w)
    np.testing.assert_allclose(np.asarray(full), np.asarray(same), rtol=1e-5, atol=1e-5)
    # windowed output at position t ignores kv older than t-w+1
    windowed = blas.attention_math(q, k, v, causal=True, window=w)
    k2 = k.at[:, :, 0, :].set(77.0)
    v2 = v.at[:, :, 0, :].set(-77.0)
    w2 = blas.attention_math(q, k2, v2, causal=True, window=w)
    np.testing.assert_allclose(
        np.asarray(windowed[:, :, w:]), np.asarray(w2[:, :, w:]),
        rtol=1e-5, atol=1e-5,
    )


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_rows_are_convex_combinations(seed):
    """Each output vector lies in the convex hull of v rows: bounded by
    per-dim min/max of v (softmax weights sum to 1)."""
    q, k, v = _qkv(seed)
    y = np.asarray(blas.attention_math(q, k, v, causal=False))
    vmin = np.asarray(v).min(axis=2, keepdims=True)
    vmax = np.asarray(v).max(axis=2, keepdims=True)
    assert (y >= vmin - 1e-4).all() and (y <= vmax + 1e-4).all()


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_batch_permutation_equivariance(seed):
    q, k, v = _qkv(seed, b=4)
    perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(seed), 4))
    y = blas.attention_math(q, k, v, causal=True)
    y_perm = blas.attention_math(q[perm], k[perm], v[perm], causal=True)
    np.testing.assert_allclose(
        np.asarray(y[perm]), np.asarray(y_perm), rtol=1e-5, atol=1e-5
    )


@given(seed=st.integers(0, 10_000), scale=st.floats(0.1, 4.0))
@settings(max_examples=10, deadline=None)
def test_value_scaling_linearity(seed, scale):
    """Attention is linear in V (softmax weights independent of V)."""
    q, k, v = _qkv(seed)
    y1 = np.asarray(blas.attention_math(q, k, v, causal=True))
    y2 = np.asarray(blas.attention_math(q, k, v * scale, causal=True))
    np.testing.assert_allclose(y1 * scale, y2, rtol=2e-4, atol=2e-4)
