"""Checkpointer: roundtrip, atomicity, retention, corruption detection."""

import json
import zlib
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, restore_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "step": jnp.int32(7),
        "nested": [jnp.arange(3), {"x": jnp.float32(2.5)}],
    }


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path / "c")
    got = restore_pytree(t, tmp_path / "c")
    _assert_tree_equal(t, got)


def test_checkpointer_latest_and_resume(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (10, 20, 30):
        ck.save(s, _tree(s))
    assert ck.latest_step() == 30
    got, step = ck.restore(_tree())
    assert step == 30
    _assert_tree_equal(got, _tree(30))


def test_keep_k_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in range(1, 6):
        ck.save(s, _tree(s))
    assert ck.steps() == [4, 5]


def test_no_tmp_dirs_visible(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save(1, _tree())
    names = [p.name for p in tmp_path.iterdir()]
    assert all(not n.endswith(".tmp") for n in names)


def test_corruption_detected(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path / "c")
    # flip bytes in one leaf file
    f = next((tmp_path / "c").glob("params__w.npy"))
    raw = bytearray(f.read_bytes())
    raw[-4] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="checksum|corrupt"):
        restore_pytree(t, tmp_path / "c")


def test_structure_mismatch_detected(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path / "c")
    t2 = dict(t)
    t2["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        restore_pytree(t2, tmp_path / "c")


def test_async_save_durable_and_ordered(tmp_path):
    ck = Checkpointer(tmp_path, keep=5)
    for s in (1, 2, 3):
        ck.save_async(s, _tree(s))
    ck.wait()
    assert ck.steps() == [1, 2, 3]
    got, step = ck.restore(_tree())
    assert step == 3
    _assert_tree_equal(got, _tree(3))


def test_async_save_snapshot_isolated_from_mutation(tmp_path):
    """The async writer must snapshot at call time — later donation/mutation
    of the live tree cannot corrupt the checkpoint."""
    import numpy as np

    ck = Checkpointer(tmp_path, keep=2)
    arr = np.ones((64,), np.float32)
    ck.save_async(1, {"w": arr})
    arr *= 0.0  # mutate the host buffer immediately
    ck.wait()
    got = ck.restore({"w": arr})[0]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones((64,), np.float32))


def test_mesh_agnostic_restore_onto_sharding(tmp_path):
    """Elastic path: restore with explicit shardings onto the local mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_pytree(t, tmp_path / "c")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got = restore_pytree(t, tmp_path / "c", shardings=sh)
    _assert_tree_equal(t, got)
    assert got["w"].sharding == sh["w"]
