"""The declarative offload-op registry: parity across backends + placement.

Every op registered in ``repro.core.dispatch`` must (a) compute the same
values on the host, device, and device-pallas(interpret) paths as its
``kernels/ref.py``/``jnp`` reference, across dtypes, and (b) leave trace
records that always carry a valid device placement.  The parity suite is
closed over the registry: registering a new op without adding a sample
here fails the suite, so the descriptor table and its tests stay in
one-to-one view.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blas, engine, offload_policy, offload_trace
from repro.core import dispatch as dsp
from repro.core.dispatch import OffloadOp
from repro.kernels import ref

RNG = np.random.default_rng(7)


def _arr(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.fixture(autouse=True)
def _reset_engine():
    engine().reset()
    yield
    engine().reset()


def _np32(x):
    return np.asarray(x, np.float32)


def _samples(dtype):
    """op name -> (call thunk, reference thunk) for the parity sweep."""
    a2, b2 = _arr(48, 32, dtype=dtype), _arr(32, 40, dtype=dtype)
    x3 = _arr(2, 12, 32, dtype=dtype)
    a3, b3 = _arr(3, 16, 24, dtype=dtype), _arr(3, 24, 16, dtype=dtype)
    xe, we = _arr(2, 8, 16, dtype=dtype), _arr(2, 16, 12, dtype=dtype)
    q = _arr(2, 4, 128, 32, dtype=dtype)
    k = _arr(2, 2, 128, 32, dtype=dtype)
    v = _arr(2, 2, 128, 32, dtype=dtype)
    sq = _arr(24, 40, dtype=dtype)
    ag, xg = _arr(24, 32, dtype=dtype), _arr(32, dtype=dtype)
    v1, v2 = _arr(64, dtype=dtype), _arr(64, dtype=dtype)
    wg, wu = _arr(32, 48, dtype=dtype), _arr(32, 48, dtype=dtype)
    wd = _arr(48, 32, dtype=dtype)

    def _mlp_ref():
        import jax

        xf = x3.astype(jnp.float32)
        g = (xf @ wg.astype(jnp.float32)).astype(dtype)
        u = (xf @ wu.astype(jnp.float32)).astype(dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
        return (h.astype(jnp.float32) @ wd.astype(jnp.float32)).astype(dtype)

    # fused qkv projection operands (GQA: kv heads smaller than q heads)
    wqp = _arr(32, 48, dtype=dtype)
    wkp = _arr(32, 16, dtype=dtype)
    wvp = _arr(32, 16, dtype=dtype)
    bqp = _arr(48, dtype=dtype)
    bkp = _arr(16, dtype=dtype)
    bvp = _arr(16, dtype=dtype)

    def _qkv_ref():
        xf = x3.astype(jnp.float32)

        def proj(w, b):
            return (xf @ w.astype(jnp.float32) + b.astype(jnp.float32))

        return jnp.concatenate(
            [proj(wqp, bqp), proj(wkp, bkp), proj(wvp, bvp)], axis=-1
        ).astype(dtype)

    # moe_expert_ffn operands
    wge = _arr(2, 16, 12, dtype=dtype)
    wue = _arr(2, 16, 12, dtype=dtype)
    wde = _arr(2, 12, 16, dtype=dtype)

    def _moe_ffn_ref():
        import jax

        xf = xe.astype(jnp.float32)
        g = jnp.einsum("ecd,edf->ecf", xf, wge.astype(jnp.float32)).astype(dtype)
        u = jnp.einsum("ecd,edf->ecf", xf, wue.astype(jnp.float32)).astype(dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
        return jnp.einsum(
            "ecf,efd->ecd", h.astype(jnp.float32), wde.astype(jnp.float32)
        ).astype(dtype)

    # ssd_scan operands (chunked SSD core vs naive recurrence oracle)
    sb, ss, sh, sp, sn = 1, 16, 2, 8, 4
    sx = _arr(sb, ss, sh, sp, dtype=dtype)
    sdt = jnp.asarray(
        np.abs(RNG.normal(size=(sb, ss, sh))) * 0.5, jnp.float32
    )
    sa = jnp.asarray(-np.abs(RNG.normal(size=(sh,))), jnp.float32)
    sbh = _arr(sb, ss, sh, sn, dtype=dtype)
    sch = _arr(sb, ss, sh, sn, dtype=dtype)
    sskip = jnp.ones((sh,), jnp.float32)

    def _ssd_ref():
        xv = _np32(sx)
        y = np.zeros((sb, ss, sh, sp), np.float32)
        st = np.zeros((sb, sh, sn, sp), np.float32)
        for t in range(ss):
            dec = np.exp(np.asarray(sdt[:, t]) * np.asarray(sa))
            st = dec[..., None, None] * st + np.einsum(
                "bh,bhn,bhp->bhnp",
                np.asarray(sdt[:, t]), _np32(sbh[:, t]), xv[:, t],
            )
            y[:, t] = np.einsum("bhn,bhnp->bhp", _np32(sch[:, t]), st)
        return y + xv * np.asarray(sskip)[None, None, :, None]

    # decode_attention operands (one token against a GQA KV cache)
    qd = _arr(2, 4, 1, 32, dtype=dtype)
    kd = _arr(2, 2, 24, 32, dtype=dtype)
    vd = _arr(2, 2, 24, 32, dtype=dtype)
    lo, hi = jnp.int32(0), jnp.int32(17)

    def _decode_ref():
        slots = jnp.arange(24, dtype=jnp.int32)
        return blas.attention_math(
            qd, kd, vd, causal=False,
            kv_mask=jnp.logical_and(slots >= lo, slots < hi),
        )

    scale_rn = _arr(32, dtype=dtype)
    xrn = _arr(24, 32, dtype=dtype)

    def _rmsnorm_ref():
        xf = _np32(xrn)
        var = np.mean(np.square(xf), axis=-1, keepdims=True)
        y = xf / np.sqrt(var + 1e-6) * _np32(scale_rn)
        return jnp.asarray(y).astype(dtype)

    return {
        "gemm": (
            lambda: blas.gemm(a2, b2),
            lambda: ref.gemm_ref(a2, b2),
        ),
        "matmul": (
            lambda: blas.matmul(x3, b2),
            lambda: jnp.einsum(
                "bsk,kn->bsn", x3.astype(jnp.float32), b2.astype(jnp.float32)
            ).astype(x3.dtype),
        ),
        "gemm_batched": (
            lambda: blas.gemm_batched(a3, b3),
            lambda: ref.gemm_batched_ref(a3, b3),
        ),
        "expert_matmul": (
            lambda: blas.expert_matmul(xe, we),
            lambda: ref.moe_gemm_ref(xe, we),
        ),
        "mlp_block": (
            lambda: blas.mlp_block(x3, wu, wd, gate=wg, kind="swiglu"),
            _mlp_ref,
        ),
        "attention": (
            lambda: blas.attention(q, k, v, causal=True),
            lambda: ref.attention_ref(q, k, v, causal=True),
        ),
        "syrk": (
            lambda: blas.syrk(sq),
            lambda: ref.gemm_ref(sq, sq.T),
        ),
        "gemv": (
            lambda: blas.gemv(ag, xg),
            lambda: ref.gemm_ref(ag, xg[:, None])[:, 0],
        ),
        "dot": (
            lambda: blas.dot(v1, v2),
            lambda: jnp.sum(
                v1.astype(jnp.float32) * v2.astype(jnp.float32)
            ).astype(v1.dtype),
        ),
        "axpy": (
            lambda: blas.axpy(2.0, v1, v2),
            lambda: 2.0 * v1 + v2,
        ),
        "scal": (
            lambda: blas.scal(0.5, v1),
            lambda: 0.5 * v1,
        ),
        "nrm2": (
            lambda: blas.nrm2(v1),
            lambda: jnp.sqrt(
                jnp.sum(jnp.square(v1.astype(jnp.float32)))
            ).astype(v1.dtype),
        ),
        "qkv_project": (
            lambda: blas.qkv_project(
                x3, wqp, wkp, wvp, bq=bqp, bk=bkp, bv=bvp
            ),
            _qkv_ref,
        ),
        "ssd_scan": (
            lambda: blas.ssd_scan(sx, sdt, sa, sbh, sch, sskip, chunk=8),
            _ssd_ref,
        ),
        "moe_expert_ffn": (
            lambda: blas.moe_expert_ffn(xe, wge, wue, wde),
            _moe_ffn_ref,
        ),
        "decode_attention": (
            lambda: blas.decode_attention(qd, kd, vd, lo, hi),
            _decode_ref,
        ),
        "sum": (
            lambda: blas.reduce_sum(x3, axis=-1),
            lambda: jnp.sum(x3, axis=-1),
        ),
        "mean": (
            lambda: blas.reduce_mean(x3, axis=0, keepdims=True),
            lambda: jnp.mean(x3, axis=0, keepdims=True),
        ),
        "relu": (
            lambda: blas.relu(a2),
            lambda: jnp.maximum(a2, 0.0),
        ),
        "silu": (
            lambda: blas.silu(a2),
            lambda: (
                a2.astype(jnp.float32)
                * jax.nn.sigmoid(a2.astype(jnp.float32))
            ).astype(a2.dtype),
        ),
        "rmsnorm_scale": (
            lambda: blas.rmsnorm_scale(xrn, scale_rn, eps=1e-6),
            _rmsnorm_ref,
        ),
    }


BACKEND_POLICIES = {
    "host": dict(mode="host"),
    "device": dict(mode="device"),
    "device-pallas-interpret": dict(
        mode="device", use_pallas=True, interpret=True
    ),
}


def _tol(dtype):
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=2e-5, atol=2e-5)


def test_parity_suite_covers_every_registered_op():
    """The registry and the parity table must stay in one-to-one view."""
    assert set(_samples(jnp.float32)) == set(dsp.registered_ops())


@pytest.mark.parametrize("backend", sorted(BACKEND_POLICIES))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_registered_ops_match_reference(backend, dtype):
    samples = _samples(dtype)
    for name in dsp.registered_ops():
        call, reference = samples[name]
        with offload_policy(**BACKEND_POLICIES[backend]):
            got = call()
        np.testing.assert_allclose(
            _np32(got), _np32(reference()), err_msg=f"{name} on {backend}",
            **_tol(dtype),
        )


def _run_all_ops():
    samples = _samples(jnp.float32)
    for name in dsp.registered_ops():
        samples[name][0]()


def test_every_trace_record_carries_valid_device_id():
    """Regression for the pre-registry drift: gemm/gemm_batched/
    expert_matmul/attention/syrk and the level-1/2 ops dropped the
    placement their launch chose.  Through the single dispatch() path every
    record must carry it: offloaded records name a real device, host
    records the host sentinel."""
    n_dev = 3
    with offload_policy(mode="device", num_devices=n_dev):
        engine().reset()
        with offload_trace() as t:
            _run_all_ops()
    assert len(t.records) == len(dsp.registered_ops())
    by_op = {r.op: r for r in t.records}
    for r in t.records:
        if r.backend.startswith("device"):
            assert 0 <= r.device_id < n_dev, (r.op, r.device_id)
        else:
            assert r.device_id == -1, (r.op, r.device_id)
    # host-only descriptors (syrk per the paper; the light reductions/
    # elementwise ops) are recorded on the host ...
    host_only = {n for n in dsp.registered_ops() if dsp.get_op(n).host_only}
    assert "syrk" in host_only
    for name in host_only:
        assert by_op[name].backend == "host", name
    # ... and everything else must be offloaded AND placed under mode=device
    for r in t.records:
        if r.op not in host_only:
            assert r.backend.startswith("device") and r.device_id >= 0, r.op


def test_dispatch_routes_to_pinned_handle_device():
    """A handle keys scheduling on the pinned buffer: cost-aware follows
    the residency credit to the handle's device."""
    with offload_policy(
        mode="device", num_devices=4, scheduler="cost-aware"
    ):
        eng = engine()
        eng.reset()
        h = eng.pin_handle("weights", 1 << 20, device_id=2)
        a, b = _arr(256, 256), _arr(256, 256)
        with offload_trace() as t:
            blas.gemm(a, b, handle=h)
        (rec,) = t.records
        assert rec.device_id == 2


def test_unknown_op_and_duplicate_registration_raise():
    with pytest.raises(KeyError, match="unknown offload op"):
        dsp.get_op("cholesky")
    gemm_desc = dsp.get_op("gemm")
    # idempotent: re-registering the identical descriptor is a no-op
    dsp.register(gemm_desc)
    clone = OffloadOp(name="gemm", cost=lambda: None, host=lambda: None)
    with pytest.raises(ValueError, match="already registered"):
        dsp.register(clone)
