"""Chunked double-buffered staging: cost model, accounting, prefetch, bench.

The overlap contracts of ISSUE 6:

1. **Makespan bounds** — any pipelined makespan lies in
   ``[max(copy, compute), copy + compute]`` and is monotone (non-increasing)
   in chunk count.
2. **Degenerate safety** — staged bytes not divisible by the chunk tile,
   1-chunk ops and zero-staging ops produce no division-by-zero, NaN, or
   negative ``copy_fraction``.
3. **Accounting** — ``device_timelines()`` gates compute on the *first*
   staging leg of a pipelined launch (the DMA shingles under compute), a
   fully-resident launch occupies the DMA engine for exactly zero seconds,
   and ``migrate_handle``'s d2d charge lands in one DMA window only.
4. **Frontend prefetch** — with ``prefetch_staging`` on, wave k+1's leaf
   operands stage while wave k computes, and the consumer takes the
   residency credit.
"""

import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    HESOC_VCU128,
    TPU_V5E,
    OpCost,
    breakdown,
    engine,
    gemm_cost,
    offload_policy,
    offload_trace,
    pipeline_makespan,
    pipelined_breakdown,
    staging_legs,
)
from repro.core.accounting import OffloadRecord
from repro.core.cost_model import MAX_PIPELINE_CHUNKS, RegionBreakdown

EPS = 1e-12


@pytest.fixture(autouse=True)
def _reset_engine():
    engine().reset()
    yield
    engine().reset()


def _cost(staged, flops=1e6, touched=None):
    return OpCost(
        op="gemm",
        flops=flops,
        staged_bytes=staged,
        touched_bytes=staged if touched is None else touched,
    )


# ---------------------------------------------------------------------------
# 1. Makespan bounds + monotonicity (hypothesis sweeps)
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(
    staged=st.floats(min_value=1.0, max_value=1e9),
    flops=st.floats(min_value=1.0, max_value=1e13),
    chunks=st.integers(min_value=1, max_value=128),
)
def test_pipelined_makespan_within_bounds(staged, flops, chunks):
    for plat in (HESOC_VCU128, TPU_V5E):
        bd = pipelined_breakdown(_cost(staged, flops), plat, chunks=chunks)
        lo = max(bd.copy_s, bd.compute_s)
        hi = bd.copy_s + bd.compute_s
        assert lo - EPS <= bd.overlapped_s <= hi + EPS
        assert bd.offload_s <= bd.serial_s + EPS
        assert bd.pipelined_speedup >= 1.0 - EPS


@settings(max_examples=25)
@given(
    staged=st.floats(min_value=1.0, max_value=1e9),
    flops=st.floats(min_value=1.0, max_value=1e13),
)
def test_pipelined_makespan_monotone_in_chunks(staged, flops):
    """Doubling the chunk count never makes the modeled schedule worse."""
    for plat in (HESOC_VCU128, TPU_V5E):
        prev = None
        for k in (1, 2, 4, 8, 16, 32, 64):
            bd = pipelined_breakdown(_cost(staged, flops), plat, chunks=k)
            if prev is not None:
                assert bd.overlapped_s <= prev + EPS
            prev = bd.overlapped_s


@settings(max_examples=40)
@given(
    legs=st.lists(
        st.floats(min_value=0.0, max_value=10.0), min_size=1, max_size=20
    ),
    work=st.lists(
        st.floats(min_value=0.0, max_value=10.0), min_size=1, max_size=20
    ),
    buffers=st.integers(min_value=1, max_value=4),
)
def test_pipeline_makespan_raw_bounds(legs, work, buffers):
    """The leg-level simulator honors the bounds for *unequal* legs too."""
    k = min(len(legs), len(work))
    legs, work = legs[:k], work[:k]
    span = pipeline_makespan(legs, work, buffers=buffers)
    assert max(sum(legs), sum(work)) - EPS <= span
    assert span <= sum(legs) + sum(work) + EPS


# ---------------------------------------------------------------------------
# 2. Degenerate chunk math (the satellite bugfix)
# ---------------------------------------------------------------------------

def test_staging_legs_remainder_and_cap():
    # not divisible: full legs + one remainder, summing exactly
    legs = staging_legs(10_000.0, 4096.0)
    assert len(legs) == 3
    assert legs[:2] == (4096.0, 4096.0)
    assert abs(sum(legs) - 10_000.0) < EPS
    # degenerate: zero bytes, zero/None/oversized chunk -> one leg
    assert staging_legs(0.0, 4096.0) == (0.0,)
    assert staging_legs(100.0, 0.0) == (100.0,)
    assert staging_legs(100.0, None) == (100.0,)
    assert staging_legs(100.0, 200.0) == (100.0,)
    # tiny tile: capped at MAX_PIPELINE_CHUNKS equal legs, sum preserved
    legs = staging_legs(1e9, 1.0)
    assert len(legs) == MAX_PIPELINE_CHUNKS
    assert abs(sum(legs) - 1e9) < 1.0


@settings(max_examples=40)
@given(
    staged=st.floats(min_value=0.0, max_value=1e9),
    chunk=st.floats(min_value=0.0, max_value=1e8),
)
def test_staging_legs_always_sum_and_positive(staged, chunk):
    legs = staging_legs(staged, chunk)
    assert all(b >= 0.0 for b in legs)
    assert abs(sum(legs) - max(staged, 0.0)) <= max(staged, 1.0) * 1e-9


def test_one_chunk_degenerate_matches_serial():
    """A single-chunk pipeline cannot overlap anything: serial numbers."""
    cost = _cost(1000.0, flops=1e7)
    for plat in (HESOC_VCU128, TPU_V5E):
        p = pipelined_breakdown(cost, plat, chunks=1)
        s = breakdown(cost, plat)
        assert p.chunks == 1
        assert p.offload_s == pytest.approx(s.offload_s)
        assert 0.0 <= p.copy_fraction <= 1.0


def test_zero_staged_bytes_no_nan():
    """Fully-resident (or zero-operand) launches: no division hazards."""
    cost = _cost(0.0, flops=1e9, touched=1e6)
    for plat in (HESOC_VCU128, TPU_V5E):
        for rf in (0.0, 1.0):
            p = pipelined_breakdown(cost, plat, resident_fraction=rf)
            assert p.copy_s == 0.0
            assert p.copy_fraction == 0.0
            assert p.offload_s == pytest.approx(
                plat.t_fork_join() + p.overlapped_s
            )
            assert p.overlapped_s == pytest.approx(p.compute_s)


@settings(max_examples=40)
@given(
    staged=st.floats(min_value=0.0, max_value=1e9),
    flops=st.floats(min_value=0.0, max_value=1e13),
    rf=st.floats(min_value=0.0, max_value=1.0),
)
def test_copy_fraction_never_negative(staged, flops, rf):
    for plat in (HESOC_VCU128, TPU_V5E):
        p = pipelined_breakdown(
            _cost(staged, flops), plat, resident_fraction=rf
        )
        assert 0.0 <= p.copy_fraction <= 1.0 + EPS
        assert p.exposed_copy_s >= 0.0
        assert p.hidden_copy_s >= 0.0


# ---------------------------------------------------------------------------
# 3. Accounting: chunk gating, zero-DMA residency, d2d single-count
# ---------------------------------------------------------------------------

def _record(regions, *, op="gemm", rf=0.0, device_id=0, count=1.0):
    return OffloadRecord(
        op=op, shape_key="k", dtype="float32", backend="device",
        cost=_cost(regions.copy_s), regions=regions, zero_copy=False,
        device_id=device_id, resident_fraction=rf, count=count,
    )


def test_fully_resident_launch_zero_dma_occupancy():
    """Regression (ISSUE 6 satellite): resident_fraction=1.0 must not
    occupy the DMA engine even if the record carries a copy region."""
    from repro.core import offload_trace

    with offload_trace() as t:
        t.add(_record(
            RegionBreakdown(copy_s=5.0, fork_join_s=0.5, compute_s=2.0,
                            host_s=0.0),
            rf=1.0,
        ))
    tl = t.device_timelines()[0]
    assert tl.dma_busy_s == 0.0
    assert tl.makespan_s == pytest.approx(2.5)
    assert tl.serial_s == pytest.approx(2.5)


def test_timeline_gates_compute_on_first_chunk_leg():
    """A pipelined launch's compute starts after ONE staging leg, not the
    whole copy: the makespan beats the serial schedule by the hidden legs."""
    from repro.core import offload_trace

    cost = gemm_cost(128, 128, 128, 8)
    p = pipelined_breakdown(cost, HESOC_VCU128)
    assert p.chunks > 1
    with offload_trace() as t:
        t.add(_record(p))
    tl = t.device_timelines()[0]
    work = p.fork_join_s + p.compute_s
    assert tl.makespan_s == pytest.approx(
        max(p.copy_s, p.first_copy_leg_s + work)
    )
    assert tl.makespan_s < p.copy_s + work - EPS  # genuinely shingled
    assert tl.serial_s == pytest.approx(p.copy_s + work)
    assert tl.dma_busy_s == pytest.approx(p.copy_s)
    assert tl.compute_busy_s == pytest.approx(work)


def test_timeline_repeat_counts_keep_bounds():
    from repro.core import offload_trace

    cost = gemm_cost(128, 128, 128, 8)
    p = pipelined_breakdown(cost, HESOC_VCU128)
    with offload_trace() as t:
        t.add(_record(p, count=7.0))
    tl = t.device_timelines()[0]
    work = p.fork_join_s + p.compute_s
    assert tl.dma_busy_s == pytest.approx(7 * p.copy_s)
    assert tl.compute_busy_s == pytest.approx(7 * work)
    assert max(tl.dma_busy_s, tl.compute_busy_s) <= tl.makespan_s + EPS
    assert tl.makespan_s <= tl.serial_s + EPS


def test_migrate_d2d_not_double_counted_in_dma_window():
    """migrate_handle charges the destination DMA stream exactly once: the
    timeline's DMA occupancy equals staging + d2d summed over records, and
    adding the migration moves the makespan by at most its d2d time."""
    with offload_policy(
        mode="device", platform="hesoc-vcu128", num_devices=2,
        scheduler="cost-aware",
    ) as eng:
        cost = gemm_cost(128, 128, 128, 8)
        with offload_trace() as t:
            h = eng.pin_handle("kv", 65536.0, device_id=1)
            eng.launch(cost, dtype="float64", shape_key="gemm:128")
            before = t.device_timelines()
            eng.migrate_handle(h, 0)
            after = t.device_timelines()
    recs = [r for r in t.offloaded() if r.device_id == 0]
    d2d_total = sum(r.regions.d2d_s for r in recs)
    staging_total = sum(
        0.0 if r.resident_fraction >= 1.0 else r.regions.copy_s for r in recs
    )
    tl = after[0]
    assert tl.dma_busy_s == pytest.approx(staging_total + d2d_total)
    # exactly one d2d record, charged once
    d2d_recs = [r for r in recs if r.op == "d2d_copy"]
    assert len(d2d_recs) == 1
    assert d2d_total == pytest.approx(d2d_recs[0].regions.d2d_s)
    assert tl.makespan_s <= before[0].makespan_s + d2d_recs[0].regions.offload_s + EPS


def test_issue_advances_stream_clocks():
    """The event-driven launch path stamps the device stream clocks."""
    with offload_policy(
        mode="device", platform="hesoc-vcu128", num_devices=1,
    ) as eng:
        cost = gemm_cost(128, 128, 128, 8)
        res = eng.launch(cost, dtype="float64", shape_key="gemm:128")
        dev = eng.devices[res.device_id]
        p = pipelined_breakdown(cost, eng.platform)
        assert dev.dma_free_s == pytest.approx(p.copy_s)
        assert dev.compute_free_s == pytest.approx(
            p.first_copy_leg_s + p.fork_join_s + p.compute_s
        )
        assert dev.stream_makespan_s < p.copy_s + p.fork_join_s + p.compute_s
        t = dev.inflight[-1]
        assert t.complete_s == pytest.approx(dev.compute_free_s)
        assert t.copy_done_s == pytest.approx(dev.dma_free_s)


# ---------------------------------------------------------------------------
# 4. Acceptance + policy wiring
# ---------------------------------------------------------------------------

def test_tpu_n2048_offload_within_15pct_of_max():
    cost = gemm_cost(2048, 2048, 2048, 4)
    p = pipelined_breakdown(cost, TPU_V5E)
    assert p.offload_s <= 1.15 * max(p.copy_s, p.compute_s)


def test_paper_crossover_pipelined_speedup():
    """heSoC n=128 float64 — the paper's balanced copy/compute regime —
    gains >= 1.5x from double-buffered staging (ROADMAP open item 2)."""
    cost = gemm_cost(128, 128, 128, 8)
    p = pipelined_breakdown(cost, HESOC_VCU128)
    assert p.pipelined_speedup >= 1.5


def test_policy_pipeline_staging_off_restores_serial():
    cost = gemm_cost(128, 128, 128, 8)
    with offload_policy(
        mode="device", platform="hesoc-vcu128", pipeline_staging=False,
    ) as eng:
        with offload_trace() as t:
            eng.launch(cost, dtype="float64", shape_key="gemm:128")
    serial = breakdown(cost, HESOC_VCU128)
    assert t.records[0].regions.offload_s == pytest.approx(serial.offload_s)


def test_dispatch_sees_pipelined_cost():
    cost = gemm_cost(128, 128, 128, 8)
    with offload_policy(mode="device", platform="hesoc-vcu128") as eng:
        with offload_trace() as t:
            eng.launch(cost, dtype="float64", shape_key="gemm:128")
    rec = t.records[0]
    pipelined = pipelined_breakdown(cost, HESOC_VCU128)
    assert rec.regions.offload_s == pytest.approx(pipelined.offload_s)
    assert rec.regions.offload_s < breakdown(cost, HESOC_VCU128).offload_s


# ---------------------------------------------------------------------------
# 5. Frontend cross-wave prefetch
# ---------------------------------------------------------------------------

def test_prefetch_stages_next_wave_operands():
    import jax.numpy as jnp
    import numpy as np

    import repro.hnp as hnp

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)

    with offload_policy(
        mode="device", num_devices=2, scheduler="cost-aware",
        prefetch_staging=True,
    ):
        with offload_trace() as t:
            with hnp.offload_region("prefetch-chain") as region:
                h = hnp.array(x) @ w0
                out = hnp.asnumpy(h @ w1)
    pf = [r for r in t.records if r.op == "prefetch_stage"]
    assert pf, "prefetch_staging should issue prefetch_stage records"
    assert region.report.prefetched_bytes >= w1.nbytes
    # the consumer took the residency credit for the prefetched operand
    consumer = region.report.launches[-1]
    assert consumer.resident_fraction > 0.5
    assert consumer.staged_in_bytes < w1.nbytes
    # value parity: prefetch is a scheduling hint, not a numeric change
    want = np.asarray(x) @ np.asarray(w0) @ np.asarray(w1)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_prefetch_off_by_default_no_records():
    import jax.numpy as jnp
    import numpy as np

    import repro.hnp as hnp

    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    with offload_policy(mode="device", num_devices=2):
        with offload_trace() as t:
            hnp.asnumpy(hnp.array(x) @ w @ w)
    assert not [r for r in t.records if r.op == "prefetch_stage"]


# ---------------------------------------------------------------------------
# 6. Trajectory dedupe + ci_run (satellite bugfix)
# ---------------------------------------------------------------------------

def _fake_summary(**over):
    base = {
        "cluster_scaling": {"cost-aware_scaling_8dev": 7.0},
        "serve_makespan": {"pinned_speedup": 3.5},
        "frontend_graph": {
            "modeled_speedup": 1.4, "staging_bytes_saved": 1000.0,
        },
        "model_forward": {
            "modeled_speedup": 1.05, "staging_bytes_saved": 500.0,
            "fused_launches": 1,
        },
        "pipelined_staging": {
            "paper_crossover": {"pipelined_speedup": 1.56},
            "tpu_large_n_steady": {"pipelined_copy_fraction": 0.34},
            "tpu_n2048": {"pipelined_vs_max": 1.01},
        },
        "offered_load_sweep": {
            "max_qps_at_slo": 174.0,
            "continuous_vs_lockstep": {"speedup": 1.42},
        },
        "failover_accounting": {"requeued_compute_s": 1.1e-4},
        "expert_placement": {"expert_placement_speedup": 1.49},
        "elapsed_s": 1.0,
    }
    base.update(over)
    return base


def test_trajectory_dedupes_same_commit_same_headline(tmp_path, monkeypatch):
    from benchmarks.run import _append_trajectory

    monkeypatch.setenv("GITHUB_SHA", "deadbee")
    path = str(tmp_path / "traj.jsonl")
    _append_trajectory(_fake_summary(), path)
    # identical headline, different elapsed_s -> still one line
    _append_trajectory(_fake_summary(elapsed_s=2.0), path)
    lines = [json.loads(l) for l in open(path) if l.strip()]
    assert len(lines) == 1
    # a changed modeled number is a new point on the trajectory
    changed = _fake_summary()
    changed["serve_makespan"] = {"pinned_speedup": 9.9}
    _append_trajectory(changed, path)
    lines = [json.loads(l) for l in open(path) if l.strip()]
    assert len(lines) == 2


def test_trajectory_compacts_preexisting_duplicates(tmp_path, monkeypatch):
    from benchmarks.run import _append_trajectory, _headline_hash

    monkeypatch.setenv("GITHUB_SHA", "deadbee")
    path = str(tmp_path / "traj.jsonl")
    dup = {"commit": "0ldc0de", "timestamp": "t", "ci_run": "",
           "headline": {"x": 1.0, "elapsed_s": 5.0}}
    with open(path, "w") as f:
        f.write(json.dumps(dup) + "\n")
        dup2 = dict(dup, headline={"x": 1.0, "elapsed_s": 9.0})
        f.write(json.dumps(dup2) + "\n")
    assert _headline_hash(dup["headline"]) == _headline_hash(dup2["headline"])
    _append_trajectory(_fake_summary(), path)
    lines = [json.loads(l) for l in open(path) if l.strip()]
    assert len(lines) == 2  # compacted duplicate + the new entry
    assert lines[0]["commit"] == "0ldc0de"


def test_trajectory_ci_run_populated_from_env(tmp_path, monkeypatch):
    from benchmarks.run import _append_trajectory

    monkeypatch.setenv("GITHUB_SHA", "deadbee")
    path = str(tmp_path / "traj.jsonl")
    monkeypatch.delenv("GITHUB_RUN_ID", raising=False)
    monkeypatch.setenv("CI_RUN_ID", "run-42")
    entry = _append_trajectory(_fake_summary(), path)
    assert entry["ci_run"] == "run-42"
    monkeypatch.setenv("GITHUB_RUN_ID", "gha-7")
    changed = _fake_summary()
    changed["serve_makespan"] = {"pinned_speedup": 8.8}
    entry = _append_trajectory(changed, path)
    assert entry["ci_run"] == "gha-7"
