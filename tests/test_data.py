"""Data pipeline: determinism, host-disjointness, restart purity."""

import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.data import MemmapTokens, SyntheticLM, make_batches


def test_deterministic_by_step():
    d = SyntheticLM(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    a, b = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab_size=50, seq_len=16, global_batch=2, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)


def test_hosts_get_different_data():
    kw = dict(vocab_size=100, seq_len=8, global_batch=8, seed=3, num_hosts=2)
    h0 = SyntheticLM(host_id=0, **kw).batch(0)
    h1 = SyntheticLM(host_id=1, **kw).batch(0)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


@given(step=st.integers(0, 1_000_000))
@settings(max_examples=20, deadline=None)
def test_tokens_in_vocab(step):
    d = SyntheticLM(vocab_size=37, seq_len=8, global_batch=2, seed=1)
    b = d.batch(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 37


def test_restart_purity_matches_iterator():
    d = SyntheticLM(vocab_size=64, seq_len=4, global_batch=2, seed=9)
    it = make_batches(d, start_step=0)
    seq = [next(it)["tokens"] for _ in range(6)]
    it2 = make_batches(d, start_step=3)  # "restart from checkpoint at step 3"
    resumed = [next(it2)["tokens"] for _ in range(3)]
    for a, b in zip(seq[3:], resumed):
        np.testing.assert_array_equal(a, b)


def test_memmap_source(tmp_path):
    path = tmp_path / "toks.bin"
    arr = (np.arange(10_000) % 91).astype(np.int32)
    arr.tofile(path)
    d = MemmapTokens(str(path), vocab_size=91, seq_len=32, global_batch=4, seed=0)
    b0, b0b = d.batch(0), d.batch(0)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert b0["tokens"].shape == (4, 32)
    assert b0["tokens"].max() < 91


def test_memmap_too_small(tmp_path):
    path = tmp_path / "tiny.bin"
    np.arange(4, dtype=np.int32).tofile(path)
    with pytest.raises(ValueError):
        MemmapTokens(str(path), vocab_size=10, seq_len=32, global_batch=1)


def test_zipf_skew():
    """Zipfian stream: low token ids must be much more frequent."""
    d = SyntheticLM(vocab_size=1000, seq_len=512, global_batch=8, seed=2)
    t = d.batch(0)["tokens"].ravel()
    low = (t < 10).mean()
    high = ((t >= 500) & (t < 510)).mean()
    assert low > 10 * (high + 1e-9)
