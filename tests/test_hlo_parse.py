"""Trip-count-aware HLO analyzer: exactness on known scan structures."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import parse_collectives, roofline_terms
from repro.roofline.hlo_parse import analyze_module


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_single_dot_exact():
    c = _compile(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 16), jnp.float32),
    )
    mc = analyze_module(c.as_text())
    assert mc.dot_flops == pytest.approx(2 * 64 * 32 * 16, rel=0.01)


@pytest.mark.parametrize("trip", [1, 5, 33])
def test_scan_trip_count(trip):
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    c = _compile(
        f,
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((trip, 128, 128), jnp.float32),
    )
    mc = analyze_module(c.as_text())
    assert mc.dot_flops == pytest.approx(2 * 128**3 * trip, rel=0.02)


def test_nested_scan():
    def g(x, ws):
        def outer(c, wo):
            def inner(ci, w):
                return ci @ w, ()
            c2, _ = jax.lax.scan(inner, c, ws)
            return c2 @ wo, ()
        y, _ = jax.lax.scan(outer, x, jnp.stack([jnp.eye(128)] * 3))
        return y.sum()

    c = _compile(
        g,
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((5, 128, 128), jnp.float32),
    )
    mc = analyze_module(c.as_text())
    assert mc.dot_flops == pytest.approx(2 * 128**3 * (3 * 5 + 3), rel=0.02)


def test_traffic_nonzero_and_scales_with_trip():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    m1 = analyze_module(
        _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((2, 64, 64), jnp.float32)).as_text()
    )
    m2 = analyze_module(
        _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((20, 64, 64), jnp.float32)).as_text()
    )
    assert m2.traffic_bytes > 5 * m1.traffic_bytes


def test_collective_parse_synthetic():
    hlo = """
HloModule test
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %ag = f32[64,8]{1,0} all-gather(%a), dimensions={0}
  %ar = f32[8,8]{1,0} all-reduce(%a), to_apply=%sum
  ROOT %out = f32[8,8] copy(%ar)
}
"""
    c = parse_collectives(hlo)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 64 * 8 * 4
    assert c["all-reduce"]["bytes"] == 8 * 8 * 4
    assert c["total"]["count"] == 2


def test_roofline_terms_math():
    r = roofline_terms(197e12, 819e9, 50e9, chips=1)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory", "collective")
