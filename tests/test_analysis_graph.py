"""Pass 1 (``repro.analysis.graph``) — the hnp graph verifier.

Clean graphs verify clean; each seeded corruption (shape/dtype lies, stale
cached values, dead or escaped residency handles, double-staged buffers,
hazardous wave plans) produces its precisely named violation.  The
``validate=True`` surfaces on ``dispatch_placed`` and ``offload_region``
raise before anything launches.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.hnp as hnp
from repro.analysis import Violation
from repro.analysis.graph import (
    GraphVerificationError,
    WavePlan,
    check_plan,
    collect_nodes,
    plan_waves,
    verify_call,
    verify_graph,
)
from repro.core import engine, offload_policy
from repro.core.dispatch import dispatch_placed


def rules(violations):
    return {v.rule for v in violations}


@pytest.fixture(autouse=True)
def _host_mode():
    engine().reset()
    with offload_policy(mode="host"):
        yield
    engine().reset()


def _gemm_chain():
    a = hnp.array(np.ones((8, 6), np.float32))
    b = hnp.array(np.ones((6, 4), np.float32))
    return a, b, hnp.tanh(a @ b) + 1.0


# ---------------------------------------------------------------------------
# clean paths
# ---------------------------------------------------------------------------

def test_clean_graph_verifies_clean():
    _, _, y = _gemm_chain()
    assert verify_graph([y.node]) == []


def test_clean_region_validates_and_matches_reference():
    x = np.asarray(np.random.default_rng(0).normal(size=(32, 16)), np.float32)
    w = np.asarray(np.random.default_rng(1).normal(size=(16, 8)), np.float32)
    with hnp.offload_region("validated", validate=True):
        got = hnp.asnumpy(hnp.tanh(hnp.array(x) @ w))
    np.testing.assert_allclose(got, np.tanh(x @ w), rtol=1e-5, atol=1e-5)


def test_collect_nodes_covers_evaluated_subgraph():
    a, b, y = _gemm_chain()
    ids = {n.id for n in collect_nodes([y.node])}
    assert a.node.id in ids and b.node.id in ids and y.node.id in ids


@settings(max_examples=10)
@given(
    st.tuples(st.integers(min_value=1, max_value=9),
              st.integers(min_value=1, max_value=9),
              st.integers(min_value=1, max_value=9)),
    st.one_of(st.just("tanh"), st.just("relu"), st.just("exp")),
)
def test_random_clean_graphs_verify_clean(dims, act):
    m, k, n = dims
    a = hnp.array(np.ones((m, k), np.float32))
    b = hnp.array(np.ones((k, n), np.float32))
    y = getattr(hnp, act)(a @ b)
    assert verify_graph([y.node]) == []


# ---------------------------------------------------------------------------
# structural corruption -> named violations
# ---------------------------------------------------------------------------

def test_seeded_shape_mismatch_is_named():
    _, _, y = _gemm_chain()
    y.node.inputs[0].shape = (99, 99)   # lie about the gemm's result shape
    assert "graph/shape-mismatch" in rules(verify_graph([y.node]))


def test_seeded_dtype_mismatch_is_named():
    a, _, _ = _gemm_chain()
    z = a + a
    z.node.dtype = np.dtype(np.float64)
    assert "graph/dtype-mismatch" in rules(verify_graph([z.node]))


def test_stale_cached_value_is_named():
    a, _, _ = _gemm_chain()
    z = a + a
    z.node.set_value(np.zeros((8, 6), np.float32))  # cache over live inputs: ok
    assert verify_graph([z.node]) == []
    g = hnp.tanh(a)                        # unevaluated producer
    z.node.inputs = (g.node, g.node)       # spliced under the cached consumer
    assert "graph/stale-value" in rules(verify_graph([z.node]))


def test_unknown_op_is_named():
    from repro.frontend.lazy import Node

    a, _, _ = _gemm_chain()
    bogus = Node("frobnicate", (a.node,), {}, (8, 6), np.dtype(np.float32))
    assert "graph/unknown-op" in rules(verify_graph([bogus]))


def test_bad_arity_is_named():
    from repro.frontend.lazy import Node

    a, _, _ = _gemm_chain()
    bad = Node("add", (a.node,), {}, (8, 6), np.dtype(np.float32))
    assert "graph/bad-arity" in rules(verify_graph([bad]))


# ---------------------------------------------------------------------------
# residency lifetimes
# ---------------------------------------------------------------------------

def test_use_after_unstage_is_named():
    eng = engine()
    h = eng.pin_handle("uau", 4096.0, device_id=0)
    a = hnp.array(np.ones((4, 4), np.float32))
    a.node.attrs["handle"] = h
    eng.unstage_handle(h)
    v = verify_graph([(a @ a).node])
    assert "graph/use-after-unstage" in rules(v)
    assert any("uau" in x.message for x in v)


def test_handle_escaping_its_region_is_named():
    eng = engine()
    h = eng.pin_handle("esc", 4096.0, device_id=0)
    a = hnp.array(np.ones((4, 4), np.float32))
    a.node.attrs["handle"] = h
    eng._handles.pop("esc")               # ledger forgets it; token stays valid
    assert "graph/handle-escapes-region" in rules(verify_graph([(a @ a).node]))


def test_double_stage_of_same_buffer_is_named():
    eng = engine()
    x = np.ones((4, 4), np.float32)
    a = hnp.array(x)
    b = hnp.array(x)                      # same underlying buffer, new leaf
    b.node.set_value(a.node.value)        # unify the buffers explicitly
    a.node.attrs["handle"] = eng.pin_handle("h1", 64.0, device_id=0)
    b.node.attrs["handle"] = eng.pin_handle("h2", 64.0, device_id=0)
    v = verify_graph([(a @ b).node])
    assert "graph/double-stage" in rules(v)


# ---------------------------------------------------------------------------
# wave-schedule hazards (corrupted plans -> named violations)
# ---------------------------------------------------------------------------

def _diamond():
    a = hnp.array(np.ones((8, 8), np.float32))
    y = hnp.tanh(a @ a)
    z = y @ a                              # heavy consumer of tanh
    w = hnp.relu(y)                        # elementwise consumer of tanh
    return a, y, z, w


def test_real_plan_is_hazard_free():
    _, _, z, w = _diamond()
    plan = plan_waves([z.node, w.node])
    assert check_plan(plan) == []
    assert len(plan.waves) >= 2


def test_raw_hazard_consumer_scheduled_with_producer():
    _, _, z, w = _diamond()
    plan = plan_waves([z.node, w.node])
    flat = [[n for wave in plan.waves for n in wave]]   # everything in wave 0
    v = check_plan(WavePlan(plan.order, flat, {}, [], []))
    assert "graph/raw-hazard" in rules(v)


def test_raw_hazard_dependent_nodes_in_one_stacked_launch():
    _, _, z, w = _diamond()
    plan = plan_waves([z.node, w.node])
    heavy = [n for n in plan.order if n.op.startswith("registry:")]
    assert len(heavy) == 2
    v = check_plan(WavePlan(plan.order, plan.waves, plan.chains, [heavy], []))
    assert "graph/raw-hazard" in rules(v)
    assert any("stacked launch" in x.message for x in v)


def test_war_hazard_fused_link_with_live_outside_reader():
    _, _, z, w = _diamond()
    plan = plan_waves([z.node, w.node])
    order = plan.order
    gemm1 = min((n for n in order if n.op.startswith("registry:")),
                key=lambda n: n.id)
    tanh = next(n for n in order if n.op == "tanh")
    relu = next(n for n in order if n.op == "relu")
    corrupted = {gemm1.id: [tanh, relu]}  # fuses tanh although z still reads it
    v = check_plan(WavePlan(order, plan.waves, corrupted, [], []))
    assert "graph/war-hazard" in rules(v)


def test_cycle_reported_for_unschedulable_nodes():
    _, _, z, w = _diamond()
    plan = plan_waves([z.node, w.node])
    v = check_plan(WavePlan(plan.order, [], {}, [], plan.order[:1]))
    assert "graph/cycle" in rules(v)


# ---------------------------------------------------------------------------
# validate=True surfaces
# ---------------------------------------------------------------------------

def test_offload_region_validate_raises_on_seeded_hazard():
    with hnp.offload_region("seeded", validate=True):
        a = hnp.array(np.ones((8, 6), np.float32))
        b = hnp.array(np.ones((6, 4), np.float32))
        y = a @ b
        y.node.shape = (123, 456)          # corrupt before forcing
        with pytest.raises(GraphVerificationError) as exc:
            hnp.asnumpy(y)
    assert "graph/shape-mismatch" in str(exc.value)


def test_dispatch_placed_validate_rejects_bad_operands():
    with pytest.raises(GraphVerificationError) as exc:
        dispatch_placed(
            "gemm",
            np.ones((4, 3), np.float32),
            np.ones((5, 2), np.float32),   # inner dims disagree
            validate=True,
        )
    assert "graph/shape-mismatch" in str(exc.value)


def test_dispatch_placed_validate_rejects_unknown_op():
    with pytest.raises(GraphVerificationError) as exc:
        dispatch_placed("no_such_op", validate=True)
    assert "graph/unknown-op" in str(exc.value)


def test_dispatch_placed_validate_rejects_dead_handle():
    eng = engine()
    h = eng.pin_handle("dead", 1024.0, device_id=0)
    eng.unstage_handle(h)
    v = verify_call(
        "gemm",
        (np.ones((4, 3), np.float32), np.ones((3, 2), np.float32)),
        handle=h,
    )
    assert "graph/use-after-unstage" in rules(v)


def test_dispatch_placed_validate_accepts_clean_call():
    out, launch = dispatch_placed(
        "gemm",
        np.ones((4, 3), np.float32),
        np.ones((3, 2), np.float32),
        validate=True,
    )
    assert out.shape == (4, 2)


def test_violations_render_with_rule_names():
    v = Violation("graph/raw-hazard", "msg", "node#1(add)")
    assert v.render() == "node#1(add): graph/raw-hazard: msg"
