"""End-to-end behaviour: train loop with checkpointing, serving, offload
accounting through a whole model — the paper's stack assembled."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import engine, offload_policy, offload_trace
from repro.launch.train import train
from repro.launch.serve import serve_batch
from repro.models import build_model


def test_train_loop_end_to_end(tmp_path):
    losses = train(
        "yi-6b", smoke=True, steps=8, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100,
        num_microbatches=1,
    )
    assert len(losses) == 8
    assert losses[-1] < losses[0]
    # checkpoints landed
    assert any(p.name.startswith("step_") for p in tmp_path.iterdir())


def test_train_resumes_from_checkpoint(tmp_path):
    train("yi-6b", smoke=True, steps=6, global_batch=4, seq_len=32,
          ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100,
          num_microbatches=1)
    # second call resumes at step 6 and runs nothing new -> returns []
    losses = train("yi-6b", smoke=True, steps=6, global_batch=4, seq_len=32,
                   ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100,
                   num_microbatches=1)
    assert losses == []


def test_serve_batch_greedy():
    res = serve_batch(
        "yi-6b", [[1, 2, 3, 4], [5, 6]], smoke=True, max_new_tokens=4,
        cache_len=32,
    )
    assert res.tokens.shape == (2, 4)
    assert res.tokens_per_s > 0


def test_serve_rejects_encoder():
    with pytest.raises(ValueError):
        serve_batch("hubert-xlarge", [[1, 2]], smoke=True)


def test_whole_model_offload_trace():
    """The paper's instrumentation through a full forward pass: every
    matmul in the model is visible at the BLAS seam with regions."""
    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    engine().reset()
    with offload_policy(mode="auto", platform="tpu-v5e", resident_fraction=1.0):
        with offload_trace() as t:
            model.forward(params, batch)
    ops = t.by_op()
    assert "gemm" in ops or "attention" in ops
    # layer-scan records carry the structural multiplier
    assert any(r.count == cfg.num_layers for r in t.records)
    assert t.total_flops() > 0


def test_offload_crossover_matches_paper_story():
    """Small problems stay on host, large ones offload (auto policy)."""
    from repro.core import blas

    engine().reset()
    with offload_policy(mode="auto", platform="hesoc-vcu128"):
        with offload_trace() as t:
            blas.gemm(jnp.ones((16, 16)), jnp.ones((16, 16)))
            blas.gemm(jnp.ones((512, 512)), jnp.ones((512, 512)))
    small, large = t.records
    assert small.backend == "host"
    assert large.backend.startswith("device")
