"""Reproduction of the paper's published claims (EXPERIMENTS.md §Paper).

Anchors (float64 GEMM on the CVA6+Snitch heSoC, FPGA-emulated):
  1. offload speedup at n=128:            2.71x
  2. 'data copy' share of offload time:   47%
  3. zero-copy (IOMMU) projection:        ~4.7x total (paper's rounding;
     the model gives 2.71 / (1 - 0.47 + 0.47/7.5) = 4.57x)
  4. qualitative: offload does NOT pay off at small sizes (Fig. 3 shows
     host-only faster at n=16/32), crossover below 128.
"""

import pytest

from repro.core import (
    HESOC_VCU128,
    breakdown,
    crossover_size,
    decide_offload,
    gemm_cost,
)


F64 = 8


def test_speedup_at_128():
    _, bd = decide_offload(gemm_cost(128, 128, 128, F64), HESOC_VCU128)
    assert bd.speedup == pytest.approx(2.71, rel=1e-3)


def test_copy_fraction_at_128():
    bd = breakdown(gemm_cost(128, 128, 128, F64), HESOC_VCU128)
    assert bd.copy_fraction == pytest.approx(0.47, rel=1e-3)


def test_zero_copy_projection():
    bd = breakdown(gemm_cost(128, 128, 128, F64), HESOC_VCU128, zero_copy=True)
    # paper reports 4.7x; the exact-anchor model projects 4.57x
    assert bd.speedup == pytest.approx(4.57, abs=0.15)
    assert 4.4 <= bd.speedup <= 4.85


def test_small_sizes_do_not_offload():
    for n in (16, 32):
        ok, bd = decide_offload(gemm_cost(n, n, n, F64), HESOC_VCU128)
        assert not ok, f"offload should lose at n={n} (speedup {bd.speedup:.2f})"


def test_crossover_below_128():
    n = crossover_size(HESOC_VCU128, F64)
    assert 32 < n <= 128


def test_fork_join_constant_dominates_tiny_sizes():
    bd16 = breakdown(gemm_cost(16, 16, 16, F64), HESOC_VCU128)
    assert bd16.fork_join_s > bd16.compute_s


def test_zero_copy_only_reduces_copy_region():
    c = gemm_cost(128, 128, 128, F64)
    a = breakdown(c, HESOC_VCU128)
    b = breakdown(c, HESOC_VCU128, zero_copy=True)
    assert b.copy_s < a.copy_s
    assert b.compute_s == a.compute_s
    assert b.fork_join_s == a.fork_join_s
