"""Every assigned (arch × shape) cell traces abstractly at FULL size.

``jax.eval_shape`` runs the real model code with ShapeDtypeStructs — no
compile, no allocation — so this validates every cell's shapes/dtypes and
the full-size code paths (chunked attention, SSD chunking, MoE dispatch
fallbacks, caches) in seconds. The compiled story is the dry-run's job.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_SHAPES, get_arch, list_archs, shape_applicable
from repro.models import build_model

ARCHS = [a for a in list_archs() if a != "paper-gemm"]
CELLS = [
    (a, s)
    for a in ARCHS
    for s in ALL_SHAPES
    if shape_applicable(get_arch(a), s)[0]
]


@pytest.mark.parametrize("arch,shape", CELLS, ids=[f"{a}-{s.name}" for a, s in CELLS])
def test_cell_traces_at_full_size(arch, shape):
    cfg = get_arch(arch)
    model = build_model(cfg)
    specs = model.input_specs(shape)
    params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))

    if shape.kind in ("train", "prefill"):
        out = jax.eval_shape(lambda p, b: model.forward(p, b), params, specs)
        logits, aux = out
        assert logits.shape == (shape.global_batch, shape.seq_len, cfg.vocab_size)
        assert aux.dtype == jnp.float32
    else:
        logits, cache = jax.eval_shape(
            lambda p, c, t, i: model.decode_step(p, c, t, i),
            params, specs["cache"], specs["tokens"], specs["cache_index"],
        )
        assert logits.shape == (shape.global_batch, cfg.vocab_size)
        # cache structure must round-trip (scan-threaded state)
        assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(
            specs["cache"]
        )


def test_vlm_decode_smoke():
    """qwen2-vl decode with stub patch embeddings + M-RoPE positions."""
    cfg = get_arch("qwen2-vl-72b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    cache = model.init_decode_cache(2, 16)
    embeds = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model)) * 0.1
    logits, cache = model.decode_step(params, cache, embeds, jnp.int32(3))
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_skip_matrix_documented():
    """Exactly 8 cells are skipped, each with a reason (DESIGN.md §5)."""
    skipped = [
        (a, s.name, shape_applicable(get_arch(a), s)[1])
        for a in ARCHS
        for s in ALL_SHAPES
        if not shape_applicable(get_arch(a), s)[0]
    ]
    assert len(skipped) == 8, skipped
    assert all(reason for _, _, reason in skipped)
    assert len(CELLS) == 32
