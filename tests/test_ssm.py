"""Mamba-2 SSD: chunked form vs naive recurrence; decode vs full pass."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import ssm as S

CFG = dataclasses.replace(get_arch("mamba2-370m").reduced(), ssm_chunk=8)


def _naive_ssd(x, dt, a, b, c, d_skip):
    """Direct recurrence oracle: h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    y = np.zeros((bsz, s, h, p), np.float32)
    st = np.zeros((bsz, h, n, p), np.float32)
    for t in range(s):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a))  # (B, H)
        st = decay[..., None, None] * st + np.einsum(
            "bh,bhn,bhp->bhnp", np.asarray(dt[:, t]), np.asarray(b[:, t]), np.asarray(x[:, t])
        )
        y[:, t] = np.einsum("bhn,bhnp->bhp", np.asarray(c[:, t]), st)
    return y + np.asarray(x) * np.asarray(d_skip)[None, None, :, None]


def test_chunked_ssd_equals_naive_recurrence():
    """The SSD chunked matmul form == the sequential scan, across chunk
    boundaries (validates Y_diag, chunk states, and the inter-chunk scan)."""
    rng = np.random.default_rng(0)
    bsz, s = 2, 32
    cfg = CFG
    h, p, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim
    x = jnp.asarray(rng.normal(size=(bsz, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(bsz, s, h))) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(h,))), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bsz, s, h, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bsz, s, h, n)), jnp.float32)
    d_skip = jnp.ones((h,), jnp.float32)

    # drive the chunked path exactly as mamba_block does
    q = cfg.ssm_chunk
    nc = s // q
    da = dt * a
    da_c = da.reshape(bsz, nc, q, h)
    cum = jnp.cumsum(da_c, axis=2)
    cum_bh = cum.transpose(0, 3, 1, 2).reshape(bsz * h, nc, q)

    def to_bh(t):
        t = t.reshape(bsz, nc, q, h, -1).transpose(0, 3, 1, 2, 4)
        return t.reshape(bsz * h, nc, q, t.shape[-1])

    from repro.kernels import ref as kref

    xdt = x * dt[..., None]
    y_diag = kref.ssd_chunk_diag_ref(to_bh(xdt), cum_bh, to_bh(b), to_bh(c))
    decay_to_end = jnp.exp(cum_bh[:, :, -1:] - cum_bh)
    states = jnp.einsum("zcq,zcqn,zcqp->zcnp", decay_to_end, to_bh(b), to_bh(xdt))
    chunk_decay = jnp.exp(cum_bh[:, :, -1])

    def scan_fn(carry, inp):
        stt, dec = inp
        return dec[:, None, None] * carry + stt, carry

    init = jnp.zeros((bsz * h, n, p), jnp.float32)
    _, prev = jax.lax.scan(scan_fn, init, (states.transpose(1, 0, 2, 3), chunk_decay.T))
    prev = prev.transpose(1, 0, 2, 3)
    y_off = jnp.einsum("zcqn,zcnp,zcq->zcqp", to_bh(c), prev, jnp.exp(cum_bh))
    y = (y_diag + y_off).reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    y = y + x * d_skip[None, None, :, None]

    want = _naive_ssd(x, dt, a, b, c, d_skip)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-3)


def test_mamba_block_shapes_and_finite():
    rng = jax.random.PRNGKey(0)
    p = S.init_mamba(rng, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, CFG.d_model)) * 0.3
    y = S.mamba_block(p, x, CFG)
    assert y.shape == x.shape and not bool(jnp.isnan(y).any())


def test_mamba_decode_matches_block():
    """Step-by-step decode recurrence == full chunked pass (last position)."""
    rng = jax.random.PRNGKey(0)
    p = S.init_mamba(rng, CFG, jnp.float32)
    s = 16
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, CFG.d_model)) * 0.3
    full = S.mamba_block(p, x, CFG)

    state = (
        jnp.zeros((2, CFG.ssm_num_heads, CFG.ssm_state_dim, CFG.ssm_head_dim), jnp.float32),
        jnp.zeros((2, CFG.ssm_conv_width - 1,
                   CFG.d_inner + 2 * CFG.ssm_num_groups * CFG.ssm_state_dim), jnp.float32),
    )
    out = None
    for t in range(s):
        out, state = S.decode_mamba_block(p, x[:, t : t + 1], state, CFG)
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_mamba_causality():
    rng = jax.random.PRNGKey(0)
    p = S.init_mamba(rng, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, CFG.d_model)) * 0.3
    y1 = S.mamba_block(p, x, CFG)
    x2 = x.at[:, 12:, :].set(55.0)
    y2 = S.mamba_block(p, x2, CFG)
    np.testing.assert_allclose(
        np.asarray(y1[:, :12]), np.asarray(y2[:, :12]), rtol=1e-4, atol=1e-4
    )
