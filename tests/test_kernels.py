"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [
    (128, 128, 128),      # exact tile
    (256, 128, 384),      # multi-tile k
    (200, 130, 96),       # ragged everything (padding path)
    (8, 8, 8),            # tiny
    (1, 256, 64),         # degenerate m
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_shapes_dtypes(m, n, k, dtype):
    a, b = _randn(m, k, dtype=dtype), _randn(k, n, dtype=dtype)
    got = ops.gemm(a, b, interpret=True)
    want = ref.gemm_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("block", [(128, 128, 128), (64, 64, 64), (128, 64, 256)])
def test_gemm_block_shapes(block):
    a, b = _randn(192, 160), _randn(160, 224)
    got = ops.gemm(a, b, block=block, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.gemm_ref(a, b)), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("bsz", [1, 3, 8])
def test_gemm_batched(bsz):
    a, b = _randn(bsz, 96, 64), _randn(bsz, 64, 80)
    got = ops.gemm_batched(a, b, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.gemm_batched_ref(a, b)), rtol=2e-5, atol=2e-5
    )


def test_moe_gemm_is_grouped():
    e, c, d, f = 4, 32, 48, 56
    x, w = _randn(e, c, d), _randn(e, d, f)
    got = ops.moe_gemm(x, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.moe_gemm_ref(x, w)), rtol=2e-5, atol=2e-5
    )


def test_gemm_fp32_accumulation_bf16_inputs():
    """bf16 inputs must accumulate in fp32 (MXU semantics), not bf16."""
    k = 4096
    a = jnp.full((8, k), 0.01, jnp.bfloat16)
    b = jnp.full((k, 8), 0.01, jnp.bfloat16)
    got = np.asarray(ops.gemm(a, b, out_dtype=jnp.float32, interpret=True))
    # true value k * 1e-4 = 0.4096; bf16 accumulation would lose severely
    assert abs(got[0, 0] - k * 1e-4) / (k * 1e-4) < 0.02


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    dict(sq=128, skv=128, hq=4, hkv=4, causal=True),
    dict(sq=128, skv=128, hq=8, hkv=2, causal=True),          # GQA
    dict(sq=96, skv=96, hq=4, hkv=2, causal=True, window=32), # SWA
    dict(sq=64, skv=64, hq=4, hkv=4, causal=False),           # encoder
    dict(sq=16, skv=128, hq=4, hkv=2, causal=True),           # suffix decode
    dict(sq=100, skv=100, hq=4, hkv=2, causal=True),          # ragged pad
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_variants(case, dtype):
    d = 32
    q = _randn(2, case["hq"], case["sq"], d, dtype=dtype)
    k = _randn(2, case["hkv"], case["skv"], d, dtype=dtype)
    v = _randn(2, case["hkv"], case["skv"], d, dtype=dtype)
    got = ops.flash_attention(
        q, k, v, causal=case["causal"], window=case.get("window"),
        block_q=32, block_kv=32, interpret=True,
    )
    want = ref.attention_ref(
        q, k, v, causal=case["causal"], window=case.get("window")
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_block_shape_independence():
    q, k, v = _randn(1, 2, 128, 32), _randn(1, 2, 128, 32), _randn(1, 2, 128, 32)
    a = ops.flash_attention(q, k, v, block_q=32, block_kv=32, interpret=True)
    b = ops.flash_attention(q, k, v, block_q=64, block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Flash decode (single token vs cache, ragged valid ranges)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    dict(hq=4, hkv=2, s=64, bounds=[(0, 64), (5, 40), (10, 33)]),
    dict(hq=8, hkv=8, s=96, bounds=[(0, 96), (0, 1), (95, 96)]),
])
def test_flash_decode_ragged_bounds(case):
    from repro.core import blas

    b = len(case["bounds"])
    d = 16
    q = _randn(b, case["hq"], d)
    k = _randn(b, case["hkv"], case["s"], d)
    v = _randn(b, case["hkv"], case["s"], d)
    lo = jnp.asarray([x for x, _ in case["bounds"]], jnp.int32)
    hi = jnp.asarray([y for _, y in case["bounds"]], jnp.int32)
    out = ops.flash_decode(q, k, v, lo, hi, block_kv=16, interpret=True)
    pos = jnp.arange(case["s"])
    for i in range(b):
        mask = (pos >= lo[i]) & (pos < hi[i])
        want = blas.attention_math(
            q[i : i + 1, :, None, :], k[i : i + 1], v[i : i + 1],
            causal=False, kv_mask=mask[None],
        )[0, :, 0, :]
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(want), rtol=2e-5, atol=2e-5
        )


def test_flash_decode_block_independence():
    b, hq, hkv, s, d = 2, 4, 2, 128, 32
    q, k, v = _randn(b, hq, d), _randn(b, hkv, s, d), _randn(b, hkv, s, d)
    lo = jnp.zeros((b,), jnp.int32)
    hi = jnp.asarray([s, s // 2], jnp.int32)
    a = ops.flash_decode(q, k, v, lo, hi, block_kv=32, interpret=True)
    c = ops.flash_decode(q, k, v, lo, hi, block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD chunk kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,nc,q,p,n", [(4, 2, 32, 16, 8), (2, 8, 64, 32, 16), (1, 1, 8, 8, 8)])
def test_ssd_chunk_diag(bh, nc, q, p, n):
    x = _randn(bh, nc, q, p)
    dta = jnp.cumsum(-jnp.abs(_randn(bh, nc, q)) * 0.1, axis=-1)
    b = _randn(bh, nc, q, n)
    c = _randn(bh, nc, q, n)
    got = ops.ssd_chunk_diag(x, dta, b, c, interpret=True)
    want = ref.ssd_chunk_diag_ref(x, dta, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ssd_chunk_diag_causality():
    """Output at position t must not depend on inputs at positions > t."""
    bh, nc, q, p, n = 1, 1, 16, 8, 4
    x = _randn(bh, nc, q, p)
    dta = jnp.cumsum(-jnp.abs(_randn(bh, nc, q)) * 0.1, axis=-1)
    b, c = _randn(bh, nc, q, n), _randn(bh, nc, q, n)
    y1 = np.asarray(ops.ssd_chunk_diag(x, dta, b, c, interpret=True))
    x2 = x.at[:, :, 10:, :].set(123.0)
    y2 = np.asarray(ops.ssd_chunk_diag(x2, dta, b, c, interpret=True))
    np.testing.assert_allclose(y1[:, :, :10], y2[:, :, :10], rtol=1e-5)
