"""KV-cache placement routing: pinned handles steer decode placement.

End-to-end over ``launch/serve.py:serve_cluster``: prefill pins each
batch's KV cache to its device as a ``DeviceHandle``; the ``cost-aware``
scheduler must route decode batches to the cache-holding device (residency
credit — no cache movement), while placement-oblivious ``round-robin``
does not and pays modeled ``d2d_copy`` migrations.  The un-pinned
round-robin baseline re-stages every cache from host and must post a
strictly larger modeled makespan than pinned cost-aware serving.
"""

import numpy as np
import pytest

from repro.core import engine, offload_policy
from repro.launch.serve import serve_cluster

ARCH = "yi-6b"


@pytest.fixture(autouse=True)
def _reset_engine():
    engine().reset()
    yield
    engine().reset()


def _batches(n, bsz=4, plen=3):
    rng = np.random.default_rng(11)
    return [
        [list(rng.integers(1, 200, size=plen)) for _ in range(bsz)]
        for _ in range(n)
    ]


def test_cost_aware_routes_decode_to_cache_device():
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        engine().reset()
        res = serve_cluster(
            ARCH, _batches(3), smoke=True, max_new_tokens=2, cache_len=512,
        )
    # every decode batch landed on the device holding its pinned cache
    assert res.placements == res.prefill_placements
    assert res.cache_devices == res.placements
    assert res.d2d_s == 0.0 and res.restage_s == 0.0


def test_round_robin_ignores_cache_placement():
    # 3 batches on 2 devices: the RR counter continues from the prefill
    # round, so every decode lands opposite its cache — migrations follow.
    with offload_policy(mode="device", num_devices=2, scheduler="round-robin"):
        engine().reset()
        res = serve_cluster(
            ARCH, _batches(3), smoke=True, max_new_tokens=2, cache_len=512,
        )
    mismatched = [
        d for d, p in zip(res.placements, res.prefill_placements) if d != p
    ]
    assert mismatched, "round-robin should not follow the cache"
    assert res.d2d_s > 0.0          # pinned caches migrated over the d2d link
    assert res.restage_s == 0.0     # but never bounced through host memory
    # at decode placement the caches were still where prefill pinned them
    assert res.cache_devices == res.prefill_placements


def test_pinned_cost_aware_beats_unpinned_round_robin_makespan():
    """Acceptance: pinned decode batches land on the pinning device and the
    modeled makespan beats unpinned round-robin (which re-stages every
    cache from host DRAM on its decode lane)."""
    batches = _batches(4)
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        engine().reset()
        pinned = serve_cluster(
            ARCH, batches, smoke=True, max_new_tokens=2, cache_len=512,
            pin_caches=True,
        )
    with offload_policy(mode="device", num_devices=2, scheduler="round-robin"):
        engine().reset()
        unpinned = serve_cluster(
            ARCH, batches, smoke=True, max_new_tokens=2, cache_len=512,
            pin_caches=False,
        )
    # pinned: decode follows the cache, nothing moves
    assert pinned.placements == pinned.cache_devices == pinned.prefill_placements
    assert pinned.d2d_s == 0.0 and pinned.restage_s == 0.0
    # unpinned: every decode lane pays the host re-stage copy
    assert unpinned.restage_s > 0.0
    assert pinned.makespan_s < unpinned.makespan_s
    assert pinned.tokens_per_s > unpinned.tokens_per_s
