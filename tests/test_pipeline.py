"""GPipe pipeline parallelism: schedule == sequential composition, fwd+bwd."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

PIPE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.launch.pipeline import pipeline_apply

    S, D, B, M = 4, 16, 8, 4
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"w": jax.random.normal(k1, (S, D, D)) * 0.3,
              "b": jax.random.normal(k2, (S, D)) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    def stage_fn(p, xmb):
        return jax.nn.gelu(xmb @ p["w"] + p["b"])

    def sequential(params, x):
        for i in range(S):
            x = stage_fn(jax.tree_util.tree_map(lambda a: a[i], params), x)
        return x

    want = sequential(params, x)
    with mesh:
        got = jax.jit(
            lambda p, x: pipeline_apply(p, x, stage_fn, mesh,
                                        num_microbatches=M)
        )(params, x)
    fwd_err = float(jnp.max(jnp.abs(want - got)))

    def loss_pipe(p):
        with mesh:
            return jnp.sum(pipeline_apply(p, x, stage_fn, mesh,
                                          num_microbatches=M) ** 2)

    def loss_seq(p):
        return jnp.sum(sequential(p, x) ** 2)

    with mesh:
        g_pipe = jax.jit(jax.grad(loss_pipe))(params)
    g_seq = jax.grad(loss_seq)(params)
    g_err = max(
        float(jnp.max(jnp.abs(g_pipe["w"] - g_seq["w"]))),
        float(jnp.max(jnp.abs(g_pipe["b"] - g_seq["b"]))),
    )
    print(json.dumps({"fwd_err": fwd_err, "grad_err": g_err}))
    """
)


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c", PIPE], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["fwd_err"] < 1e-5, rec
    assert rec["grad_err"] < 1e-4, rec
