"""HeroCluster: scheduler policies, device loss, per-device accounting."""

import jax.numpy as jnp
import pytest

from repro.core import accounting, blas
from repro.core.cost_model import gemm_cost
from repro.core.hero import (
    HeroCluster,
    LaunchTicket,
    engine,
    offload_policy,
)
from repro.runtime.fault_tolerance import ClusterSupervisor


@pytest.fixture(autouse=True)
def _reset_engine():
    engine().reset()
    yield
    engine().reset()


def _launch(cluster, m=512, n=512, k=512, key="x"):
    return cluster.launch(
        gemm_cost(m, n, k, 2), dtype="bfloat16", shape_key=key,
    )


# ---------------------------------------------------------------------------
# Scheduler policies
# ---------------------------------------------------------------------------

def test_round_robin_placement_deterministic():
    a = HeroCluster(num_devices=4, scheduler="round-robin")
    b = HeroCluster(num_devices=4, scheduler="round-robin")
    pa = [_launch(a, key=f"k{i}").device_id for i in range(8)]
    pb = [_launch(b, key=f"k{i}").device_id for i in range(8)]
    assert pa == pb == [0, 1, 2, 3, 0, 1, 2, 3]


def test_least_loaded_invariant():
    """Every placement lands on a device whose pending load was minimal."""
    c = HeroCluster(num_devices=3, scheduler="least-loaded")
    sizes = [1024, 128, 128, 768, 256, 1024, 128, 512, 640, 384]
    for i, s in enumerate(sizes):
        before = {d.device_id: d.pending_s for d in c.devices}
        res = c.launch(
            gemm_cost(s, s, s, 2), dtype="bfloat16", shape_key=f"g{i}",
        )
        assert res.device_id >= 0
        assert before[res.device_id] == min(before.values())
    # the big calls must not all pile on one device
    assert len({d.device_id for d in c.devices if d.pending_s > 0}) == 3


def test_cost_aware_prefers_resident_device():
    """Residency affinity: the device already holding the operands wins
    even when another device is idle (the copy region vanishes there)."""
    c = HeroCluster(num_devices=2, scheduler="cost-aware")
    c.mark_resident("hot-shape", device_id=1)
    res = c.launch(
        gemm_cost(2048, 2048, 2048, 2), dtype="bfloat16",
        shape_key="hot-shape",
    )
    assert res.device_id == 1


def test_scheduler_unknown_name_rejected():
    with pytest.raises(ValueError):
        HeroCluster(num_devices=2, scheduler="fifo")


def test_launch_result_unpacks_and_compares():
    c = HeroCluster(num_devices=2)
    res = _launch(c)
    backend, device_id = res
    assert backend == str(res)
    assert device_id == res.device_id
    assert res.startswith("device") or res == "host"


# ---------------------------------------------------------------------------
# Boot / residency / device loss
# ---------------------------------------------------------------------------

def test_first_offload_boots_only_chosen_device():
    c = HeroCluster(num_devices=3, scheduler="round-robin")
    assert not c.booted
    res = _launch(c)
    assert c.device(res.device_id).booted
    others = [d for d in c.devices if d.device_id != res.device_id]
    assert not any(d.booted for d in others)


def test_device_loss_evicts_and_reschedules():
    c = HeroCluster(num_devices=3, scheduler="least-loaded")
    c.mark_resident("params", device_id=0)
    # queue work on device 0
    while not c.device(0).inflight:
        _launch(c, key=f"w{len(c.device(0).inflight)}")
        if all(not d.inflight for d in c.devices):
            break
    for i in range(6):
        _launch(c, key=f"q{i}")
    lost = c.device(0)
    n_inflight = len(lost.inflight)
    assert n_inflight > 0
    moved = c.fail_device(0)
    assert not lost.alive and not lost.is_resident("params")
    assert not lost.inflight
    assert len(moved) == n_inflight
    assert all(dev_id in (1, 2) for _, dev_id in moved)
    # subsequent launches avoid the dead device
    for i in range(6):
        assert _launch(c, key=f"r{i}").device_id in (1, 2)
    # recovery brings it back cold
    c.restore_device(0)
    assert c.device(0).alive and not c.device(0).booted


def test_all_devices_failed_raises():
    c = HeroCluster(num_devices=1)
    with pytest.raises(RuntimeError):
        c.fail_device(0)


def test_cluster_supervisor_heartbeat_and_events():
    clock = {"t": 0.0}
    c = HeroCluster(num_devices=2, scheduler="least-loaded")
    sup = ClusterSupervisor(c, timeout_s=10.0, clock=lambda: clock["t"])
    _launch(c, key="a")
    _launch(c, key="b")
    clock["t"] = 5.0
    sup.beat(0)
    clock["t"] = 12.0  # device 1 silent for 12s, device 0 for 7s
    events = sup.poll()
    assert [e.device_id for e in events] == [1]
    assert not c.device(1).alive
    # the orphaned launch moved to device 0
    assert all(dev == 0 for _, dev in events[0].rescheduled)
    sup.recover(1)
    assert c.device(1).alive


# ---------------------------------------------------------------------------
# Accounting: per-device aggregation + overlap timeline
# ---------------------------------------------------------------------------

def test_per_device_trace_sums_to_cluster_total():
    with offload_policy(mode="device", num_devices=4,
                        scheduler="least-loaded", platform="tpu-v5e"):
        engine().reset()
        with accounting.offload_trace() as t:
            for i, s in enumerate([1024, 512, 512, 256, 768, 640, 384, 896]):
                blas.gemm(jnp.ones((s, s), jnp.bfloat16),
                          jnp.ones((s, s), jnp.bfloat16))
    per_dev = t.by_device()
    assert len(per_dev) > 1                     # work actually spread
    copy, fork, comp, _ = t.totals()
    assert sum(d.copy_s for d in per_dev.values()) == pytest.approx(copy)
    assert sum(d.fork_join_s for d in per_dev.values()) == pytest.approx(fork)
    assert sum(d.compute_s for d in per_dev.values()) == pytest.approx(comp)
    assert sum(d.flops for d in per_dev.values()) == pytest.approx(
        sum(r.cost.flops * r.count for r in t.offloaded())
    )


def test_overlap_timeline_bounds():
    """makespan <= serial per device, and >= the compute-only lower bound."""
    with offload_policy(mode="device", num_devices=2,
                        scheduler="round-robin", platform="tpu-v5e"):
        engine().reset()
        with accounting.offload_trace() as t:
            for s in (512, 512, 512, 512):
                blas.gemm(jnp.ones((s, s), jnp.bfloat16),
                          jnp.ones((s, s), jnp.bfloat16))
    tls = t.device_timelines()
    assert set(tls) == {0, 1}
    per_dev = t.by_device()
    for dev, tl in tls.items():
        assert tl.makespan_s <= tl.serial_s + 1e-15
        assert tl.makespan_s >= per_dev[dev].fork_join_s + per_dev[dev].compute_s
    assert t.cluster_makespan_s() == pytest.approx(
        max(tl.makespan_s for tl in tls.values())
    )


def test_tp_matmul_not_recorded_as_pallas():
    """A tp_mode matmul with no ambient mesh must still run the plain path
    and never log a pallas backend for the shard_map route (the historic
    mislabel); with a mesh the record carries the tp-shard-map note."""
    with offload_policy(mode="device", use_pallas=True, interpret=True):
        engine().reset()
        with accounting.offload_trace() as t:
            x = jnp.ones((2, 4, 32), jnp.float32)
            w = jnp.ones((32, 16), jnp.float32)
            y = blas.matmul(x, w, tp_mode="row")  # no mesh -> plain path
    assert y.shape == (2, 4, 16)
    (rec,) = t.records
    assert rec.note == ""                       # plan did not apply
    assert rec.backend in ("device", "device-pallas")


def test_cluster_scaling_monotone():
    from benchmarks.cluster_scaling import sweep

    rows = sweep("least-loaded", sizes=(1, 2, 4, 8))
    gf = [r["gflops"] for r in rows]
    assert all(b >= a - 1e-9 for a, b in zip(gf, gf[1:]))
    assert gf[-1] > 2.0 * gf[0]                 # real scaling, not noise


def test_pin_device_routes_all_launches():
    c = HeroCluster(num_devices=4, scheduler="least-loaded")
    with c.pin_device(2):
        ids = {_launch(c, key=f"p{i}").device_id for i in range(5)}
    assert ids == {2}
    # pin released: other devices get work again
    ids_after = {_launch(c, key=f"a{i}").device_id for i in range(6)}
    assert ids_after != {2}
    c.fail_device(3)
    with pytest.raises(RuntimeError):
        with c.pin_device(3):
            pass


def test_inflight_queue_bounded():
    c = HeroCluster(num_devices=1, scheduler="round-robin")
    for i in range(c.device(0).MAX_INFLIGHT + 50):
        _launch(c, key=f"b{i}")
    dev = c.device(0)
    assert len(dev.inflight) == dev.MAX_INFLIGHT
    assert dev.completed_launches == 50


def test_fail_device_under_pin_reschedules_via_scheduler():
    c = HeroCluster(num_devices=3, scheduler="least-loaded")
    for i in range(4):
        _launch(c, key=f"w{i}")
    with c.pin_device(1):
        moved = c.fail_device(2)          # not the pinned device
        # orphans go to scheduler-chosen survivors, never hijacked by the pin
        assert all(dev in (0, 1) for _, dev in moved)
        moved0 = c.fail_device(0)
        assert all(dev == 1 for _, dev in moved0)
        # a new launch in the pin scope stays pinned
        assert _launch(c, key="pinned").device_id == 1


def test_supervisor_total_loss_recorded_not_raised():
    clock = {"t": 0.0}
    c = HeroCluster(num_devices=2, scheduler="least-loaded")
    sup = ClusterSupervisor(c, timeout_s=1.0, clock=lambda: clock["t"])
    _launch(c, key="x")
    clock["t"] = 100.0                    # everything silent
    events = sup.poll()
    assert len(events) == 2
    assert events[-1].total_loss and not events[0].total_loss
    assert not c.alive_devices()
    with pytest.raises(RuntimeError):
        _launch(c, key="after")           # clear error, not scheduler crash


def test_fail_device_without_survivors_leaves_cluster_intact():
    c = HeroCluster(num_devices=1)
    _launch(c, key="x")
    n = len(c.device(0).inflight)
    with pytest.raises(RuntimeError):
        c.fail_device(0)
    assert c.device(0).alive                  # not mutated by the refusal
    assert len(c.device(0).inflight) == n


# ---------------------------------------------------------------------------
# Serving across the cluster
# ---------------------------------------------------------------------------

def test_serve_cluster_load_balances_batches():
    from repro.launch.serve import serve_cluster

    batches = [
        [[1, 2, 3], [4, 5]],
        [[6, 7], [8, 9, 10]],
        [[11], [12, 13]],
        [[14, 15, 16], [17]],
    ]
    with offload_policy(num_devices=2, scheduler="least-loaded"):
        engine().reset()
        res = serve_cluster(
            "yi-6b", batches, smoke=True, max_new_tokens=2, cache_len=16,
        )
    assert len(res.results) == 4
    assert all(r.tokens.shape == (2, 2) for r in res.results)
    # batches spread over both devices, makespan is the longest lane
    assert set(res.placements) == {0, 1}
    assert res.makespan_s == pytest.approx(max(res.per_device_s.values()))
    assert res.makespan_s < sum(res.per_device_s.values()) + 1e-12
    assert res.total_tokens == 16
    assert res.tokens_per_s > 0
