"""MoE layer: dispatch correctness vs dense-einsum reference, router laws."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.configs import get_arch
from repro.models import moe as M

CFG = dataclasses.replace(
    get_arch("qwen3-moe-30b-a3b").reduced(), capacity_factor=8.0
)  # capacity large enough that nothing drops -> exact reference match


def _setup(seed=0, b=2, s=8):
    rng = jax.random.PRNGKey(seed)
    params = M.init_moe(rng, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, CFG.d_model)) * 0.3
    return params, x


def _dense_reference(p, x, cfg):
    """Every expert on every token, gate-weighted — O(E·T) oracle."""
    t = x.shape[0] * x.shape[1]
    xf = x.reshape(t, cfg.d_model)
    logits = xf @ p["router"].astype(x.dtype)
    gates, idx = M._top_k_gates(logits, cfg.experts_per_token)
    out = np.zeros((t, cfg.d_model), np.float32)
    for e in range(cfg.num_experts):
        g = np.asarray(xf @ p["we_gate"][e])
        u = np.asarray(xf @ p["we_up"][e])
        h = (g / (1 + np.exp(-g))) * u  # silu*up
        y = h @ np.asarray(p["we_down"][e])
        for k in range(cfg.experts_per_token):
            sel = np.asarray(idx[:, k]) == e
            out[sel] += np.asarray(gates[:, k])[sel, None] * y[sel]
    return out.reshape(x.shape)


def test_moe_matches_dense_reference():
    params, x = _setup()
    got, aux = M.moe_ffn(params, x, CFG)
    want = _dense_reference(params, x, CFG)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    assert float(aux) >= 1.0 - 1e-5  # switch aux loss lower bound is 1 at E*mean·ce


def test_gates_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    gates, idx = M._top_k_gates(logits, 4)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 16


def test_capacity_drops_tokens_but_stays_finite():
    cfg = dataclasses.replace(CFG, capacity_factor=0.1)  # force drops
    params, x = _setup(b=4, s=16)
    got, _ = M.moe_ffn(params, x, cfg)
    assert not bool(jnp.isnan(got).any())


def test_expert_capacity_mxu_aligned():
    for t in (64, 1000, 4096):
        cap = M.expert_capacity(t, CFG)
        assert cap % 8 == 0 and cap >= 8


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_moe_permutation_equivariance(seed):
    """Permuting tokens permutes outputs (dispatch has no positional leak)."""
    params, x = _setup(seed=seed % 7, b=1, s=8)
    perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(seed), 8))
    y, _ = M.moe_ffn(params, x, CFG)
    y_perm, _ = M.moe_ffn(params, x[:, perm], CFG)
    np.testing.assert_allclose(
        np.asarray(y[:, perm]), np.asarray(y_perm), rtol=2e-4, atol=2e-4
    )


def test_dense_residual_arctic():
    cfg = dataclasses.replace(
        get_arch("arctic-480b").reduced(), capacity_factor=8.0
    )
    rng = jax.random.PRNGKey(0)
    p = M.init_moe(rng, cfg, jnp.float32)
    assert "dense" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model)) * 0.3
    with_res, _ = M.moe_ffn(p, x, cfg)
    p_no = {k: v for k, v in p.items() if k != "dense"}
    no_res, _ = M.moe_ffn(p_no, x, dataclasses.replace(cfg, dense_residual=False))
    assert float(jnp.max(jnp.abs(with_res - no_res))) > 1e-6
