"""Decode attention backends: flash-decode kernel == masked-math path.

The decode path dispatches through the seam (``engine().launch``): the
default host form is shardable masked math; with the Pallas policy the
``flash_decode`` kernel runs instead (interpret mode here). Both must
produce the same logits across cache regimes (filled, partially filled,
rolling SWA, per-layer windows)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core import engine, offload_policy
from repro.models import build_model


@pytest.mark.parametrize("arch", ["yi-6b", "h2o-danube-1.8b", "gemma3-27b"])
def test_decode_pallas_matches_masked(arch):
    cfg = get_arch(arch).reduced()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0, cfg.vocab_size)
    engine().reset()

    def run():
        cache = m.init_decode_cache(2, 16)
        logits = None
        for t in range(10):
            logits, cache = m.decode_step(
                params, cache, tokens[:, t : t + 1], jnp.int32(t)
            )
        return logits

    base = run()
    with offload_policy(mode="device", use_pallas=True, interpret=True):
        pall = run()
    err = float(jnp.max(jnp.abs(base.astype(jnp.float32) - pall.astype(jnp.float32))))
    assert err < 2e-2, err


def test_decode_rolling_wrap_consistent():
    """SWA rolling cache past the wrap point: both backends agree."""
    cfg = get_arch("h2o-danube-1.8b").reduced()  # window 8
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 14), 0, cfg.vocab_size)
    engine().reset()

    def run():
        cache = m.init_decode_cache(1, 32)  # rolling buffer = window (8)
        logits = None
        for t in range(14):                 # wraps at t=8
            logits, cache = m.decode_step(
                params, cache, tokens[:, t : t + 1], jnp.int32(t)
            )
        return logits

    base = run()
    with offload_policy(mode="device", use_pallas=True, interpret=True):
        pall = run()
    err = float(jnp.max(jnp.abs(base.astype(jnp.float32) - pall.astype(jnp.float32))))
    assert err < 2e-2, err
