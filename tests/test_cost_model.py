"""Property tests (hypothesis) for the offload cost model invariants."""

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    HESOC_VCU128,
    TPU_V5E,
    breakdown,
    decide_offload,
    gemm_cost,
    gemv_cost,
    syrk_cost,
)

dims = st.integers(min_value=1, max_value=4096)
itemsizes = st.sampled_from([1, 2, 4, 8])
platforms = st.sampled_from([HESOC_VCU128, TPU_V5E])


@given(m=dims, n=dims, k=dims, i=itemsizes)
def test_gemm_cost_positive_and_exact(m, n, k, i):
    c = gemm_cost(m, n, k, i)
    assert c.flops == 2.0 * m * n * k
    assert c.staged_bytes == (m * k + k * n + m * n) * i
    assert c.touched_bytes > 0


@given(m=dims, n=dims, k=dims, i=itemsizes, p=platforms)
@settings(max_examples=50)
def test_regions_nonnegative(m, n, k, i, p):
    bd = breakdown(gemm_cost(m, n, k, i), p)
    assert bd.copy_s >= 0 and bd.fork_join_s >= 0 and bd.compute_s >= 0
    assert bd.offload_s >= bd.compute_s


@given(n=st.integers(min_value=8, max_value=2048), i=itemsizes, p=platforms)
@settings(max_examples=50)
def test_speedup_monotone_in_square_size(n, i, p):
    """Bigger square GEMMs always benefit at least as much from offload."""
    bd1 = breakdown(gemm_cost(n, n, n, i), p)
    bd2 = breakdown(gemm_cost(2 * n, 2 * n, 2 * n, i), p)
    assert bd2.speedup >= bd1.speedup * 0.999  # fp tolerance


@given(m=dims, n=dims, k=dims, i=itemsizes)
@settings(max_examples=50)
def test_zero_copy_never_slower(m, n, k, i):
    c = gemm_cost(m, n, k, i)
    a = breakdown(c, HESOC_VCU128)
    b = breakdown(c, HESOC_VCU128, zero_copy=True)
    assert b.offload_s <= a.offload_s


@given(m=dims, n=dims, k=dims, i=itemsizes, f=st.floats(0.0, 1.0))
@settings(max_examples=50)
def test_residency_reduces_copy(m, n, k, i, f):
    c = gemm_cost(m, n, k, i)
    a = breakdown(c, TPU_V5E)
    b = breakdown(c, TPU_V5E, resident_fraction=f)
    assert b.copy_s <= a.copy_s + 1e-12


@given(m=dims, n=dims, k=dims, i=itemsizes, p=platforms,
       ms=st.floats(min_value=1.0, max_value=4.0))
@settings(max_examples=50)
def test_min_speedup_threshold_consistent(m, n, k, i, p, ms):
    c = gemm_cost(m, n, k, i)
    ok, bd = decide_offload(c, p, min_speedup=ms)
    assert ok == (bd.speedup >= ms)


@given(n=dims, k=dims, i=itemsizes)
def test_syrk_half_of_gemm(n, k, i):
    assert syrk_cost(n, k, i).flops * 2 == gemm_cost(n, n, k, i).flops


@given(m=dims, n=dims, i=itemsizes)
def test_gemv_flops(m, n, i):
    assert gemv_cost(m, n, i).flops == 2.0 * m * n
