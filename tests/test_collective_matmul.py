"""Ring collective matmul == all_gather + matmul (the overlap primitive)."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.sharding.collective_matmul import ring_ag_matmul

    N, B, S, D, F = 4, 2, 16, 8, 12
    mesh = jax.make_mesh((N,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, F))

    def local(x_shard, w_loc):
        return ring_ag_matmul(x_shard, w_loc, "model")

    fn = shard_map(local, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model")),
                       out_specs=P(None, None, "model"),
                       check_vma=False)
    with mesh:
        got = fn(x, w)
    want = jnp.einsum("bsd,df->bsf", x, w)
    err = float(jnp.max(jnp.abs(got - want)))

    # differentiability (the TP backward path)
    def loss(x):
        with mesh:
            return jnp.sum(fn(x, w) ** 2)
    g = jax.grad(loss)(x)
    g_want = jax.grad(lambda x: jnp.sum(jnp.einsum("bsd,df->bsf", x, w) ** 2))(x)
    gerr = float(jnp.max(jnp.abs(g - g_want)))
    print(json.dumps({"err": err, "gerr": gerr}))
    """
)


def test_ring_ag_matmul_matches_gather_matmul():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] < 1e-4, rec
    assert rec["gerr"] < 1e-3, rec
