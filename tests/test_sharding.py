"""Partitioning rules + a miniature end-to-end dry run in a subprocess.

The subprocess is required because forcing a multi-device host platform
(XLA_FLAGS) must happen before JAX initializes — the main pytest process
already owns a single-device runtime.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.models import build_model
from repro.sharding import batch_pspecs, cache_pspecs, param_pspecs

MESH16 = None


def _mesh():
    # a fake Mesh-like for rule evaluation: rules only read .shape / axis_names
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    return FakeMesh()


def test_dense_param_rules():
    cfg = get_arch("qwen2-72b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    specs = param_pspecs(shapes, _mesh())
    st = specs["stack"]
    assert st["mixer"]["wq"] == P(None, None, "model")
    assert st["mixer"]["wo"] == P(None, "model", None)
    assert st["ffn"]["w_gate"] == P(None, None, "model")
    assert st["ffn"]["w_down"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)
    assert specs["head"] == P(None, "model")
    assert st["norm1"]["scale"] == P(None, None)


def test_moe_param_rules_ep():
    cfg = get_arch("qwen3-moe-30b-a3b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    specs = param_pspecs(shapes, _mesh())
    ffn = specs["stack"]["ffn"]
    assert ffn["we_gate"] == P(None, "model", None, None)   # EP over experts
    assert ffn["we_down"] == P(None, "model", None, None)
    assert ffn["router"] == P(None, None, None)


def test_indivisible_vocab_replicated():
    cfg = get_arch("mamba2-370m")  # vocab 50280 % 16 != 0
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    specs = param_pspecs(shapes, _mesh())
    assert specs["embed"] == P(None, None)


def test_batch_specs():
    specs = batch_pspecs(
        {
            "tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
            "positions": jax.ShapeDtypeStruct((3, 256, 4096), jnp.int32),
        },
        _mesh(),
    )
    assert specs["tokens"] == P(("data",), None)
    assert specs["positions"] == P(None, ("data",), None)


def test_batch_indivisible_replicates():
    specs = batch_pspecs({"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}, _mesh())
    assert specs["tokens"] == P(None, None)


def test_cache_specs_sp():
    # B=1 long-context: sequence sharded over (data, model)
    specs = cache_pspecs(
        {"k": jax.ShapeDtypeStruct((9, 1, 8, 524288, 128), jnp.bfloat16)}, _mesh()
    )
    assert specs["k"] == P(None, None, None, ("data", "model"), None)
    # B=128 decode: batch over data, seq over model
    specs = cache_pspecs(
        {"k": jax.ShapeDtypeStruct((80, 128, 8, 32768, 128), jnp.bfloat16)}, _mesh()
    )
    assert specs["k"] == P(None, ("data",), None, "model", None)


MINI_DRYRUN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.launch.steps import TrainOptions, init_train_state, make_train_step
    from repro.sharding import batch_pspecs, named, opt_pspecs, param_pspecs
    from repro.roofline.hlo_parse import analyze_module

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        get_arch("yi-6b").reduced(), num_layers=4, num_microbatches=2,
        d_model=128, d_ff=256, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32,
    )
    m = build_model(cfg)
    ps = jax.eval_shape(lambda: m.init_params(jax.random.PRNGKey(0)))
    specs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    opts = TrainOptions()
    os_ = jax.eval_shape(lambda p: init_train_state(m, p, opts)[0], ps)
    with mesh:
        fn = jax.jit(
            make_train_step(m, opts),
            in_shardings=(named(mesh, param_pspecs(ps, mesh)),
                          named(mesh, opt_pspecs(os_, mesh)), None,
                          named(mesh, batch_pspecs(specs, mesh))),
        )
        comp = fn.lower(ps, os_, None, specs).compile()
    mc = analyze_module(comp.as_text())
    print(json.dumps({
        "dot_flops": mc.dot_flops,
        "collective_bytes": mc.collective_bytes,
        "num_whiles": mc.num_whiles,
    }))
    """
)


TP_NUMERICS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import build_model, moe as M

    # dense TP blocks: shard_map vs plain path
    cfg = dataclasses.replace(get_arch("qwen2-72b").reduced(),
                              num_layers=2, d_model=64, num_heads=8,
                              num_kv_heads=2, head_dim=16, d_ff=128,
                              vocab_size=256, num_microbatches=1)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 256),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, 256)}
    ref, _ = m.forward(params, batch)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        tp, _ = jax.jit(m.forward)(params, batch)
        _ = jax.jit(jax.grad(lambda p: m.loss(p, batch)))(params)
    tp_err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - tp.astype(jnp.float32))))

    # MoE: shard_map dispatch vs grouped (no-mesh) dispatch
    mcfg = dataclasses.replace(get_arch("qwen3-moe-30b-a3b").reduced(),
                               capacity_factor=8.0, num_experts=4,
                               experts_per_token=2)
    p = M.init_moe(jax.random.PRNGKey(0), mcfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, mcfg.d_model)) * 0.3
    out_g, _ = M.moe_ffn(p, x, mcfg)
    with mesh:
        out_s, _ = jax.jit(lambda p, x: M.moe_ffn(p, x, mcfg))(p, x)
    moe_err = float(jnp.max(jnp.abs(out_g - out_s)))

    # per-matmul tp_mode paths (iteration-6 knob) still numerically exact
    from repro.core import blas
    xx = jax.random.normal(jax.random.PRNGKey(5), (4, 8, 64))
    ww = jax.random.normal(jax.random.PRNGKey(6), (64, 32))
    want = xx @ ww
    with mesh:
        row = jax.jit(lambda a, b: blas.matmul(a, b, tp_mode="row"))(xx, ww)
        col = jax.jit(lambda a, b: blas.matmul(a, b, tp_mode="col"))(xx, ww)
    tp_mm_err = max(
        float(jnp.max(jnp.abs(row - want))), float(jnp.max(jnp.abs(col - want)))
    )
    print(json.dumps({"tp_err": tp_err, "moe_err": moe_err,
                      "tp_mm_err": tp_mm_err}))
    """
)


def test_tp_and_moe_shard_map_numerics():
    """shard_map TP blocks + explicit-collective MoE must match the plain
    single-device paths (fwd bitwise-ish; grads compile)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c", TP_NUMERICS], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["tp_err"] < 3e-2, rec
    assert rec["moe_err"] < 2e-4, rec
    assert rec["tp_mm_err"] < 1e-4, rec


def test_mini_dryrun_subprocess():
    """Machinery check: an 8-device sharded train step lowers, compiles,
    and the per-device dot flops land within 2x of the analytic budget."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    B, S, L, d, dff, hq, hkv, hd, V = 8, 64, 4, 128, 256, 4, 2, 32, 512
    T = B * S
    fwd = (
        2 * T * (d * hq * hd + 2 * d * hkv * hd + hq * hd * d + 3 * d * dff) * L
        + 2 * T * d * V
        + 4 * B * hq * S * S * hd * L
    )
    per_dev_total = rec["dot_flops"] * 8  # 8 devices
    assert 2.0 * fwd < per_dev_total < 8.0 * fwd  # fwd+bwd+remat ≈ 3.8x
    assert rec["collective_bytes"] > 0
    assert rec["num_whiles"] >= 3  # mb scan + fwd/bwd layer scans
