"""Generate the §Roofline table from dry-run artifacts (single-pod mesh).

For each (arch × shape) cell:
  compute_s    = dot_flops_per_device / peak_FLOP/s
  memory_s     = traffic_bytes_per_device / HBM_bw
  collective_s = collective_bytes_per_device / link_bw
  MODEL_FLOPS  = 6 · N_active · D   (training; 2 · N_active · D inference)
  useful ratio = MODEL_FLOPS_per_device / dot_flops_per_device
  roofline fraction = ideal-compute time at peak / max(three terms)

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod16x16]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.roofline.analysis import TPU_V5E_HW

BOTTLENECK_HINTS = {
    "compute": "raise arithmetic intensity (larger microbatch per step or fused kernels); already the good case",
    "memory": "reduce HBM traffic: fuse elementwise chains, keep activations bf16, improve reuse via larger tiles",
    "collective": "reshard to cut gather/scatter volume (EP dispatch, TP all-gathers); overlap collectives with compute",
}


def load_cells(root: Path, mesh: str):
    cells = []
    for p in sorted((root / mesh).glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def model_flops_per_device(rec) -> float:
    """6·N_active·D train / 2·N_active·D inference, per device."""
    n = rec["active_params"]
    if rec["shape"] == "train_4k":
        mult = 6.0
        toks = rec["tokens_per_step"]
    elif rec["shape"] == "prefill_32k":
        mult = 2.0
        toks = rec["tokens_per_step"]
    else:
        mult = 2.0
        toks = rec["tokens_per_step"]  # decode: one token per sequence
    return mult * n * toks / rec["chips"]


def rows(cells):
    out = []
    for r in cells:
        if r.get("status") != "ok":
            out.append(
                {
                    "arch": r["arch"],
                    "shape": r["shape"],
                    "status": r.get("status"),
                    "reason": r.get("reason", r.get("error", ""))[:90],
                }
            )
            continue
        hw = TPU_V5E_HW
        comp = r["dot_flops_per_device"] / hw.peak_flops
        # Memory term: kernel-ideal HBM traffic from the BLAS seam (each op
        # streams operands/results once — the Pallas-tiled execution on the
        # real TPU).  The raw XLA:CPU module traffic (unfused S² attention
        # etc.) is kept as a reference column.
        mem = (r["seam_bytes_global"] / r["chips"]) / hw.hbm_bw
        mem_raw = r["traffic_bytes_per_device"] / hw.hbm_bw
        coll = r["collective_bytes_per_device"] / hw.link_bw
        bound = max(comp, mem, coll)
        dom = ("compute", "memory", "collective")[
            (comp, mem, coll).index(bound)
        ]
        mf = model_flops_per_device(r)
        ideal = mf / hw.peak_flops
        out.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "status": "ok",
                "compute_s": comp,
                "memory_s": mem,
                "memory_raw_s": mem_raw,
                "collective_s": coll,
                "dominant": dom,
                "model_flops_dev": mf,
                "useful_ratio": mf / r["dot_flops_per_device"],
                "roofline_fraction": ideal / bound if bound else 0.0,
                "temp_gib": r["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30,
            }
        )
    return out


def markdown(rows_, mesh: str) -> str:
    lines = [
        f"### Roofline — {mesh} (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)",
        "",
        "| arch | shape | compute s | memory s | mem(raw XLA) s | collective s | bound | 6ND/HLO | roofline frac | temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows_:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — | — |"
            )
            continue
        lines.append(
            "| {arch} | {shape} | {compute_s:.3f} | {memory_s:.3f} | {memory_raw_s:.3f} | {collective_s:.3f} "
            "| **{dominant}** | {useful_ratio:.2f} | {roofline_fraction:.1%} | {temp_gib:.1f} |".format(**r)
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--root", default="artifacts/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    cells = load_cells(Path(args.root), args.mesh)
    rws = rows(cells)
    if args.csv:
        print("arch,shape,compute_s,memory_s,collective_s,dominant,useful_ratio,roofline_frac,temp_gib")
        for r in rws:
            if r["status"] == "ok":
                print(
                    f"{r['arch']},{r['shape']},{r['compute_s']:.4f},{r['memory_s']:.4f},"
                    f"{r['collective_s']:.4f},{r['dominant']},{r['useful_ratio']:.3f},"
                    f"{r['roofline_fraction']:.4f},{r['temp_gib']:.1f}"
                )
    else:
        print(markdown(rws, args.mesh))


if __name__ == "__main__":
    main()
