"""Pallas GEMM kernel sweep: wall-time (interpret mode) + modeled device
occupancy for each tile configuration. One row per (shape, block).

Run: PYTHONPATH=src:. python -m benchmarks.gemm_sweep
(interpret mode is a correctness vehicle; timings are CPU-emulation times,
the modeled columns are the TPU-target numbers.)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TPU_V5E, gemm_cost
from repro.kernels import ops, ref

SHAPES = ((256, 256, 256), (512, 512, 256), (1024, 512, 512))
BLOCKS = ((128, 128, 128), (64, 64, 64), (128, 64, 256))


def main() -> None:
    rng = np.random.default_rng(0)
    print("m,n,k,block,us_per_call_interp,max_err,modeled_tpu_us,mxu_util")
    for m, n, k in SHAPES:
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        want = np.asarray(ref.gemm_ref(a, b))
        for blk in BLOCKS:
            f = lambda: ops.gemm(a, b, block=blk, interpret=True)
            out = f()
            err = float(np.max(np.abs(np.asarray(out) - want)))
            t0 = time.perf_counter()
            f().block_until_ready()
            dt = (time.perf_counter() - t0) * 1e6
            c = gemm_cost(m, n, k, 4)
            modeled = c.flops / TPU_V5E.dev_flops * 1e6
            # MXU utilisation of the tile geometry (edge padding waste)
            bm, bn, bk = blk
            pads = (
                (m + (-m) % bm) * (n + (-n) % bn) * (k + (-k) % bk)
            ) / (m * n * k)
            print(
                f"{m},{n},{k},{bm}x{bn}x{bk},{dt:.0f},{err:.2e},"
                f"{modeled:.2f},{1/pads:.2f}"
            )


if __name__ == "__main__":
    main()
