"""Reproduce the paper's Figure 3: float64 GEMM runtime, host vs offload,
decomposed into the three regions, for n in {16, 32, 64, 128}.

Run: PYTHONPATH=src:. python -m benchmarks.paper_fig3
"""

from __future__ import annotations

from repro.core import HESOC_VCU128, breakdown, gemm_cost

SIZES = (16, 32, 64, 128)
F64 = 8


def rows():
    out = []
    for n in SIZES:
        c = gemm_cost(n, n, n, F64)
        bd = breakdown(c, HESOC_VCU128)
        bz = breakdown(c, HESOC_VCU128, zero_copy=True)
        out.append(
            {
                "n": n,
                "host_ms": bd.host_s * 1e3,
                "copy_ms": bd.copy_s * 1e3,
                "fork_join_ms": bd.fork_join_s * 1e3,
                "compute_ms": bd.compute_s * 1e3,
                "offload_ms": bd.offload_s * 1e3,
                "speedup": bd.speedup,
                "zero_copy_speedup": bz.speedup,
            }
        )
    return out


def ascii_figure(rows_) -> str:
    """Stacked-bar rendition of Figure 3 (host vs offload per size)."""
    lines = ["Figure 3 reproduction — float64 GEMM on CVA6+Snitch (modeled)", ""]
    scale = max(r["host_ms"] for r in rows_) / 60.0
    for r in rows_:
        host = int(r["host_ms"] / scale)
        copy = max(int(r["copy_ms"] / scale), 1)
        fork = max(int(r["fork_join_ms"] / scale), 1)
        comp = max(int(r["compute_ms"] / scale), 1)
        lines.append(f"n={r['n']:<4d} host    |{'H' * host} {r['host_ms']:.1f} ms")
        lines.append(
            f"      offload |{'C' * copy}{'F' * fork}{'X' * comp} "
            f"{r['offload_ms']:.1f} ms  (copy/fork-join/compute)  "
            f"speedup {r['speedup']:.2f}x"
        )
        lines.append("")
    return "\n".join(lines)


def main() -> None:
    rws = rows()
    print(ascii_figure(rws))
    print("n,host_ms,copy_ms,fork_join_ms,compute_ms,offload_ms,speedup,zero_copy_speedup")
    for r in rws:
        print(
            f"{r['n']},{r['host_ms']:.3f},{r['copy_ms']:.3f},{r['fork_join_ms']:.3f},"
            f"{r['compute_ms']:.3f},{r['offload_ms']:.3f},{r['speedup']:.3f},"
            f"{r['zero_copy_speedup']:.3f}"
        )


if __name__ == "__main__":
    main()
