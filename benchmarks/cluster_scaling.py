"""HeroCluster scaling sweep: modeled throughput, 1 -> 8 virtual PMCAs.

A fixed GEMM workload (a serving-shaped mix of large and small calls) is
pushed through the offload seam against clusters of increasing size.  For
each size the sweep reports the modeled cluster makespan (per-device
copy/compute-overlap timelines, devices in parallel), the throughput in
GFLOP/s, and the per-device trace rollups — asserting that the per-device
region sums equal the cluster totals.

Throughput must rise monotonically 1 -> 8 for the balanced schedulers; the
sweep prints all three policies side by side.

Run: PYTHONPATH=src:. python -m benchmarks.cluster_scaling
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import accounting, blas
from repro.core.hero import SCHEDULERS, engine, offload_policy

# A serving-shaped workload: a few big prefill GEMMs, many medium decode
# GEMMs, a tail of small projections.  Sizes chosen so every call clears
# the offload crossover on the TPU platform model.
WORKLOAD = (
    [(1024, 1024, 1024)] * 4
    + [(512, 512, 512)] * 12
    + [(256, 1024, 256)] * 16
)


def run_workload() -> accounting.OffloadTrace:
    with accounting.offload_trace() as trace:
        for m, n, k in WORKLOAD:
            a = jnp.ones((m, k), jnp.bfloat16)
            b = jnp.ones((k, n), jnp.bfloat16)
            blas.gemm(a, b)
    return trace


def sweep(scheduler: str, sizes=(1, 2, 4, 8)) -> list:
    rows = []
    for n in sizes:
        with offload_policy(
            mode="device", num_devices=n, scheduler=scheduler,
            platform="tpu-v5e",
        ):
            engine().reset()
            trace = run_workload()
            engine().sync()
        per_dev = trace.by_device()
        copy, fork, comp, _ = trace.totals()
        # invariant: per-device rollups sum to the cluster totals
        assert abs(sum(d.copy_s for d in per_dev.values()) - copy) < 1e-12
        assert abs(sum(d.compute_s for d in per_dev.values()) - comp) < 1e-12
        makespan = trace.cluster_makespan_s()
        flops = trace.total_flops()
        rows.append(
            {
                "devices": n,
                "used": len(per_dev),
                "makespan_s": makespan,
                "gflops": flops / makespan / 1e9,
                "serial_s": copy + fork + comp,
            }
        )
    return rows


def main() -> None:
    for scheduler in sorted(SCHEDULERS):
        print(f"\n# scheduler={scheduler}")
        print("devices,used,makespan_s,gflops_modeled,serial_s,scaling_vs_1dev")
        rows = sweep(scheduler)
        base = rows[0]["gflops"]
        prev = 0.0
        monotone = True
        for r in rows:
            print(
                f"{r['devices']},{r['used']},{r['makespan_s']:.6f},"
                f"{r['gflops']:.1f},{r['serial_s']:.6f},"
                f"{r['gflops'] / base:.2f}x"
            )
            monotone = monotone and r["gflops"] >= prev - 1e-9
            prev = r["gflops"]
        print(f"monotone_1_to_8={monotone}")


if __name__ == "__main__":
    main()
