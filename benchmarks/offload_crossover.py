"""TPU-native offload crossover: the paper's size-dependent decision with
v5e constants, plus the resident-weights (zero-copy) regime.

Run: PYTHONPATH=src:. python -m benchmarks.offload_crossover
"""

from __future__ import annotations

from repro.core import TPU_V5E, breakdown, crossover_size, gemm_cost

BF16 = 2


def main() -> None:
    print("n,speedup_staged,speedup_resident,offload_staged,offload_resident")
    for n in (64, 128, 256, 512, 1024, 2048, 4096, 8192):
        c = gemm_cost(n, n, n, BF16)
        staged = breakdown(c, TPU_V5E)
        resident = breakdown(c, TPU_V5E, resident_fraction=1.0)
        print(
            f"{n},{staged.speedup:.2f},{resident.speedup:.2f},"
            f"{staged.speedup >= 1.0},{resident.speedup >= 1.0}"
        )
    print()
    print("crossover (staged, bf16):", crossover_size(TPU_V5E, BF16))
    print(
        "crossover (resident — the paper's IOMMU end-state):",
        crossover_size(TPU_V5E, BF16, zero_copy=True),
    )


if __name__ == "__main__":
    main()
