"""Benchmark runner — one section per paper table/figure + framework tables.

Two modes:

* default        — prints ``name,us_per_call,derived`` CSV blocks per
                   section (the full human-readable sweep).
* ``--smoke``    — a fast, deterministic subset (modeled numbers only plus
                   one smoke serve round) written to ``BENCH_offload.json``:
                   gemm sweep, cluster scaling 1->8, the serve makespan of
                   pinned cost-aware vs unpinned round-robin placement, and
                   the frontend graph-vs-eager comparison.  Each smoke run
                   also *appends* a headline line to ``BENCH_trajectory.jsonl``
                   (commit + timestamp from the CI env when present), so the
                   perf trajectory accumulates across PRs instead of being
                   overwritten.  Runs in CI after ``make check`` (``make ci``).

Run: PYTHONPATH=src:. python -m benchmarks.run [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def _section(title: str) -> None:
    print(f"\n### {title}", flush=True)


# ---------------------------------------------------------------------------
# --smoke: BENCH_offload.json
# ---------------------------------------------------------------------------

def _smoke_gemm_sweep() -> list:
    """Modeled offload decision across square GEMM sizes, both platforms.

    Each (n, platform) emits a ``cold`` row (every operand staged) and a
    ``steady`` row (weights + output resident, resident_fraction=2/3 — the
    serving/chain regime the frontend's residency threading produces), with
    both the serial and the chunked double-buffered staging model side by
    side.  ``pipelined_vs_max`` is the pipeline-quality metric: modeled
    offload time over max(copy, compute) — 1.0 is a perfect shingle.
    """
    from repro.core import (
        HESOC_VCU128,
        TPU_V5E,
        breakdown,
        gemm_cost,
        pipelined_breakdown,
    )

    rows = []
    for n in (128, 256, 512, 1024, 2048, 4096, 8192):
        cost = gemm_cost(n, n, n, 4)
        for plat in (HESOC_VCU128, TPU_V5E):
            for regime, rf in (("cold", 0.0), ("steady", 2.0 / 3.0)):
                bd = breakdown(cost, plat, resident_fraction=rf)
                p = pipelined_breakdown(cost, plat, resident_fraction=rf)
                denom = max(p.copy_s, p.compute_s)
                rows.append({
                    "n": n,
                    "platform": plat.name,
                    "regime": regime,
                    "resident_fraction": rf,
                    "offload_s": bd.offload_s,
                    "host_s": bd.host_s,
                    "speedup": bd.speedup,
                    "copy_fraction": bd.copy_fraction,
                    "pipelined_offload_s": p.offload_s,
                    "pipelined_speedup": p.pipelined_speedup,
                    "pipelined_copy_fraction": p.copy_fraction,
                    "chunks": p.chunks,
                    "pipelined_vs_max": (
                        p.offload_s / denom if denom > 0 else 1.0
                    ),
                })
    return rows


def _smoke_pipelined_staging() -> dict:
    """Chunked double-buffered staging vs the serial copy-then-compute model.

    Three regimes pin the headline:

    * ``paper_crossover`` — the paper's n=128 float64 GEMM on the heSoC,
      where T_copy ~ T_compute (the 0.47 copy-fraction anchor).  Balanced
      streams are exactly where overlap pays the most: the ~2x modeled win
      ROADMAP open item 2 called out.
    * ``tpu_n2048`` — the acceptance point: cold large-n staging on tpu-v5e
      must approach max(copy, compute), not copy + compute.
    * ``tpu_large_n_steady`` — n=8192 with weights+output resident
      (resident_fraction=2/3): the serving regime where serial staging
      spends 0.60 of offload time copying; the pipeline hides most of it.
    """
    from repro.core import (
        HESOC_VCU128,
        TPU_V5E,
        breakdown,
        gemm_cost,
        pipelined_breakdown,
    )

    def entry(cost, plat, rf=0.0):
        s = breakdown(cost, plat, resident_fraction=rf)
        p = pipelined_breakdown(cost, plat, resident_fraction=rf)
        denom = max(p.copy_s, p.compute_s)
        return {
            "platform": plat.name,
            "resident_fraction": rf,
            "serial_offload_s": s.offload_s,
            "pipelined_offload_s": p.offload_s,
            "chunks": p.chunks,
            "pipelined_speedup": p.pipelined_speedup,
            "serial_copy_fraction": s.copy_fraction,
            "pipelined_copy_fraction": p.copy_fraction,
            "pipelined_vs_max": p.offload_s / denom if denom > 0 else 1.0,
        }

    out = {
        "paper_crossover": dict(
            n=128, dtype="float64",
            **entry(gemm_cost(128, 128, 128, 8), HESOC_VCU128),
        ),
        "tpu_n2048": dict(
            n=2048, dtype="float32",
            **entry(gemm_cost(2048, 2048, 2048, 4), TPU_V5E),
        ),
        "tpu_large_n_steady": dict(
            n=8192, dtype="float32",
            **entry(gemm_cost(8192, 8192, 8192, 4), TPU_V5E, rf=2.0 / 3.0),
        ),
    }
    return out


def _smoke_cluster_scaling() -> dict:
    """Modeled throughput scaling 1 -> 8 PMCAs, per scheduler."""
    from benchmarks.cluster_scaling import sweep

    out = {}
    for scheduler in ("round-robin", "least-loaded", "cost-aware"):
        rows = sweep(scheduler)
        out[scheduler] = rows
        base = rows[0]["gflops"]
        out[scheduler + "_scaling_8dev"] = rows[-1]["gflops"] / base
    return out


def _smoke_serve_makespan() -> dict:
    """KV-cache placement routing: pinned cost-aware vs unpinned RR."""
    import numpy as np

    from repro.core.hero import engine, offload_policy
    from repro.launch.serve import serve_cluster

    rng = np.random.default_rng(0)
    batches = [
        [list(rng.integers(1, 200, size=3)) for _ in range(4)]
        for _ in range(4)
    ]
    out = {}
    for label, scheduler, pin in (
        ("pinned-cost-aware", "cost-aware", True),
        ("unpinned-round-robin", "round-robin", False),
    ):
        with offload_policy(mode="device", num_devices=2, scheduler=scheduler):
            engine().reset()
            res = serve_cluster(
                "yi-6b", batches, smoke=True, max_new_tokens=2,
                cache_len=512, pin_caches=pin,
            )
        out[label] = {
            "makespan_s": res.makespan_s,
            "tokens_per_s": res.tokens_per_s,
            "d2d_s": res.d2d_s,
            "restage_s": res.restage_s,
            "prefill_placements": res.prefill_placements,
            "decode_placements": res.placements,
        }
    out["pinned_speedup"] = (
        out["unpinned-round-robin"]["makespan_s"]
        / max(out["pinned-cost-aware"]["makespan_s"], 1e-30)
    )
    return out


def _smoke_frontend_graph() -> dict:
    """Graph frontend vs eager BLAS: same 3-GEMM chain, modeled numbers.

    Eager ``blas.*`` pays full host<->device staging per op; the ``hnp``
    graph threads residency (intermediates stay on device) and fuses the
    elementwise links, so it must win on staged bytes and modeled time."""
    import jax.numpy as jnp
    import numpy as np

    import repro.hnp as hnp
    from repro.core import blas, engine, offload_policy, offload_trace

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    ws = [
        jnp.asarray(rng.normal(size=(512, 512)), jnp.float32),
        jnp.asarray(rng.normal(size=(512, 512)), jnp.float32),
        jnp.asarray(rng.normal(size=(512, 256)), jnp.float32),
    ]

    def stats(trace):
        copy, fork, comp, _ = trace.totals()
        return {
            "launches": len(trace.offloaded()),
            "staged_bytes": trace.total_staged_bytes(),
            "staged_bytes_charged": trace.total_staged_bytes_charged(),
            "offload_s": copy + fork + comp + trace.total_d2d_s(),
            "makespan_s": trace.cluster_makespan_s(),
        }

    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        engine().reset()
        with offload_trace() as t_eager:
            h = blas.matmul(x, ws[0])
            h = jnp.tanh(h)
            h = blas.matmul(h, ws[1])
            h = jnp.tanh(h)
            blas.matmul(h, ws[2])
        engine().reset()
        with offload_trace() as t_graph:
            with hnp.offload_region("bench-chain") as region:
                g = hnp.tanh(hnp.array(x) @ ws[0])
                g = hnp.tanh(g @ ws[1])
                hnp.asnumpy(g @ ws[2])
    eager, graph = stats(t_eager), stats(t_graph)
    return {
        "eager": eager,
        "graph": graph,
        "graph_fused_ops": region.report.fused_ops,
        "graph_readback_bytes": region.report.readback_bytes,
        "staging_bytes_saved": (
            eager["staged_bytes_charged"] - graph["staged_bytes_charged"]
        ),
        "modeled_speedup": eager["offload_s"] / max(graph["offload_s"], 1e-30),
    }


def _smoke_model_forward() -> dict:
    """Eager block forward vs graph-captured forward, same model + batch.

    ``forward_mode="graph"`` lowers each block as an hnp expression graph
    through the same registered descriptors; it must fuse at least one
    elementwise epilogue (residual/gate) and save staging bytes via
    per-launch residency threading."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core import engine, offload_policy, offload_trace
    from repro.models import build_model
    from repro.models import forward as F

    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size
        )
    }
    model_g = build_model(dataclasses.replace(cfg, forward_mode="graph"))

    def stats(trace):
        copy, fork, comp, _ = trace.totals()
        return {
            "launches": len(trace.offloaded()),
            "staged_bytes_charged": trace.total_staged_bytes_charged(),
            "offload_s": copy + fork + comp + trace.total_d2d_s(),
        }

    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        engine().reset()
        with offload_trace() as t_eager:
            model.forward(params, batch)
        engine().reset()
        with F.capture_reports() as reports:
            with offload_trace() as t_graph:
                model_g.forward(params, batch)
    eager, graph = stats(t_eager), stats(t_graph)
    fused_launches = sum(
        1 for rep in reports for launch in rep.launches if launch.fused
    )
    return {
        "arch": cfg.name,
        "eager": eager,
        "graph": graph,
        "fused_launches": fused_launches,
        "batched_launches": sum(r.batched_launches for r in reports),
        "nodes_eliminated": sum(r.nodes_eliminated for r in reports),
        "staging_bytes_saved": (
            eager["staged_bytes_charged"] - graph["staged_bytes_charged"]
        ),
        "modeled_speedup": eager["offload_s"] / max(graph["offload_s"], 1e-30),
    }


def _smoke_failover_accounting() -> dict:
    """Device failure mid-flight: the requeued work must stay on the books.

    Launches a round-robin GEMM burst on 2 devices, kills device 0 with
    tickets still in flight, and rolls up the trace.  Regression target:
    ``fail_device``/``resize`` used to move the LaunchTicket but record
    nothing, so ``by_device()``/``device_timelines()`` silently dropped the
    re-run compute from the surviving device's busy time.  Now every
    requeue adds one compute-only record on the survivor (the aborted
    attempt stays charged to the lost lane), and ``requeued_compute_s``
    rides the trajectory headline so it can't regress to zero."""
    from repro.core import gemm_cost, offload_trace
    from repro.core.hero import HeroCluster

    c = HeroCluster(num_devices=2, scheduler="round-robin")
    with offload_trace() as t:
        for i in range(4):
            c.launch(
                gemm_cost(512, 512, 512, 2), dtype="bfloat16",
                shape_key=f"fo{i}",
            )
        moved = c.fail_device(0)
    requeues = [r for r in t.records if r.note.startswith("requeue")]
    requeued_s = sum(r.regions.compute_s * r.count for r in requeues)
    timelines = t.device_timelines()
    return {
        "tickets_moved": len(moved),
        "requeue_records": len(requeues),
        "requeued_compute_s": requeued_s,
        "survivor_compute_busy_s": timelines[1].compute_busy_s,
        "by_device_compute_s": {
            str(dev): agg.compute_s for dev, agg in sorted(t.by_device().items())
        },
    }


def _smoke_offered_load() -> dict:
    """Offered-load sweep: the streaming engine's max-QPS-at-SLO headline.

    Three load points (under / at / 2x the modeled capacity) over one
    seeded bursty trace, continuous batching vs the lock-step baseline on
    the identical request population.  Entirely modeled — no model build —
    so this is cheap despite using the full (non-reduced) arch config.
    The recorded ``seed`` makes every number replayable bit-for-bit."""
    from repro.launch.streaming import offered_load_sweep

    return offered_load_sweep("yi-6b", seed=0)


def _smoke_expert_placement() -> dict:
    """Skewed-router sweep: dynamic expert placement vs static homes.

    Zipfian expert popularity at three skew points over the same seeded
    router stream; dynamic migrates/replicates hot experts (d2d charged on
    the DMA stream clocks) while static keeps the contiguous-block homes.
    The headline ``expert_placement_speedup`` is the modeled-makespan
    ratio at the gated point s=1.2; every point records its seed and full
    token conservation (routed = processed + dropped, zero unaccounted)."""
    from repro.core.placement import placement_sweep

    return placement_sweep(seed=0)


def _git_commit() -> str:
    for var in ("GITHUB_SHA", "CI_COMMIT_SHA"):
        if os.environ.get(var):
            return os.environ[var]
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _ci_run_id() -> str:
    """Best-effort CI run identifier across the common CI environments."""
    for var in ("GITHUB_RUN_ID", "CI_RUN_ID", "CI_JOB_ID", "CI_PIPELINE_ID",
                "BUILD_ID"):
        v = os.environ.get(var)
        if v:
            return v
    return ""


def _headline_hash(headline: dict) -> str:
    """Stable content hash of a headline, excluding run-noise fields.

    ``elapsed_s`` (and ``timestamp``/``ci_run`` at the entry level) vary per
    run even when the modeled numbers are identical; the dedupe key must
    not, or re-running smoke at the same commit appends duplicates forever.
    """
    import hashlib

    stable = {k: v for k, v in headline.items() if k != "elapsed_s"}
    payload = json.dumps(stable, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _append_trajectory(summary: dict, path: str = "BENCH_trajectory.jsonl") -> dict:
    """One headline line per smoke run — deduped by (commit, headline-hash).

    The perf trajectory accumulates across PRs instead of being overwritten
    per run, but re-running smoke at the same commit with the same modeled
    numbers must not append a duplicate line.  Pre-existing duplicates are
    compacted on the rewrite (first occurrence wins).
    """
    serve = summary["serve_makespan"]
    frontend = summary["frontend_graph"]
    model_fwd = summary["model_forward"]
    pipelined = summary["pipelined_staging"]
    stream = summary["offered_load_sweep"]
    entry = {
        "commit": _git_commit(),
        # CI stamps a reproducible time; local runs fall back to wall clock.
        "timestamp": os.environ.get("CI_TIMESTAMP")
        or time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "ci_run": _ci_run_id(),
        "headline": {
            "cost_aware_scaling_8dev": summary["cluster_scaling"][
                "cost-aware_scaling_8dev"
            ],
            "serve_pinned_speedup": serve["pinned_speedup"],
            "frontend_modeled_speedup": frontend["modeled_speedup"],
            "frontend_staging_bytes_saved": frontend["staging_bytes_saved"],
            "model_forward_speedup": model_fwd["modeled_speedup"],
            "model_forward_staging_saved": model_fwd["staging_bytes_saved"],
            "model_forward_fused_launches": model_fwd["fused_launches"],
            "pipelined_speedup": pipelined["paper_crossover"][
                "pipelined_speedup"
            ],
            "tpu_large_n_copy_fraction": pipelined["tpu_large_n_steady"][
                "pipelined_copy_fraction"
            ],
            "tpu_n2048_vs_max": pipelined["tpu_n2048"]["pipelined_vs_max"],
            "max_qps_at_slo": stream["max_qps_at_slo"],
            "stream_vs_lockstep_qps": stream["continuous_vs_lockstep"][
                "speedup"
            ],
            "expert_placement_speedup": summary["expert_placement"][
                "expert_placement_speedup"
            ],
            "requeued_compute_s": summary["failover_accounting"][
                "requeued_compute_s"
            ],
            "elapsed_s": summary["elapsed_s"],
        },
    }
    kept: list = []
    seen: set = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    e = json.loads(line)
                except ValueError:
                    continue  # drop corrupt lines rather than crash the gate
                k = (e.get("commit", ""), _headline_hash(e.get("headline", {})))
                if k in seen:
                    continue
                seen.add(k)
                kept.append(e)
    key = (entry["commit"], _headline_hash(entry["headline"]))
    if key not in seen:
        kept.append(entry)
    with open(path, "w") as f:
        for e in kept:
            f.write(json.dumps(e) + "\n")
    return entry


def smoke(out_path: str = "BENCH_offload.json") -> dict:
    from repro.obs import metrics as obs_metrics

    t0 = time.time()
    with obs_metrics.collect() as reg:
        summary = {
            "gemm_sweep": _smoke_gemm_sweep(),
            "pipelined_staging": _smoke_pipelined_staging(),
            "cluster_scaling": _smoke_cluster_scaling(),
            "serve_makespan": _smoke_serve_makespan(),
            "offered_load_sweep": _smoke_offered_load(),
            "frontend_graph": _smoke_frontend_graph(),
            "model_forward": _smoke_model_forward(),
            "failover_accounting": _smoke_failover_accounting(),
            "expert_placement": _smoke_expert_placement(),
        }
    # every dispatch/stream/serve counter the smoke sections incremented,
    # rolled flat — the bench gate asserts this snapshot is present
    summary["metrics"] = reg.rollup()
    summary["elapsed_s"] = time.time() - t0
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    _append_trajectory(summary)
    serve = summary["serve_makespan"]
    frontend = summary["frontend_graph"]
    model_fwd = summary["model_forward"]
    pipe = summary["pipelined_staging"]
    print(
        f"BENCH_offload: gemm_sweep={len(summary['gemm_sweep'])} rows, "
        f"pipelined staging speedup="
        f"{pipe['paper_crossover']['pipelined_speedup']:.2f}x @ paper "
        f"crossover (tpu large-n steady copy_fraction="
        f"{pipe['tpu_large_n_steady']['pipelined_copy_fraction']:.2f}), "
        f"cost-aware 8-dev scaling="
        f"{summary['cluster_scaling']['cost-aware_scaling_8dev']:.2f}x, "
        f"pinned-vs-unpinned serve speedup={serve['pinned_speedup']:.2f}x, "
        f"max QPS at SLO={summary['offered_load_sweep']['max_qps_at_slo']:.0f} "
        f"(continuous vs lockstep "
        f"{summary['offered_load_sweep']['continuous_vs_lockstep']['speedup']:.2f}x), "
        f"hnp graph-vs-eager speedup={frontend['modeled_speedup']:.2f}x "
        f"(staging saved={frontend['staging_bytes_saved']:.0f}B), "
        f"model graph-forward speedup={model_fwd['modeled_speedup']:.2f}x "
        f"({model_fwd['fused_launches']} fused launches, "
        f"staging saved={model_fwd['staging_bytes_saved']:.0f}B), "
        f"failover requeued compute="
        f"{summary['failover_accounting']['requeued_compute_s']:.2e}s over "
        f"{summary['failover_accounting']['requeue_records']} requeues, "
        f"expert placement dynamic-vs-static="
        f"{summary['expert_placement']['expert_placement_speedup']:.2f}x "
        f"@ Zipf s=1.2, "
        f"{len(summary['metrics'])} metric series "
        f"-> {out_path} ({summary['elapsed_s']:.1f}s)"
    )
    return summary


# ---------------------------------------------------------------------------
# default: the full human-readable sweep
# ---------------------------------------------------------------------------

def full() -> None:
    t0 = time.time()

    _section("paper_fig3 — Figure 3 reproduction (heSoC platform model)")
    from benchmarks import paper_fig3

    paper_fig3.main()

    _section("offload_crossover — TPU-native offload decision")
    from benchmarks import offload_crossover

    offload_crossover.main()

    _section("gemm_sweep — Pallas GEMM kernel (interpret) vs oracle")
    from benchmarks import gemm_sweep

    gemm_sweep.main()

    _section("cluster_scaling — HeroCluster modeled throughput, 1 -> 8 PMCAs")
    from benchmarks import cluster_scaling

    cluster_scaling.main()

    _section("roofline_table — per-cell roofline terms (from dry-run artifacts)")
    from pathlib import Path

    from benchmarks import roofline_table

    root = Path("artifacts/dryrun_opt")
    if not root.exists():
        root = Path("artifacts/dryrun")
    if root.exists():
        cells = roofline_table.load_cells(root, "pod16x16")
        print(roofline_table.markdown(roofline_table.rows(cells), "pod16x16"))
    else:
        print("(no dry-run artifacts found — run `python -m repro.launch.dryrun --all`)")

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset -> BENCH_offload.json (CI gate)")
    ap.add_argument("--out", default="BENCH_offload.json",
                    help="output path for --smoke")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.out)
    else:
        full()


if __name__ == "__main__":
    main()
