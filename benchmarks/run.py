"""Benchmark runner — one section per paper table/figure + framework tables.

Prints ``name,us_per_call,derived`` CSV blocks per section.
Run: PYTHONPATH=src:. python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time


def _section(title: str) -> None:
    print(f"\n### {title}", flush=True)


def main() -> None:
    t0 = time.time()

    _section("paper_fig3 — Figure 3 reproduction (heSoC platform model)")
    from benchmarks import paper_fig3

    paper_fig3.main()

    _section("offload_crossover — TPU-native offload decision")
    from benchmarks import offload_crossover

    offload_crossover.main()

    _section("gemm_sweep — Pallas GEMM kernel (interpret) vs oracle")
    from benchmarks import gemm_sweep

    gemm_sweep.main()

    _section("cluster_scaling — HeroCluster modeled throughput, 1 -> 8 PMCAs")
    from benchmarks import cluster_scaling

    cluster_scaling.main()

    _section("roofline_table — per-cell roofline terms (from dry-run artifacts)")
    from pathlib import Path

    from benchmarks import roofline_table

    root = Path("artifacts/dryrun_opt")
    if not root.exists():
        root = Path("artifacts/dryrun")
    if root.exists():
        cells = roofline_table.load_cells(root, "pod16x16")
        print(roofline_table.markdown(roofline_table.rows(cells), "pod16x16"))
    else:
        print("(no dry-run artifacts found — run `python -m repro.launch.dryrun --all`)")

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
