"""Benchmark runner — one section per paper table/figure + framework tables.

Two modes:

* default        — prints ``name,us_per_call,derived`` CSV blocks per
                   section (the full human-readable sweep).
* ``--smoke``    — a fast, deterministic subset (modeled numbers only plus
                   one smoke serve round) written to ``BENCH_offload.json``:
                   gemm sweep, cluster scaling 1->8, and the serve makespan
                   of pinned cost-aware vs unpinned round-robin placement.
                   Runs in CI after ``make check`` (``make ci``), so the
                   perf trajectory is recorded on every PR.

Run: PYTHONPATH=src:. python -m benchmarks.run [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _section(title: str) -> None:
    print(f"\n### {title}", flush=True)


# ---------------------------------------------------------------------------
# --smoke: BENCH_offload.json
# ---------------------------------------------------------------------------

def _smoke_gemm_sweep() -> list:
    """Modeled offload decision across square GEMM sizes, both platforms."""
    from repro.core import HESOC_VCU128, TPU_V5E, breakdown, gemm_cost

    rows = []
    for n in (128, 256, 512, 1024, 2048):
        cost = gemm_cost(n, n, n, 4)
        for plat in (HESOC_VCU128, TPU_V5E):
            bd = breakdown(cost, plat)
            rows.append({
                "n": n,
                "platform": plat.name,
                "offload_s": bd.offload_s,
                "host_s": bd.host_s,
                "speedup": bd.speedup,
                "copy_fraction": bd.copy_fraction,
            })
    return rows


def _smoke_cluster_scaling() -> dict:
    """Modeled throughput scaling 1 -> 8 PMCAs, per scheduler."""
    from benchmarks.cluster_scaling import sweep

    out = {}
    for scheduler in ("round-robin", "least-loaded", "cost-aware"):
        rows = sweep(scheduler)
        out[scheduler] = rows
        base = rows[0]["gflops"]
        out[scheduler + "_scaling_8dev"] = rows[-1]["gflops"] / base
    return out


def _smoke_serve_makespan() -> dict:
    """KV-cache placement routing: pinned cost-aware vs unpinned RR."""
    import numpy as np

    from repro.core.hero import engine, offload_policy
    from repro.launch.serve import serve_cluster

    rng = np.random.default_rng(0)
    batches = [
        [list(rng.integers(1, 200, size=3)) for _ in range(4)]
        for _ in range(4)
    ]
    out = {}
    for label, scheduler, pin in (
        ("pinned-cost-aware", "cost-aware", True),
        ("unpinned-round-robin", "round-robin", False),
    ):
        with offload_policy(mode="device", num_devices=2, scheduler=scheduler):
            engine().reset()
            res = serve_cluster(
                "yi-6b", batches, smoke=True, max_new_tokens=2,
                cache_len=512, pin_caches=pin,
            )
        out[label] = {
            "makespan_s": res.makespan_s,
            "tokens_per_s": res.tokens_per_s,
            "d2d_s": res.d2d_s,
            "restage_s": res.restage_s,
            "prefill_placements": res.prefill_placements,
            "decode_placements": res.placements,
        }
    out["pinned_speedup"] = (
        out["unpinned-round-robin"]["makespan_s"]
        / max(out["pinned-cost-aware"]["makespan_s"], 1e-30)
    )
    return out


def smoke(out_path: str = "BENCH_offload.json") -> dict:
    t0 = time.time()
    summary = {
        "gemm_sweep": _smoke_gemm_sweep(),
        "cluster_scaling": _smoke_cluster_scaling(),
        "serve_makespan": _smoke_serve_makespan(),
    }
    summary["elapsed_s"] = time.time() - t0
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    serve = summary["serve_makespan"]
    print(
        f"BENCH_offload: gemm_sweep={len(summary['gemm_sweep'])} rows, "
        f"cost-aware 8-dev scaling="
        f"{summary['cluster_scaling']['cost-aware_scaling_8dev']:.2f}x, "
        f"pinned-vs-unpinned serve speedup={serve['pinned_speedup']:.2f}x "
        f"-> {out_path} ({summary['elapsed_s']:.1f}s)"
    )
    return summary


# ---------------------------------------------------------------------------
# default: the full human-readable sweep
# ---------------------------------------------------------------------------

def full() -> None:
    t0 = time.time()

    _section("paper_fig3 — Figure 3 reproduction (heSoC platform model)")
    from benchmarks import paper_fig3

    paper_fig3.main()

    _section("offload_crossover — TPU-native offload decision")
    from benchmarks import offload_crossover

    offload_crossover.main()

    _section("gemm_sweep — Pallas GEMM kernel (interpret) vs oracle")
    from benchmarks import gemm_sweep

    gemm_sweep.main()

    _section("cluster_scaling — HeroCluster modeled throughput, 1 -> 8 PMCAs")
    from benchmarks import cluster_scaling

    cluster_scaling.main()

    _section("roofline_table — per-cell roofline terms (from dry-run artifacts)")
    from pathlib import Path

    from benchmarks import roofline_table

    root = Path("artifacts/dryrun_opt")
    if not root.exists():
        root = Path("artifacts/dryrun")
    if root.exists():
        cells = roofline_table.load_cells(root, "pod16x16")
        print(roofline_table.markdown(roofline_table.rows(cells), "pod16x16"))
    else:
        print("(no dry-run artifacts found — run `python -m repro.launch.dryrun --all`)")

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset -> BENCH_offload.json (CI gate)")
    ap.add_argument("--out", default="BENCH_offload.json",
                    help="output path for --smoke")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.out)
    else:
        full()


if __name__ == "__main__":
    main()
