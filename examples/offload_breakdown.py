"""The paper's Figure-3 experiment as a runnable script: sweep GEMM sizes
on the modeled heSoC, print region breakdowns and the crossover, then show
what a whole transformer forward pass looks like through the same lens.

Run: PYTHONPATH=src python examples/offload_breakdown.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import (
    HESOC_VCU128,
    breakdown,
    engine,
    gemm_cost,
    offload_policy,
    offload_trace,
)
from repro.models import build_model


def gemm_sweep() -> None:
    print("float64 GEMM on the paper's heSoC (modeled):")
    print(f"{'n':>6} {'host ms':>9} {'offload ms':>11} {'copy%':>6} {'speedup':>8}")
    for n in (16, 32, 64, 128, 256, 512):
        bd = breakdown(gemm_cost(n, n, n, 8), HESOC_VCU128)
        print(
            f"{n:>6} {bd.host_s*1e3:>9.1f} {bd.offload_s*1e3:>11.1f} "
            f"{bd.copy_fraction:>6.0%} {bd.speedup:>8.2f}x"
        )


def model_breakdown() -> None:
    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    engine().reset()
    with offload_policy(mode="auto", platform="tpu-v5e", resident_fraction=1.0):
        with offload_trace() as t:
            model.forward(params, batch)
    print("\nwhole-model forward through the seam (yi-6b reduced):")
    print(t.summary())
    print("per-op:")
    for op, d in sorted(t.by_op().items()):
        print(f"  {op:14s} calls={d['calls']:3d} offloaded={d['offloaded']:3d} "
              f"flops={d['flops']:.3e}")


if __name__ == "__main__":
    gemm_sweep()
    model_breakdown()
