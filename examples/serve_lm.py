"""Batched serving example: prefill + decode with KV cache and sampling.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch yi-6b]
(reduced config on CPU; the same serve loop drives the decode dry-run cells)
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.launch.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(1, 200, size=rng.integers(4, args.prompt_len + 1)))
        for _ in range(args.batch)
    ]
    print(f"serving {args.batch} requests (ragged prompts) on {args.arch} [reduced]")
    res = serve_batch(
        args.arch,
        prompts,
        smoke=True,
        max_new_tokens=args.max_new,
        cache_len=64,
        temperature=args.temperature,
    )
    print(f"prefill {res.prefill_s:.2f}s | decode {res.decode_s:.2f}s "
          f"| {res.tokens_per_s:.1f} tok/s")
    for i, row in enumerate(res.tokens):
        print(f"  req{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
