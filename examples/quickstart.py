"""Quickstart — the paper's user story on this framework.

The paper links NumPy against an OpenBLAS that offloads GEMM to a RISC-V
accelerator; *the application code never changes*.  Here that story is
``repro.hnp``: write plain NumPy-looking array code, and the library
underneath decides what runs where.  Operations build a lazy expression
graph; forcing it lowers the whole graph onto the offload cluster — fusing
elementwise epilogues into their producing GEMM, batching independent GEMMs,
and keeping intermediates device-resident instead of round-tripping through
host DRAM.

Below the frontend sits the same seam the paper has: ``repro.core.blas``
(the OpenBLAS analogue) over the declarative op registry, with the
three-region (copy / fork-join / compute) accounting.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

import repro.hnp as hnp
from repro.core import blas, crossover_size, engine, offload_policy, offload_trace
from repro.core.platform import HESOC_VCU128


def user_application(x, w1, b1, w2):
    """A 'NumPy user app' — no kernel calls, no placement, just array math."""
    h = hnp.tanh(hnp.linear(x, w1, b1))   # GEMM + fused bias/tanh epilogue
    y = h @ w2                            # consumes h where it lives
    sim = hnp.syrk(y)                     # any registered op, by name
    return y, sim


def main() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(512, 128)), jnp.float32)

    print("=== transparent acceleration: the hnp graph frontend ===")
    engine().reset()
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        with offload_trace() as t:
            with hnp.offload_region("quickstart") as region:
                y, sim = user_application(hnp.array(x), w1, b1, w2)
                y_np = hnp.asnumpy(y)      # forces: whole graph lowers here
                hnp.asnumpy(sim)
    print(t.summary())
    print(region.report.summary())
    for r in region.report.launches:
        fused = f" (+fused {'/'.join(r.fused)})" if r.fused else ""
        print(
            f"  {r.op:10s} -> {r.backend}@dev{r.device_id}"
            f" resident={r.resident_fraction:.0%}"
            f" readback={r.readback_bytes:.0f}B{fused}"
        )
    ref = np.tanh(np.asarray(x) @ np.asarray(w1) + np.asarray(b1)) @ np.asarray(w2)
    print(f"max err vs numpy: {np.max(np.abs(y_np - ref)):.2e}")

    print("\n=== same chain, eager BLAS seam (per-op staging) ===")
    engine().reset()
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        with offload_trace() as te:
            h = blas.matmul(x, w1)
            h = jnp.tanh(h + b1)
            blas.matmul(h, w2)
    saved = te.total_staged_bytes_charged() - t.by_graph()["quickstart"].staged_bytes_charged
    print(te.summary())
    print(f"staging the graph frontend avoided: {saved:.0f} bytes")

    print("\n=== paper platform (CVA6 + Snitch heSoC model), auto offload ===")
    engine().reset()
    with offload_policy(mode="auto", platform="hesoc-vcu128"):
        with offload_trace() as tp:
            y2, _ = user_application(hnp.array(x), w1, b1, w2)
            hnp.asnumpy(y2)
    print(tp.summary())
    for r in tp.records:
        print(f"  {r.op:8s} {r.shape_key:40s} -> {r.backend}")
    print(f"paper-platform crossover size (f64): n={crossover_size(HESOC_VCU128, 8)}")

    print("\n=== Pallas device kernels (interpret-mode validation) ===")
    engine().reset()
    with offload_policy(mode="device", use_pallas=True, interpret=True):
        y3 = hnp.asnumpy(hnp.array(x) @ w1)
    ref = np.asarray(x) @ np.asarray(w1)
    print(f"pallas gemm max err vs numpy: {np.max(np.abs(y3 - ref)):.2e}")

    # -----------------------------------------------------------------------
    # Static analysis: proving the seam instead of trusting it.
    #
    # `repro.analysis` checks the three layers the offload story rides on.
    # `hnp.offload_region(..., validate=True)` runs the graph verifier over
    # every graph forced inside the region *before dispatch*: node shapes
    # and dtypes re-derived against the registry host lowerings, residency
    # handle lifetimes, and wave-schedule RAW/WAR hazards — each break is a
    # named violation (graph/shape-mismatch, graph/use-after-unstage, ...).
    # The race detector then replays the LaunchTicket event streams the
    # modeled devices emitted and checks happens-before: compute never
    # starts before its first copy leg lands, clocks stay monotone, staged
    # data is down before any launch that could read it.
    # -----------------------------------------------------------------------
    print("\n=== graph verifier: validate=True catches a seeded hazard ===")
    from repro.analysis.graph import GraphVerificationError
    from repro.analysis.races import check_ticket_streams, ticket_streams

    engine().reset()
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        with hnp.offload_region("verified", validate=True):
            ok = hnp.tanh(hnp.array(x) @ w1)     # clean graph: verifies,
            hnp.asnumpy(ok)                       # lowers, and launches
            bad = hnp.relu(ok @ w2)
            bad.node.shape = (1, 1)               # corrupt the captured graph
            try:
                hnp.asnumpy(bad)                  # verifier fires pre-dispatch
            except GraphVerificationError as e:
                print(f"caught pre-dispatch: {e.violations[0].render()}")
        streams = ticket_streams()
    races = check_ticket_streams(streams)
    n = sum(len(t) for t in streams.values())
    print(f"race detector: {len(races)} violations over {n} tickets "
          f"on {len(streams)} devices (happens-before holds)")

    # -----------------------------------------------------------------------
    # Pipelined staging: killing the copy.
    #
    # The paper's bottleneck is the host<->device copy region.  By default
    # (`OffloadPolicy.pipeline_staging=True`) every launch is scored with
    # chunked, double-buffered staging: operands tile into
    # `Platform.dma_chunk_bytes` DMA legs (SPM/2 on the heSoC, the Pallas
    # pipeline tile on TPU) and the compute engine starts after the FIRST
    # leg lands, consuming chunk k while the DMA lands chunk k+1.  Offload
    # time approaches max(copy, compute) instead of copy + compute.  Inside
    # an `hnp.offload_region`, `prefetch_staging=True` adds the cross-wave
    # version: wave k+1's operand copies issue under wave k's compute.
    # -----------------------------------------------------------------------
    print("\n=== pipelined staging: copy_fraction before/after ===")
    from repro.core import TPU_V5E, breakdown, gemm_cost, pipelined_breakdown

    print(f"{'platform':14s} {'n':>5s} {'regime':7s} "
          f"{'serial cf':>9s} {'pipe cf':>8s} {'chunks':>6s} {'speedup':>8s}")
    for plat, itemsize, sizes in (
        (HESOC_VCU128, 8, (128, 256)),
        (TPU_V5E, 4, (2048, 8192)),
    ):
        for n in sizes:
            cost = gemm_cost(n, n, n, itemsize)
            # cold: every operand staged; steady: weights+output resident
            # (the serving/chain regime residency threading produces).
            for regime, rf in (("cold", 0.0), ("steady", 2.0 / 3.0)):
                s = breakdown(cost, plat, resident_fraction=rf)
                p = pipelined_breakdown(cost, plat, resident_fraction=rf)
                print(f"{plat.name:14s} {n:5d} {regime:7s} "
                      f"{s.copy_fraction:9.2f} {p.copy_fraction:8.2f} "
                      f"{p.chunks:6d} {p.pipelined_speedup:7.2f}x")
    p = pipelined_breakdown(gemm_cost(128, 128, 128, 8), HESOC_VCU128)
    print(f"paper crossover (n=128 f64): offload {p.serial_s * 1e3:.1f}ms -> "
          f"{p.offload_s * 1e3:.1f}ms with {p.chunks} DMA legs "
          f"(first leg {p.first_copy_leg_s * 1e3:.1f}ms gates compute)")

    print("\n=== cross-wave prefetch inside an offload_region ===")
    engine().reset()
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware",
                        prefetch_staging=True):
        with offload_trace() as tpf:
            with hnp.offload_region("prefetched") as reg:
                h = hnp.array(x) @ w1      # wave 1
                hnp.asnumpy(h @ w2[:512])  # wave 2: w2 prefetched under wave 1
    pf = [r for r in tpf.records if r.op == "prefetch_stage"]
    print(f"prefetch records: {len(pf)} "
          f"({reg.report.prefetched_bytes:.0f}B staged ahead); "
          f"cluster makespan {tpf.cluster_makespan_s() * 1e3:.3f}ms")

    # -----------------------------------------------------------------------
    # Graph forward: whole model blocks on lazy hnp graphs.
    #
    # cfg.forward_mode="graph" routes every transformer block through
    # models/forward.py: the block forward is captured as one hnp expression
    # graph, so the scheduler (not the call order) decides the launches —
    # elementwise epilogues (residual adds, SiLU gates, RMSNorm scales) fuse
    # into their producer GEMM, independent same-shape projections batch into
    # one gemm_batched, and intermediates stay device-resident across the
    # block.  Same registered descriptors as eager -> identical outputs.
    # -----------------------------------------------------------------------
    print("\n=== graph forward: a transformer block on the hnp scheduler ===")
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.models import forward as fwd

    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    model_g = build_model(dataclasses.replace(cfg, forward_mode="graph"))
    engine().reset()
    with offload_policy(mode="device", num_devices=2, scheduler="cost-aware"):
        with offload_trace() as t_eager:
            logits_eager, _ = model.forward(params, batch)
        engine().reset()
        with fwd.capture_reports() as reports:
            with offload_trace() as t_graph:
                logits_graph, _ = model_g.forward(params, batch)
    err = np.max(np.abs(np.asarray(logits_eager, np.float32)
                        - np.asarray(logits_graph, np.float32)))
    print(f"eager vs graph forward max err: {err:.2e}")
    rep = reports[0]  # the captured attention block (GraphReport)
    print(rep.summary())
    for r in rep.launches:
        fused = f" (+fused {'/'.join(r.fused)})" if r.fused else ""
        print(f"  {r.op:14s} -> {r.backend}@dev{r.device_id}"
              f" resident={r.resident_fraction:.0%}{fused}")
    saved = (t_eager.total_staged_bytes_charged()
             - t_graph.total_staged_bytes_charged())
    print(f"staging the graph forward avoided: {saved:.0f} bytes")

    # -----------------------------------------------------------------------
    # Streaming serve: continuous batching under live traffic.
    #
    # `repro.launch.streaming` turns the cluster into a serving front door:
    # seeded bursty arrivals (two request classes with their own prompt /
    # output length mixes and deadlines), prefill/decode disaggregation
    # across lanes (the KV handle migrates d2d from the prefill lane to its
    # decode slot), continuous batching (slots refill every step as
    # requests finish — no lock-step batch barrier), and SLO-aware
    # admission control that sheds load when the modeled queues say TTFT
    # would blow the budget.  Everything runs on modeled event time —
    # `make lint` AST-bans wall-clock reads in the engine — so a seed
    # reproduces the exact event stream.  Below: the same request
    # population offered at ~0.5x and ~2x estimated capacity.  At low load
    # nothing queues; past saturation admission rejects the overflow and
    # the served p99 TTFT stays inside the 250 ms SLO while sustained QPS
    # holds at the knee — that knee is the bench headline
    # (max_qps_at_slo in BENCH_offload.json).
    # -----------------------------------------------------------------------
    print("\n=== streaming serve: QPS / p99 TTFT at two offered loads ===")
    from repro.launch.streaming import (
        StreamConfig,
        bursty_trace,
        estimate_capacity,
        scale_trace,
        serve_stream,
    )

    scfg = StreamConfig(num_devices=4, prefill_lanes=1, decode_slots=8)
    cap = estimate_capacity("yi-6b", scfg)
    base = bursty_trace(2.0 * cap, 1.0, seed=0)
    print(f"{'offered':>9s} {'sustained':>9s} {'rejected':>8s} "
          f"{'ttft p99':>9s} {'tok p99':>8s}  SLO")
    for util in (0.5, 2.0):
        rep = serve_stream("yi-6b", scale_trace(base, util / 2.0), config=scfg)
        p = rep.point_dict()
        print(f"{p['offered_qps']:7.0f}/s {p['sustained_qps']:7.0f}/s "
              f"{rep.reject_rate:7.0%} {p['ttft_p99_ms']:7.0f}ms "
              f"{p['per_token_p99_ms']:6.1f}ms  "
              f"{'met' if p['meets_slo'] else 'MISSED'}")
    print(f"(estimated capacity {cap:.0f}/s; past it, admission sheds load "
          "so the served tail holds the SLO)")

    # -----------------------------------------------------------------------
    # When one expert gets famous.
    #
    # MoE routing is rarely uniform: under Zipfian popularity one expert
    # can see 10x its fair share of tokens, and with static homes the lane
    # that owns it becomes the makespan.  `repro.core.placement` watches
    # the per-step routed-token histogram (EMA with enter/exit hysteresis)
    # and *moves the weights to the traffic*: a hot expert's weight triple
    # migrates d2d to the least-loaded lane when the move amortizes, and a
    # persistently-hot expert gets a second replica with token-split
    # dispatch — capacity and token-dropping are explicit policy knobs,
    # and every dropped token is counted (`moe.tokens_dropped{expert=}`),
    # never silently lost.  Run it under `span_trace()` and the Perfetto
    # export shows the story: the `d2d:moe/expert0` flow arrow from the
    # source lane's compute track to the destination DMA track marks the
    # migration, the per-expert counter tracks show the drop rate falling
    # once the replica lands, and the post-move steps visibly rebalance.
    # `benchmarks.run --smoke` gates this as expert_placement_speedup:
    # dynamic placement must beat the static homes >= 1.2x at Zipf s=1.2.
    # -----------------------------------------------------------------------
    print("\n=== when one expert gets famous: dynamic expert placement ===")
    from repro.core.placement import run_skewed_workload
    from repro.obs import span_trace as _span_trace
    from repro.obs.trace_export import chrome_trace as _chrome_trace
    from repro.obs.trace_export import write_trace as _write_trace

    stat = run_skewed_workload(zipf_s=1.2, seed=0, dynamic=False, steps=48)
    with _span_trace("quickstart-placement") as ptr:
        dyn = run_skewed_workload(zipf_s=1.2, seed=0, dynamic=True, steps=48)
    print(f"{'':>10s} {'makespan':>10s} {'moves':>6s} {'dropped':>8s}")
    for label, r in (("static", stat), ("dynamic", dyn)):
        print(f"{label:>10s} {r.makespan_s*1e3:8.2f}ms "
              f"{r.migrations + r.replications:6d} {r.tokens_dropped:8d}")
    print(f"dynamic vs static: {stat.makespan_s / dyn.makespan_s:.2f}x; "
          f"decisions: {', '.join(d[1] + ':e' + str(d[2]) for d in dyn.decision_log)}")
    ppath = _write_trace("quickstart_placement_trace.json", _chrome_trace(ptr))
    print(f"trace -> {ppath}: find the d2d:moe/expert* flow arrow at the "
          "migration, then compare lane busy-time before/after it")

    # -----------------------------------------------------------------------
    # Seeing where the time goes.
    #
    # Everything above ran on modeled clocks, and `repro.obs` can record all
    # of it: wrap any workload in `span_trace()` and every dispatch, staging
    # leg, d2d migration, compute window, prefetch and request lifecycle
    # lands on a per-device lane (`dev0/dma`, `dev0/compute`, ...), exactly
    # where the two stream clocks put it.  Tracing is observation-only —
    # with the tracer off the instrumentation is a single `if`, and a
    # tracer-on run is bitwise-identical (tests/test_obs.py holds us to
    # that).  `chrome_trace()` exports the span set as Chrome trace-event
    # JSON: drop the file on https://ui.perfetto.dev and you get the DMA/
    # compute overlap, flow arrows for KV-cache migrations and slot
    # refills, and counter tracks (in-flight depth, resident bytes, decode
    # slot occupancy).  The same run fills the always-on metrics registry —
    # how often each path fired, labeled and rolled up flat.
    #
    # `make trace` captures the full smoke set (eager chain / hnp graph /
    # streaming burst) and prints the top self-time spans per lane.
    # -----------------------------------------------------------------------
    print("\n=== seeing where the time goes: span trace + metrics ===")
    from repro.obs import metrics, span_trace
    from repro.obs.trace_export import chrome_trace, summarize, write_trace

    with metrics.collect() as reg:
        with span_trace("quickstart-serve") as tr:
            engine().reset()
            serve_stream("yi-6b", scale_trace(base, 0.25), config=scfg)
    path = write_trace("quickstart_trace.json", chrome_trace(tr))
    print(f"{len(tr.spans)} spans on lanes {', '.join(tr.lanes()[:6])}, ... "
          f"-> {path} (load it at https://ui.perfetto.dev)")
    print(summarize(tr.spans, top=3))
    rollup = reg.rollup()
    for key in sorted(rollup):
        if key.startswith(("serve.", "dispatch.")):
            print(f"  {key} = {rollup[key]:.0f}")


if __name__ == "__main__":
    main()
