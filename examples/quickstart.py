"""Quickstart — the paper's user story on this framework.

The paper links NumPy against an OpenBLAS that offloads GEMM to a RISC-V
accelerator; the application code never changes.  Here the same seam is
``repro.core.blas``: array code calls BLAS-level ops, the offload engine
routes each call (host / device / Pallas kernel) by cost model, and the
trace shows the paper's three-region accounting.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import blas, crossover_size, engine, offload_policy, offload_trace
from repro.core.platform import HESOC_VCU128, TPU_V5E


def user_application(x, w1, w2):
    """A 'NumPy user app': two-layer projection + similarity matrix."""
    h = blas.matmul(x, w1)                 # hot GEMM -> offload candidate
    h = jnp.tanh(h)
    y = blas.matmul(h, w2)
    sim = blas.syrk(y)                     # host-only op (per the paper)
    norm = blas.nrm2(sim.reshape(-1))      # level-1 stays host
    return y, sim, norm


def main() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(512, 128)), jnp.float32)

    print("=== paper platform (CVA6 + Snitch heSoC model) ===")
    engine().reset()
    with offload_policy(mode="auto", platform="hesoc-vcu128"):
        with offload_trace() as t:
            user_application(x, w1, w2)
    print(t.summary())
    for r in t.records:
        print(f"  {r.op:8s} {r.shape_key:40s} -> {r.backend}")
    print(f"paper-platform crossover size (f64): n={crossover_size(HESOC_VCU128, 8)}")

    print("\n=== TPU v5e, resident weights (the paper's IOMMU end-state) ===")
    engine().reset()
    with offload_policy(mode="auto", platform="tpu-v5e", resident_fraction=1.0):
        with offload_trace() as t:
            user_application(x, w1, w2)
    print(t.summary())

    print("\n=== Pallas device kernels (interpret-mode validation) ===")
    engine().reset()
    with offload_policy(mode="device", use_pallas=True, interpret=True):
        y = blas.gemm(x, w1)
    ref = np.asarray(x) @ np.asarray(w1)
    print(f"pallas gemm max err vs numpy: {np.max(np.abs(np.asarray(y) - ref)):.2e}")


if __name__ == "__main__":
    main()
