"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Uses the full stack — synthetic Zipf data pipeline, BLAS-seam model, AdamW,
checkpointing with resume, loss logging.  Sized for CPU; the same driver
scales by pointing --arch at any registry config on a real mesh.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; a few hundred steps takes a while on 1 CPU core — use
--steps 40 for a quick pass.)
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import ArchConfig
from repro.configs.registry import _REGISTRY, register
from repro.launch.train import train

# ~100M params: 12L, d=768, vocab 32k  (GPT-2-small-ish, llama-style blocks)
LM100M = ArchConfig(
    name="lm-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    rope_theta=1.0e4,
    num_microbatches=1,
    dtype="float32",
    remat=False,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    if "lm-100m" not in _REGISTRY:
        register(LM100M)
    n = LM100M.param_count()
    print(f"lm-100m: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.global_batch}x{args.seq_len}")
    losses = train(
        "lm-100m",
        smoke=False,
        steps=args.steps,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 5, 10),
        log_every=10,
    )
    if losses:
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
