"""Bench-smoke perf gate — the headline numbers can't silently regress.

Reads the artifacts ``benchmarks.run --smoke`` just wrote and asserts the
pipelined-staging headline (ISSUE 6):

* ``pipelined_speedup >= 1.3`` at the paper-crossover regime (heSoC n=128
  float64, where T_copy ~ T_compute — the overlap win ROADMAP item 2 claims);
* tpu-v5e large-n steady-state ``copy_fraction < 0.6`` (serial staging
  spends 0.60 of offload time copying there; the pipeline must hide it);
* tpu-v5e n=2048 cold ``offload_s`` within 15% of ``max(copy, compute)``
  (the acceptance criterion: a shingle, not a sum);
* ``BENCH_trajectory.jsonl`` has no duplicate (commit, headline-hash) lines.

Run: PYTHONPATH=src:. python tools/check_bench_gate.py [--offload PATH]
     [--trajectory PATH]

Exit code 0 = gate holds; 1 = regression (each failure printed).
"""

from __future__ import annotations

import argparse
import json
import sys


def check_offload(summary: dict) -> list:
    failures = []
    pipe = summary.get("pipelined_staging")
    if not pipe:
        return ["BENCH_offload.json has no pipelined_staging section"]

    crossover = pipe["paper_crossover"]
    if crossover["pipelined_speedup"] < 1.3:
        failures.append(
            "paper-crossover pipelined_speedup "
            f"{crossover['pipelined_speedup']:.3f} < 1.3"
        )

    steady = pipe["tpu_large_n_steady"]
    if steady["pipelined_copy_fraction"] >= 0.6:
        failures.append(
            "tpu-v5e large-n steady pipelined copy_fraction "
            f"{steady['pipelined_copy_fraction']:.3f} >= 0.6"
        )

    n2048 = pipe["tpu_n2048"]
    if n2048["pipelined_vs_max"] > 1.15:
        failures.append(
            "tpu-v5e n=2048 pipelined offload_s is "
            f"{n2048['pipelined_vs_max']:.3f}x max(copy, compute) > 1.15x"
        )
    return failures


def check_trajectory(path: str) -> list:
    # Mirror benchmarks.run's dedupe key so the two stay in lockstep.
    from benchmarks.run import _headline_hash

    seen = set()
    failures = []
    try:
        with open(path) as f:
            lines = [ln for ln in f if ln.strip()]
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    if not lines:
        return [f"{path} is empty — bench-smoke did not record a headline"]
    for i, line in enumerate(lines, 1):
        try:
            e = json.loads(line)
        except ValueError:
            failures.append(f"{path}:{i}: not valid JSON")
            continue
        key = (e.get("commit", ""), _headline_hash(e.get("headline", {})))
        if key in seen:
            failures.append(
                f"{path}:{i}: duplicate headline for commit {key[0]!r}"
            )
        seen.add(key)
    last = json.loads(lines[-1])
    if "pipelined_speedup" not in last.get("headline", {}):
        failures.append(
            f"{path}: latest headline is missing 'pipelined_speedup'"
        )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--offload", default="BENCH_offload.json")
    ap.add_argument("--trajectory", default="BENCH_trajectory.jsonl")
    args = ap.parse_args()

    try:
        with open(args.offload) as f:
            summary = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench gate: cannot load {args.offload}: {e}")
        return 1

    failures = check_offload(summary) + check_trajectory(args.trajectory)
    if failures:
        print("bench gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1

    pipe = summary["pipelined_staging"]
    print(
        "bench gate ok: pipelined_speedup="
        f"{pipe['paper_crossover']['pipelined_speedup']:.2f}x (>=1.3), "
        "tpu steady copy_fraction="
        f"{pipe['tpu_large_n_steady']['pipelined_copy_fraction']:.2f} (<0.6), "
        "n=2048 vs max(copy,compute)="
        f"{pipe['tpu_n2048']['pipelined_vs_max']:.3f}x (<=1.15), "
        "trajectory deduped"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
